"""Run a solver process: the cross-process serving plane's server half.

Starts a :class:`~repro.service.server.SolverServer` owning an
:class:`~repro.service.broker.OffloadBroker` with one deterministic demo
tenant (a seeded random WCG — any client building the same
``--nodes``/``--seed`` profile gets bit-identical placements), a
write-ahead request journal, and a background snapshot loop.  On start
it warm-restarts from whatever journal/snapshots the directory already
holds, so SIGKILL + rerun resumes where the dead process stopped —
the crash-recovery integration test and the CI cross-process smoke both
drive exactly this entrypoint.

    PYTHONPATH=src python examples/serve_broker.py --socket /tmp/mcop.sock \
        --journal /tmp/mcop/journal.jsonl --snapshot-dir /tmp/mcop/snaps

then, from any number of other processes:

    from repro.service import BrokerClient, BrokerSession, unix_address
    client = BrokerClient(unix_address("/tmp/mcop.sock"),
                          tenants={"app": demo_tenant(12, 0)}).connect()
    session = BrokerSession(client, "app")   # the unmodified session class
    session.observe(env); client.tick(); print(session.drain())

``--kill-at-tick N`` is a crash-test hook: the process SIGKILLs *itself*
mid-tick — after the broker state mutates, before the journal tick
append — the exact torn write the warm-restart path must absorb.
"""

import argparse
import os
import signal
import sys

import numpy as np

from repro.core import AppProfile, ResponseTimeModel, random_wcg
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.service import OffloadBroker, SolverServer, tcp_address, unix_address


def demo_tenant(nodes: int, seed: int):
    """The (profile, cost_model) pair both sides build independently —
    seeded, so server and clients agree without shipping the graph."""
    profile = AppProfile.from_wcg_times(
        random_wcg(nodes, rng=np.random.default_rng(seed))
    )
    return profile, ResponseTimeModel()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--socket", help="unix socket path")
    ap.add_argument("--tcp", help="host:port (port 0 = ephemeral)")
    ap.add_argument("--journal", help="write-ahead journal path (JSONL)")
    ap.add_argument("--snapshot-dir", help="placement-cache snapshot dir")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="snapshot cadence in ticks")
    ap.add_argument("--tenant", default="app")
    ap.add_argument("--nodes", type=int, default=12, help="demo WCG size")
    ap.add_argument("--seed", type=int, default=0, help="demo WCG seed")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "jax", "pallas"))
    ap.add_argument("--batch-capacity", type=int, default=0,
                    help="also expose a batch session group of this size")
    ap.add_argument("--max-ticks", type=int, default=None,
                    help="exit after serving this many ticks")
    ap.add_argument("--trace", help="export a chrome trace here on exit")
    ap.add_argument("--trace-jsonl",
                    help="export a tracequery-readable JSONL trace on exit")
    ap.add_argument("--kill-at-tick", type=int, default=None,
                    help="crash hook: SIGKILL self mid-tick N")
    args = ap.parse_args(argv)

    if bool(args.socket) == bool(args.tcp):
        ap.error("exactly one of --socket / --tcp is required")
    if args.socket:
        address = unix_address(args.socket)
    else:
        host, _, port = args.tcp.partition(":")
        address = tcp_address(host or "127.0.0.1", int(port or 0))

    broker = OffloadBroker(backend=args.backend, clock=lambda: 0.0)
    profile, cost_model = demo_tenant(args.nodes, args.seed)
    broker.register(args.tenant, profile, cost_model)

    if args.kill_at_tick is not None:
        real_tick = broker.tick

        def tick_then_die(**kw):
            report = real_tick(**kw)
            if report.tick >= args.kill_at_tick:
                os.kill(os.getpid(), signal.SIGKILL)  # torn mid-tick crash
            return report

        broker.tick = tick_then_die

    tracer = Tracer() if (args.trace or args.trace_jsonl) else None
    server = SolverServer(
        broker,
        address=address,
        journal_path=args.journal,
        snapshot_dir=args.snapshot_dir,
        snapshot_every_ticks=args.snapshot_every,
        tracer=tracer,
        metrics=MetricsRegistry(),
    )
    recovered = server.recover()
    bound = server.bind()
    if args.batch_capacity > 0:
        broker.register_batch(args.tenant, args.batch_capacity)
    # READY is the startup barrier the tests/CI wait on; the address
    # matters for --tcp with an ephemeral port
    print(f"RECOVERED {recovered}", flush=True)
    print(f"READY {' '.join(str(p) for p in bound)}", flush=True)
    try:
        server.serve_forever(max_ticks=args.max_ticks)
    except KeyboardInterrupt:
        server.close()
    if args.trace and tracer is not None:
        tracer.export_chrome(args.trace)
    if args.trace_jsonl and tracer is not None:
        tracer.export_jsonl(args.trace_jsonl)
    return 0


if __name__ == "__main__":
    sys.exit(main())
