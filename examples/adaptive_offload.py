"""Environment-adaptive repartitioning (paper Fig. 1) — a day in the life.

Simulates a mobile device walking through changing network conditions
(WiFi → 3G → congested 3G → back), with the cloud occasionally degraded.
The AdaptiveController re-runs MCOP only when drift exceeds the threshold
and reports the paper's three schemes at every instant.  The whole walk
goes through the *batched* path — one ``mcop_batch`` dispatch for all
repartition points — and a second user walking the same streets shows the
quantized placement cache turning their repartitions into hits.  Then the
serving tier: an OffloadBroker coalesces a whole fleet of users into one
dispatch per bucket per tick, snapshots its placement cache, and a
restarted broker replays the identical day with ZERO solver dispatches.
Also shows the cluster-scale analogue: chips failing out of a tier
triggering the same repartition path (ElasticMeshManager, sync and
broker-queued) and a straggler being detected and drained by the
HeartbeatMonitor.

    PYTHONPATH=src python examples/adaptive_offload.py
"""

import dataclasses
import tempfile

import numpy as np

from repro.core import (
    AdaptiveController,
    AppProfile,
    Environment,
    PlacementCache,
    ResponseTimeModel,
    face_recognition_graph,
)
from repro.core.placement import TPUV5E_TIER
from repro.configs import ARCHITECTURES, SHAPES
from repro.profilers.program import stage_specs
from repro.runtime import ElasticMeshManager, HeartbeatMonitor
from repro.service import OffloadBroker, run_workload, user_traces


def main():
    # ---- the paper's mobile scenario ---------------------------------
    print("=== Mobile walk: bandwidth trace (MB/s), F trace =============")
    prof = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    cache = PlacementCache()   # shared across every user of this app profile
    ctl = AdaptiveController(prof, ResponseTimeModel(), threshold=0.15,
                             min_interval=2, backend="jax", cache=cache)
    trace = [
        (8.0, 3.0, "office WiFi"),
        (7.6, 3.0, "WiFi, light load"),
        (1.2, 3.0, "walk outside → 3G"),
        (1.1, 3.0, "3G"),
        (0.3, 3.0, "congested cell"),
        (0.3, 1.5, "cloud degraded too"),
        (6.0, 3.0, "home WiFi"),
    ]
    # one batched dispatch for the whole walk's repartition points
    events = ctl.sweep([Environment.symmetric(bw, f) for bw, f, _ in trace])
    print(f"{'env':<20s} {'B':>5s} {'F':>4s} {'repart':>7s} {'cache':>5s} "
          f"{'no-off':>8s} {'full':>8s} {'partial':>8s} {'gain':>6s}")
    for (bw, f, label), ev in zip(trace, events):
        print(f"{label:<20s} {bw:5.1f} {f:4.1f} {str(ev.repartitioned):>7s} "
              f"{'hit' if ev.cache_hit else '-':>5s} "
              f"{ev.no_offload_cost:8.1f} {ev.full_offload_cost:8.1f} "
              f"{ev.partial_cost:8.1f} {ev.gain:6.1%}")
    n_repart = sum(e.repartitioned for e in ctl.history)
    print(f"→ {n_repart}/{len(trace)} observations triggered repartitioning "
          f"(threshold+cooldown hysteresis)")

    # a second user on the same streets: repartitions become cache hits
    ctl2 = AdaptiveController(prof, ResponseTimeModel(), threshold=0.15,
                              min_interval=2, backend="jax", cache=cache)
    events2 = ctl2.sweep([Environment.symmetric(bw, f) for bw, f, _ in trace])
    st = cache.stats
    print(f"→ user 2, same walk: {sum(e.cache_hit for e in events2)}"
          f"/{sum(e.repartitioned for e in events2)} repartitions served "
          f"from cache; totals hits={st.hits} misses={st.misses} "
          f"hit_rate={st.hit_rate:.0%}\n")

    # ---- the serving tier: many users, one broker ---------------------
    print("=== Offload broker: a fleet of users, one dispatch per bucket =")
    n_users, steps = 12, 10
    broker = OffloadBroker(backend="jax")
    broker.register("face", prof, ResponseTimeModel())
    traces = user_traces(n_users, steps, seed=42)
    run_workload(broker, "face", n_users=n_users, steps=steps, traces=traces)
    tel = broker.telemetry
    print(f"{n_users} users x {steps} ticks: {tel.requests} solve requests "
          f"→ {tel.solved} solves in {tel.dispatches} dispatches "
          f"(coalesce={tel.coalesce_ratio:.0%}, cache hit={tel.hit_rate:.0%}, "
          f"max queue={tel.max_queue_depth})")

    # serving restart: snapshot the cache, warm-start a new broker, replay
    with tempfile.TemporaryDirectory() as tmp:
        snap_path = f"{tmp}/face_cache.json"
        broker.save_snapshot("face", snap_path)
        broker2 = OffloadBroker(backend="jax")
        broker2.register("face", prof, ResponseTimeModel(), warm_start=snap_path)
        run_workload(broker2, "face", n_users=n_users, steps=steps, traces=traces)
    t2 = broker2.telemetry
    print(f"→ restart + warm cache, same day replayed: {t2.dispatches} solver "
          f"dispatches, hit rate {t2.hit_rate:.0%}\n")

    # ---- the cluster-scale analogue -----------------------------------
    print("=== Elastic fleet: chip loss re-prices the speedup factor ====")
    cfg = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(cfg, SHAPES["train_4k"], group=4)
    mgr = ElasticMeshManager(
        stages,
        dataclasses.replace(TPUV5E_TIER, name="pod-0", chips=128),
        dataclasses.replace(TPUV5E_TIER, name="pod-1", chips=128),
    )
    print(f"t=0   F={mgr.speedup:.2f} offloaded_stages="
          f"{int(mgr.plan.stage_tier.sum())}/{len(stages)}")
    ev = mgr.resize(step=120, remote_chips=32, reason="pod-1 ICI brownout")
    print(f"t=120 F={mgr.speedup:.2f} offloaded_stages="
          f"{int(ev.plan.stage_tier.sum())}/{len(stages)}  ({ev.reason})")
    ev = mgr.resize(step=300, remote_chips=256, reason="pod-1 restored+grown")
    print(f"t=300 F={mgr.speedup:.2f} offloaded_stages="
          f"{int(ev.plan.stage_tier.sum())}/{len(stages)}  ({ev.reason})")
    # elastic events are broker clients too: the solve queues with user
    # requests and lands at the next tick
    broker.register("fleet")
    pending = mgr.submit_resize(broker, "fleet", step=450, remote_chips=64,
                                reason="pod-1 partial brownout (queued)")
    broker.tick()
    ev = pending.resolve()
    print(f"t=450 F={mgr.speedup:.2f} offloaded_stages="
          f"{int(ev.plan.stage_tier.sum())}/{len(stages)}  ({ev.reason})\n")

    # ---- straggler mitigation -----------------------------------------
    print("=== Straggler detection & microbatch reassignment ============")
    clock = [0.0]
    mon = HeartbeatMonitor(range(8), deadline=30.0, straggler_factor=2.0,
                           clock=lambda: clock[0])
    rng = np.random.default_rng(0)
    for tick in range(10):
        clock[0] += 10.0
        for d in range(8):
            if d == 5 and tick > 4:
                continue                      # device 5 dies at t=50
            st = 1.0 + 0.05 * rng.standard_normal()
            if d == 2:
                st *= 3.0                     # device 2 is a straggler
            mon.heartbeat(d, step_time=st)
    print("failed:", mon.failed(), " stragglers:", mon.stragglers())
    assign = mon.reassignment(n_micro=32)
    print("microbatch assignment (32 total):", assign)
    print("→ dead device drained; straggler at half weight")


if __name__ == "__main__":
    main()
