"""Quickstart: the paper's algorithm on its own worked example + the
face-recognition app, then the same engine placing a 7B LLM across tiers.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import numpy as np

from repro.core import (
    Environment,
    ResponseTimeModel,
    AppProfile,
    brute_force,
    face_recognition_graph,
    full_offloading,
    maxflow_optimal,
    mcop_reference,
    no_offloading,
    offloading_gain,
    paper_example_graph,
)
from repro.core.placement import TPUV5E_TIER, plan_placement
from repro.configs import ARCHITECTURES, SHAPES
from repro.profilers.program import stage_specs


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main():
    # ------------------------------------------------------------------
    section("Paper §5.5 worked example (Figs. 6–11)")
    g = paper_example_graph()
    res = mcop_reference(g)
    print(f"local cost total C_local = {g.local_cost_total:.0f}")
    for i, ph in enumerate(res.phases, 1):
        print(f"  phase {i}: order={' '.join(ph.order):<28s} cut={ph.cut_value:.0f}")
    local = [g.names[i] for i in res.local_indices]
    cloud = [g.names[i] for i in res.cloud_indices]
    print(f"optimal cut = {res.min_cut:.0f}  local={local}  cloud={cloud}")
    print(f"(paper: cut 22, local {{a, c}}, cloud {{b, d, e, f}})")

    # ------------------------------------------------------------------
    section("Face recognition app (Figs. 12–13), F=2, B=1 MB/s")
    fg = face_recognition_graph(speedup=2.0, bandwidth_mbps=1.0)
    fres = mcop_reference(fg)
    no, full = no_offloading(fg), full_offloading(fg)
    print(f"no offloading   : {no.cost:9.1f} ms")
    print(f"full offloading : {full.cost:9.1f} ms")
    print(f"partial (MCOP)  : {fres.min_cut:9.1f} ms  "
          f"gain={offloading_gain(no.cost, fres.min_cut):.1%}")
    print("local:", [fg.names[i] for i in fres.local_indices])
    print("cloud:", [fg.names[i] for i in fres.cloud_indices])

    # ------------------------------------------------------------------
    section("Optimality check against independent oracles")
    b, m = brute_force(fg), maxflow_optimal(fg)
    print(f"brute force={b.cost:.1f}  maxflow={m.cost:.1f}  mcop={fres.min_cut:.1f}")

    # ------------------------------------------------------------------
    section("Same algorithm placing qwen2-7b stages across two TPU tiers")
    cfg = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(cfg, SHAPES["train_4k"], group=4)
    plan = plan_placement(
        stages,
        dataclasses.replace(TPUV5E_TIER, name="pod-0", chips=64),
        dataclasses.replace(TPUV5E_TIER, name="pod-1", chips=192),
    )
    print(f"stages={len(stages)}  mcop_cost={plan.mcop_cost:.3e}s/step")
    print(f"contiguous pipeline boundary at stage {plan.contiguous_boundary} "
          f"(penalty {plan.contiguity_penalty:.2e}s)")
    print(f"activation bytes crossing tiers per step: {plan.cut_bytes:.3e}")
    tier0 = [stages[i].name for i in plan.tier_stages(0)][:4]
    print(f"pod-0 keeps: {tier0}{'…' if len(plan.tier_stages(0)) > 4 else ''}")


if __name__ == "__main__":
    main()
