"""End-to-end training example: a ~100M-parameter decoder LM for a few
hundred steps on the synthetic pipeline, with checkpoint/resume and an
MCOP placement report.

This drives the same launcher as production (`repro.launch.train`); the
~100M model is a width/depth-reduced qwen2-family config (the full
assigned configs are exercised via the dry-run — this machine is one CPU).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = [
        "--arch", "qwen2-7b",
        "--reduced",
        "--steps", str(args.steps),
        "--seq-len", "128",
        "--global-batch", "16",
        "--n-micro", "2",
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "20",
    ]
    print(f"[example] python -m repro.launch.train {' '.join(argv)}")
    return train_cli.main(argv)


if __name__ == "__main__":
    sys.exit(main())
