"""Serving example: batched requests through the KV-cache engine with the
MCOP prefill/decode-pool placement report.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch import serve as serve_cli


def main():
    argv = [
        "--arch", "qwen3-32b",
        "--reduced",
        "--requests", "12",
        "--max-new-tokens", "16",
        "--max-batch", "4",
        "--prompt-len", "24",
        "--temperature", "0.7",
    ]
    print(f"[example] python -m repro.launch.serve {' '.join(argv)}")
    return serve_cli.main(argv)


if __name__ == "__main__":
    sys.exit(main())
