"""Property-based validation: MCOP vs independent exact oracles.

The paper proves Theorem 1 (each phase cut is a min s–t cut) and claims
global optimality; our oracles show that claim does NOT survive signed
node gains — MCOP is exact on ~70% of adversarial random WCGs (mean gap
≈5%, paper's own worked example exact).  First counterexample:
``random_wcg(5, rng=default_rng(100))`` → MCOP 54.06 vs optimum 53.06.

The properties below are therefore the ones that actually hold:

  * optimum ≤ MCOP ≤ full-offloading cost (the last phase IS the
    full-offloading cut), and the reported placement achieves the
    reported cost;
  * brute force == max-flow reduction (two independent exact oracles);
  * MCOP == optimum on a large measured fraction of instances, and
    exactly on the paper's example/topologies (see test_paper_example);
  * the exact solver is monotone in bandwidth and hits the textbook
    limits (B→∞ / B→0).

The optimality-gap distribution itself is quantified in
``benchmarks/optimality_gap.py`` and reported in EXPERIMENTS.md.
"""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or skip-shim (see _hyp.py)

from repro.core import (
    WCG,
    branch_and_bound,
    brute_force,
    chain_dp,
    full_offloading,
    linear_graph,
    loop_graph,
    maxflow_optimal,
    mcop_jax,
    mcop_reference,
    mesh_graph,
    no_offloading,
    random_wcg,
    tree_graph,
)

SETTINGS = dict(max_examples=60, deadline=None)


@st.composite
def wcg_strategy(draw, max_n: int = 10):
    n = draw(st.integers(2, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    edge_prob = draw(st.sampled_from([0.1, 0.3, 0.6, 0.9]))
    speedup = draw(st.sampled_from([1.2, 2.0, 3.0, 10.0]))
    n_pin = draw(st.integers(1, max(1, n // 3)))
    integer = draw(st.booleans())
    return random_wcg(
        n,
        edge_prob=edge_prob,
        speedup=speedup,
        n_unoffloadable=n_pin,
        rng=np.random.default_rng(seed),
        integer_weights=integer,
    )


# ----------------------------------------------------------------------
# numpy-based smoke fallbacks — fixed-seed versions of the key properties
# that run in tier-1 even when hypothesis is unavailable.
# ----------------------------------------------------------------------


def _smoke_wcg(seed: int) -> WCG:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 11))
    return random_wcg(
        n,
        edge_prob=float(rng.choice([0.1, 0.3, 0.6, 0.9])),
        speedup=float(rng.choice([1.2, 2.0, 3.0, 10.0])),
        n_unoffloadable=int(rng.integers(1, max(2, n // 3 + 1))),
        rng=rng,
    )


@pytest.mark.parametrize("seed", range(12))
def test_mcop_bounds_and_self_consistency_smoke(seed):
    g = _smoke_wcg(seed)
    res = mcop_reference(g)
    opt = brute_force(g)
    assert res.min_cut >= opt.cost - 1e-9
    assert res.min_cut <= full_offloading(g).cost + 1e-9
    assert g.total_cost(res.local_mask) == pytest.approx(res.min_cut, rel=1e-9)
    g.validate_placement(res.local_mask)


@pytest.mark.parametrize("seed", range(8))
def test_jax_backend_matches_reference_smoke(seed):
    g = _smoke_wcg(100 + seed)
    ref = mcop_reference(g)
    jx = mcop_jax(g)
    assert jx.min_cut == pytest.approx(ref.min_cut, rel=1e-5, abs=1e-4)
    assert g.total_cost(jx.local_mask) == pytest.approx(ref.min_cut, rel=1e-5, abs=1e-4)


@pytest.mark.parametrize("seed", range(8))
def test_maxflow_oracle_agrees_with_brute_force_smoke(seed):
    g = _smoke_wcg(200 + seed)
    assert maxflow_optimal(g).cost == pytest.approx(
        brute_force(g).cost, rel=1e-9, abs=1e-9
    )


@given(wcg_strategy())
@settings(**SETTINGS)
def test_mcop_bounds_and_self_consistency(g):
    """optimum ≤ MCOP ≤ full offloading; reported mask achieves reported cost."""
    res = mcop_reference(g)
    opt = brute_force(g)
    assert res.min_cut >= opt.cost - 1e-9
    assert res.min_cut <= full_offloading(g).cost + 1e-9
    assert g.total_cost(res.local_mask) == pytest.approx(res.min_cut, rel=1e-9)
    g.validate_placement(res.local_mask)


@given(wcg_strategy())
@settings(**SETTINGS)
def test_maxflow_oracle_agrees_with_brute_force(g):
    assert maxflow_optimal(g).cost == pytest.approx(brute_force(g).cost, rel=1e-9, abs=1e-9)


@given(wcg_strategy(max_n=8))
@settings(**SETTINGS)
def test_jax_backend_matches_reference(g):
    """The jittable MCOP implements the same algorithm, bit-for-bit-ish."""
    ref = mcop_reference(g)
    jx = mcop_jax(g)
    assert jx.min_cut == pytest.approx(ref.min_cut, rel=1e-5, abs=1e-4)
    assert g.total_cost(jx.local_mask) == pytest.approx(ref.min_cut, rel=1e-5, abs=1e-4)


@given(wcg_strategy(max_n=9))
@settings(max_examples=30, deadline=None)
def test_branch_and_bound_exact(g):
    assert branch_and_bound(g).cost == pytest.approx(brute_force(g).cost, rel=1e-9, abs=1e-9)


@given(st.integers(2, 12), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_chain_dp_on_linear_graphs(n, seed):
    g = linear_graph(n, rng=np.random.default_rng(seed))
    assert chain_dp(g).cost == pytest.approx(brute_force(g).cost, rel=1e-9)


@pytest.mark.slow
def test_mcop_exact_rate_on_adversarial_distribution():
    """Statistical reproduction check: ≥60% exact, mean gap <8% on the
    hardest random distribution (measured ≈70% / 4.9%)."""
    gaps, exact = [], 0
    n_trials = 200
    for seed in range(n_trials):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 12))
        g = random_wcg(
            n,
            edge_prob=float(rng.choice([0.1, 0.3, 0.6, 0.9])),
            speedup=float(rng.choice([1.2, 2.0, 3.0, 10.0])),
            n_unoffloadable=int(rng.integers(1, max(2, n // 3))),
            rng=rng,
        )
        gap = (mcop_reference(g).min_cut - brute_force(g).cost) / max(
            brute_force(g).cost, 1e-12
        )
        gaps.append(gap)
        exact += gap < 1e-9
    assert exact / n_trials >= 0.60, exact / n_trials
    assert np.mean(gaps) < 0.08, np.mean(gaps)


def test_known_counterexample_to_paper_theorem1():
    """Documented counterexample: MCOP strictly above the true optimum."""
    g = random_wcg(5, rng=np.random.default_rng(100))
    res = mcop_reference(g)
    opt = brute_force(g)
    assert res.min_cut > opt.cost + 0.5  # 54.06 vs 53.06
    assert maxflow_optimal(g).cost == pytest.approx(opt.cost, rel=1e-9)


@given(st.integers(3, 10), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_paper_topologies_mcop_behaves(n, seed):
    """On the paper's own topology families MCOP is near-exact in practice;
    assert the bound properties plus exactness of the exact solver."""
    rng = np.random.default_rng(seed)
    for builder in (linear_graph, loop_graph, tree_graph):
        g = builder(n, rng=rng)
        res = mcop_reference(g)
        opt = brute_force(g)
        assert opt.cost - 1e-9 <= res.min_cut <= full_offloading(g).cost + 1e-9
        assert maxflow_optimal(g).cost == pytest.approx(opt.cost, rel=1e-9)
    g = mesh_graph(2, max(2, n // 2), rng=rng)
    assert mcop_reference(g).min_cut >= brute_force(g).cost - 1e-9


@given(wcg_strategy(max_n=8), st.sampled_from([0.25, 0.5, 2.0, 4.0]))
@settings(**SETTINGS)
def test_exact_solver_bandwidth_monotonicity(g, scale):
    """For the exact optimum: higher bandwidth never hurts (per-placement
    costs are monotone in edge weights, hence so is the min)."""
    base = maxflow_optimal(g).cost
    scaled = maxflow_optimal(g.with_bandwidth_scale(scale)).cost
    if scale >= 1.0:
        assert scaled <= base + 1e-9
    else:
        assert scaled >= base - 1e-9


@given(wcg_strategy(max_n=8))
@settings(max_examples=30, deadline=None)
def test_exact_solver_extreme_bandwidth_limits(g):
    """B→∞ ⇒ offload everything with positive gain; B→0 ⇒ no offloading."""
    gains = g.w_local - g.w_cloud
    g_inf = g.with_bandwidth_scale(1e12)
    best_inf = maxflow_optimal(g_inf).cost
    ideal = float(np.where(g.offloadable & (gains > 0), g.w_cloud, g.w_local).sum())
    assert best_inf == pytest.approx(ideal, rel=1e-6, abs=1e-5)

    g_zero = g.with_bandwidth_scale(1e-12)
    best0 = maxflow_optimal(g_zero).cost
    # with a dead link no edge may be cut, so the decision is per connected
    # component: offload a whole component iff it is fully offloadable and
    # its total gain is positive
    comp = np.arange(g.n)

    def find(i):
        while comp[i] != i:
            comp[i] = comp[comp[i]]
            i = comp[i]
        return i

    for i in range(g.n):
        for j in range(g.n):
            if g.adj[i, j] > 0:
                comp[find(i)] = find(j)
    ideal0 = 0.0
    for root in {find(i) for i in range(g.n)}:
        members = [i for i in range(g.n) if find(i) == root]
        movable = all(g.offloadable[i] for i in members)
        gain = sum(gains[i] for i in members)
        if movable and gain > 0:
            ideal0 += sum(g.w_cloud[i] for i in members)
        else:
            ideal0 += sum(g.w_local[i] for i in members)
    assert best0 == pytest.approx(ideal0, rel=1e-6, abs=1e-3)


@given(wcg_strategy(max_n=8), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_total_cost_eq2_matches_explicit_sum(g, seed):
    """Eq. 2 evaluated by WCG.total_cost == hand-rolled indicator sum."""
    rng = np.random.default_rng(seed)
    mask = rng.random(g.n) < 0.5
    mask |= ~g.offloadable
    expected = 0.0
    for v in range(g.n):
        expected += g.w_local[v] if mask[v] else g.w_cloud[v]
    for i in range(g.n):
        for j in range(i + 1, g.n):
            if g.adj[i, j] and mask[i] != mask[j]:
                expected += g.adj[i, j]
    assert g.total_cost(mask) == pytest.approx(expected, rel=1e-12)


def test_mcop_scales_to_hundreds_of_vertices():
    g = random_wcg(150, edge_prob=0.05, rng=np.random.default_rng(0))
    res = mcop_reference(g)
    mf = maxflow_optimal(g)
    assert res.min_cut >= mf.cost - 1e-6
    assert g.total_cost(res.local_mask) == pytest.approx(res.min_cut, rel=1e-9)
