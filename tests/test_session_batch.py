"""Array-native session engine: SessionBatch tick ≡ serial object path.

The PR-6 acceptance suite.  The strict parity tests compare one
vectorized ``tick_sessions``/``BatchSessionGroup`` tick against K
``BrokerSession`` observe loops on the *reference* backend with ``==``
(no tolerances): events, placements, prices, cut values and shared-cache
counters must all be bit-identical across the Fig.-2 topologies × three
cost models.  Around the tentpole: traffic determinism under a fixed
seed, the vectorized cache API (`get_many`/`put_many`), the
load-adaptive WFQ hook, device-resident pricing telemetry, and the
atomic-tick failure containment.
"""

import numpy as np
import pytest

from repro.core import (
    AppProfile,
    EnergyModel,
    EnvQuantizer,
    Environment,
    PlacementCache,
    ResponseTimeModel,
    SessionBatch,
    WeightedModel,
    device_price_summary,
    face_recognition_graph,
    linear_graph,
    loop_graph,
    mesh_graph,
    price_trace,
    tick_sessions,
    tree_graph,
)
from repro.core.cost_models import EnvArrays
from repro.core import session_batch as session_batch_mod
from repro.service import (
    OffloadBroker,
    TrafficGenerator,
    WeightedFairScheduler,
    run_batch_workload,
    run_workload,
    user_traces,
)

pytestmark = pytest.mark.service

FIG2_TOPOLOGIES = {
    "linear": lambda: linear_graph(9, rng=np.random.default_rng(1)),
    "loop": lambda: loop_graph(8, rng=np.random.default_rng(2)),
    "tree": lambda: tree_graph(10, rng=np.random.default_rng(3)),
    "mesh": lambda: mesh_graph(3, 3, rng=np.random.default_rng(4)),
}

MODELS = {
    "time": ResponseTimeModel,
    "energy": EnergyModel,
    "weighted": lambda: WeightedModel(0.35),
}

EVENT_FIELDS = (
    "step",
    "repartitioned",
    "cache_hit",
    "partial_cost",
    "no_offload_cost",
    "full_offload_cost",
    "gain",
)


def _broker(**kw) -> OffloadBroker:
    kw.setdefault("backend", "reference")
    kw.setdefault("clock", lambda: 0.0)
    return OffloadBroker(**kw)


def _run_object_path(profile, model, traces, *, backend="reference"):
    broker = _broker(backend=backend)
    broker.register("app", profile, model)
    report = run_workload(
        broker,
        "app",
        n_users=len(traces),
        steps=len(traces[0]),
        threshold=0.15,
        min_interval=2,
        traces=traces,
    )
    return report, broker


def _run_batch_path(profile, model, traces, *, backend="reference"):
    k, steps = len(traces), len(traces[0])
    broker = _broker(backend=backend)
    broker.register("app", profile, model)
    group = broker.register_batch("app", k, threshold=0.15, min_interval=2)
    for t in range(steps):
        envs = EnvArrays.from_envs([traces[u][t] for u in range(k)])
        group.observe(envs, arrived=np.arange(k) if t == 0 else None)
        broker.tick()
    return group.drain(), broker


# ----------------------------------------------------------------------
# Tentpole parity: batched tick ≡ serial observe loops, bitwise
# ----------------------------------------------------------------------


@pytest.mark.parametrize("topology", sorted(FIG2_TOPOLOGIES))
@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_batch_tick_matches_object_sessions(topology, model_name):
    """One vectorized tick per step produces events (steps, flags,
    masks, every price, every cut value) and shared-cache counters
    bit-identical to K per-object BrokerSessions observing the same
    traces — ``==``, no tolerances."""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES[topology]())
    traces = user_traces(5, 7, seed=11)
    object_report, ob = _run_object_path(profile, MODELS[model_name](), traces)
    batch_reports, bb = _run_batch_path(profile, MODELS[model_name](), traces)

    assert len(batch_reports) == 7
    for t, rep in enumerate(batch_reports):
        for u in range(5):
            got, want = rep.event(u), object_report.events[u][t]
            for f in EVENT_FIELDS:
                assert getattr(got, f) == getattr(want, f), (t, u, f)
            assert got.result.min_cut == want.result.min_cut, (t, u)
            assert np.array_equal(got.result.local_mask, want.result.local_mask)
            assert got.env == want.env
    assert bb.tenant("app").cache.stats == ob.tenant("app").cache.stats


def test_batch_tick_matches_object_sessions_on_jax_backend():
    """Same parity on the f32 jax backend for the placements and every
    f64 host-priced number.  (The installed cut value of a solved
    session is the solver's f32 output, which the two paths compute from
    differently-rounded f32 weights — same caveat as ``solve_envs`` —
    so it alone is compared within f32 resolution.)"""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    traces = user_traces(4, 6, seed=3)
    object_report, _ = _run_object_path(
        profile, ResponseTimeModel(), traces, backend="jax"
    )
    batch_reports, _ = _run_batch_path(
        profile, ResponseTimeModel(), traces, backend="jax"
    )
    for t, rep in enumerate(batch_reports):
        for u in range(4):
            got, want = rep.event(u), object_report.events[u][t]
            for f in EVENT_FIELDS:
                assert getattr(got, f) == getattr(want, f), (t, u, f)
            assert np.array_equal(got.result.local_mask, want.result.local_mask)
            assert got.result.min_cut == pytest.approx(
                want.result.min_cut, rel=1e-5
            )


def test_fresh_sessions_partition_on_first_observation():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["tree"]())
    batch = SessionBatch.create(4, profile.n, threshold=0.15, min_interval=2)
    batch.activate(np.arange(3))  # slot 3 stays idle
    cache = PlacementCache(EnvQuantizer())
    envs = EnvArrays.from_envs([Environment.symmetric(2.0, 3.0)] * 4)
    rep = tick_sessions(
        batch, envs, profile=profile, model=ResponseTimeModel(),
        cache=cache, backend="reference",
    )
    assert rep.repartitioned.tolist() == [True, True, True, False]
    assert rep.solved == 1 and rep.coalesced == 2  # one bin, one solve
    assert not rep.active[3] and batch.steps[3] == 0


# ----------------------------------------------------------------------
# Traffic: Poisson arrivals + geometric churn, deterministic under seed
# ----------------------------------------------------------------------


def test_traffic_generator_replays_bit_identically():
    a = TrafficGenerator(64, seed=9, arrival_rate=3.0, churn=0.1)
    b = TrafficGenerator(64, seed=9, arrival_rate=3.0, churn=0.1)
    for _ in range(10):
        ta, tb = a.step(), b.step()
        assert np.array_equal(ta.active, tb.active)
        assert np.array_equal(ta.arrived, tb.arrived)
        assert np.array_equal(ta.departed, tb.departed)
        for fa, fb in zip(ta.envs, tb.envs):
            assert np.array_equal(fa, fb)


def test_churning_batch_workload_is_deterministic_under_fixed_seed():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["loop"]())

    def drive():
        broker = _broker()
        broker.register("app", profile, ResponseTimeModel())
        group = broker.register_batch("app", 48, threshold=0.15, min_interval=2)
        reports = run_batch_workload(
            broker, group, steps=10, seed=5, churn=0.08, arrival_rate=2.0
        )
        return reports, broker.tenant("app").cache.stats

    r1, s1 = drive()
    r2, s2 = drive()
    assert s1 == s2
    assert [int(r.active.sum()) for r in r1] == [int(r.active.sum()) for r in r2]
    for a, b in zip(r1, r2):
        assert np.array_equal(a.placements, b.placements)
        assert np.array_equal(a.partial_cost, b.partial_cost)
        assert np.array_equal(a.min_cut, b.min_cut, equal_nan=True)
        assert np.array_equal(a.repartitioned, b.repartitioned)
    # churn actually happened: some sessions departed and slots turned over
    assert any(r.active.sum() != r1[0].active.sum() for r in r1)


def test_departed_sessions_are_not_observed_and_slots_recycle():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["mesh"]())
    batch = SessionBatch.create(2, profile.n, min_interval=1)
    cache = PlacementCache(EnvQuantizer())
    envs = EnvArrays.from_envs([Environment.symmetric(2.0, 3.0)] * 2)
    batch.activate([0, 1])
    tick_sessions(batch, envs, profile=profile, model=ResponseTimeModel(),
                  cache=cache, backend="reference")
    steps_before = batch.steps.copy()
    batch.deactivate([1])
    rep = tick_sessions(batch, envs, profile=profile, model=ResponseTimeModel(),
                        cache=cache, backend="reference")
    assert batch.steps[1] == steps_before[1]  # clock frozen while departed
    assert not rep.repartitioned[1]
    batch.activate([1])  # slot turns over: fresh session, due immediately
    rep2 = tick_sessions(batch, envs, profile=profile, model=ResponseTimeModel(),
                         cache=cache, backend="reference")
    assert rep2.repartitioned[1] and rep2.steps[1] == 1


# ----------------------------------------------------------------------
# Vectorized cache API: get_many/put_many ≡ scalar loop
# ----------------------------------------------------------------------


def test_get_many_put_many_match_scalar_loop_exactly():
    """Batch probe/insert must leave hit/miss counters, stored masks and
    LRU recency identical to the equivalent scalar get/put loop."""
    rng = np.random.default_rng(0)
    envs = [
        Environment.symmetric(float(b), float(s))
        for b, s in zip(
            np.geomspace(0.3, 9.0, 12), 1.5 + rng.random(12) * 3.0
        )
    ]
    masks = rng.random((12, 7)) < 0.5

    scalar = PlacementCache(EnvQuantizer(), capacity=8)
    batch = PlacementCache(EnvQuantizer(), capacity=8)
    for env, mask in zip(envs, masks):
        scalar.put(env, mask)
    batch.put_many(EnvArrays.from_envs(envs), masks)
    assert scalar.stats == batch.stats
    assert list(scalar._entries) == list(batch._entries)
    for key in scalar._entries:
        assert np.array_equal(scalar._entries[key], batch._entries[key])

    probe = envs[::2] + [Environment.symmetric(123.0, 9.0)]  # mix hit/miss
    scalar_out = [scalar.get(env, expected_n=7) for env in probe]
    batch_out = batch.get_many(EnvArrays.from_envs(probe), expected_n=7)
    assert scalar.stats == batch.stats
    assert len(scalar_out) == len(batch_out)
    for a, b in zip(scalar_out, batch_out):
        assert (a is None) == (b is None)
        if a is not None:
            assert np.array_equal(a, b)
    assert list(scalar._entries) == list(batch._entries)  # same LRU order


def test_keys_batch_matches_scalar_key():
    q = EnvQuantizer()
    envs = [
        Environment(2.0, 1.7, 3.0),
        Environment(0.31, 0.29, 1.5, p_compute=1.1, p_idle=0.2, p_transfer=1.9),
        Environment.symmetric(8.0, 3.0),
    ]
    cache = PlacementCache(q)
    batch_keys = cache.keys_batch(EnvArrays.from_envs(envs))
    assert batch_keys == [cache.key(e) for e in envs]


# ----------------------------------------------------------------------
# Load-adaptive WFQ weights
# ----------------------------------------------------------------------


def test_adaptive_weights_track_inverse_recent_latency():
    """weight = base × mean-EWMA / own-EWMA: a tenant whose ticks keep
    consuming the solver (high service latency) is damped, a light one
    boosted; static-weight tenants are untouched."""
    s = WeightedFairScheduler()
    s.ensure_tenant("heavy", weight=1.0)
    s.ensure_tenant("light", weight=1.0)
    s.ensure_tenant("static", weight=2.0)
    s.set_adaptive("heavy", alpha=0.5, floor=0.25, ceiling=4.0)
    s.set_adaptive("light", alpha=0.5, floor=0.25, ceiling=4.0)
    for _ in range(6):
        s.observe_latency("heavy", 0.9)
        s.observe_latency("light", 0.1)
    assert s.weight("heavy") < 1.0 < s.weight("light")
    assert s.weight("light") <= 4.0 and s.weight("heavy") >= 0.25
    assert s.weight("static") == 2.0


def test_adaptive_weight_values_and_clamps():
    s = WeightedFairScheduler()
    s.ensure_tenant("heavy", weight=1.0)
    s.ensure_tenant("light", weight=1.0)
    s.set_adaptive("heavy", alpha=1.0, floor=0.5, ceiling=2.0)
    s.set_adaptive("light", alpha=1.0, floor=0.5, ceiling=2.0)
    s.observe_latency("heavy", 1.0)
    s.observe_latency("light", 0.01)
    # mean = 0.505: heavy gets 0.505/1.0, light 0.505/0.01 clamped at 2×
    assert s.weight("heavy") == pytest.approx(0.505, rel=1e-9)
    assert s.weight("light") == 2.0  # clamped at base × ceiling


def test_broker_feeds_group_latency_into_adaptive_weights(monkeypatch):
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    broker = _broker()
    broker.register("a", profile, ResponseTimeModel(), adaptive_weight=True)
    broker.register("b", profile, ResponseTimeModel(), adaptive_weight=True)
    ga = broker.register_batch("a", 8, min_interval=1)
    gb = broker.register_batch("b", 8, min_interval=1)
    seen = []
    monkeypatch.setattr(
        broker._scheduler,
        "observe_latency",
        lambda name, seconds: seen.append((name, float(seconds))),
    )
    envs = EnvArrays.from_envs([Environment.symmetric(2.0, 3.0)] * 8)
    ga.observe(envs, arrived=np.arange(8))
    gb.observe(envs, arrived=np.arange(8))
    broker.tick()
    assert [name for name, _ in seen] == ["a", "b"]  # every group reported
    assert all(lat >= 0.0 for _, lat in seen)


# ----------------------------------------------------------------------
# Device-resident pricing telemetry
# ----------------------------------------------------------------------


def test_device_price_summary_matches_host_report_within_f32():
    profile = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    model = ResponseTimeModel()
    rng = np.random.default_rng(4)
    envs = [
        Environment.symmetric(float(b), 3.0) for b in np.geomspace(0.3, 9.0, 10)
    ]
    masks = rng.random((10, profile.n)) < 0.5
    masks[:, ~profile.offloadable] = True
    active = np.ones(10, dtype=bool)
    active[7:] = False

    out = device_price_summary(profile, model, envs, masks, active=active)
    host = price_trace(profile, model, list(zip(envs, masks)))
    act = active
    assert out["partial_mean"] == pytest.approx(
        float(np.asarray(host.partial_cost)[act].mean()), rel=1e-5
    )
    assert out["gain_min"] == pytest.approx(
        float(np.asarray(host.gain)[act].min()), rel=1e-5
    )
    assert out["partial_max"] == pytest.approx(
        float(np.asarray(host.partial_cost)[act].max()), rel=1e-5
    )
    assert out["no_offload_mean"] == pytest.approx(
        float(np.asarray(host.no_offload_cost)[act].mean()), rel=1e-5
    )


def test_batch_group_carries_device_summary_when_enabled():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    broker = _broker(backend="jax")
    broker.register("app", profile, ResponseTimeModel())
    group = broker.register_batch("app", 6, device_telemetry=True)
    group.observe(
        EnvArrays.from_envs([Environment.symmetric(2.0, 3.0)] * 6),
        arrived=np.arange(6),
    )
    broker.tick()
    (rep,) = group.drain()
    assert rep.device_summary is not None
    assert set(rep.device_summary) >= {"partial_mean", "gain_mean"}
    assert rep.device_summary["partial_mean"] == pytest.approx(
        float(rep.partial_cost[rep.active].mean()), rel=1e-5
    )


# ----------------------------------------------------------------------
# Atomicity + pytree plumbing
# ----------------------------------------------------------------------


def test_failed_solve_restores_state_and_tick_retries_identically(monkeypatch):
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["tree"]())
    model = ResponseTimeModel()
    envs = EnvArrays.from_envs(
        [Environment.symmetric(float(b), 3.0) for b in np.geomspace(0.5, 6.0, 5)]
    )

    def drive(fail_first):
        batch = SessionBatch.create(5, profile.n, min_interval=1)
        batch.activate(np.arange(5))
        cache = PlacementCache(EnvQuantizer())
        calls = {"n": 0}
        real = session_batch_mod.solve_envs

        def flaky(*a, **kw):
            calls["n"] += 1
            if fail_first and calls["n"] == 1:
                raise RuntimeError("transient device error")
            return real(*a, **kw)

        monkeypatch.setattr(session_batch_mod, "solve_envs", flaky)
        if fail_first:
            with pytest.raises(RuntimeError, match="transient"):
                tick_sessions(batch, envs, profile=profile, model=model,
                              cache=cache, backend="reference")
            # full rollback: no counters, no clocks, no anchors
            assert cache.stats.lookups == 0
            assert batch.steps.sum() == 0 and not batch.has_partition.any()
        rep = tick_sessions(batch, envs, profile=profile, model=model,
                            cache=cache, backend="reference")
        monkeypatch.setattr(session_batch_mod, "solve_envs", real)
        return rep, cache.stats

    clean, clean_stats = drive(fail_first=False)
    retried, retried_stats = drive(fail_first=True)
    assert clean_stats == retried_stats  # no double counting on retry
    assert np.array_equal(clean.placements, retried.placements)
    assert np.array_equal(clean.partial_cost, retried.partial_cost)
    assert np.array_equal(clean.steps, retried.steps)


def test_session_batch_is_a_registered_pytree():
    import jax

    batch = SessionBatch.create(6, 9, threshold=0.2, min_interval=3)
    batch.activate([0, 2])
    leaves, treedef = jax.tree_util.tree_flatten(batch)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.n == 9
    assert rebuilt.threshold == 0.2 and rebuilt.min_interval == 3
    assert np.array_equal(rebuilt.active, batch.active)
    # identity tree_map round-trips every array leaf
    mapped = jax.tree_util.tree_map(lambda x: x, batch)
    assert np.array_equal(mapped.placements, batch.placements)


def test_tick_report_telemetry_counts_batched_sessions():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    broker = _broker()
    broker.register("app", profile, ResponseTimeModel())
    group = broker.register_batch("app", 10, min_interval=1)
    group.observe(
        EnvArrays.from_envs([Environment.symmetric(2.0, 3.0)] * 10),
        arrived=np.arange(7),
    )
    report = broker.tick()
    assert report.batch_groups == 1
    assert report.batch_sessions == 7
    assert report.batch_solved == 1          # one shared bin
    assert report.batch_hits == 6            # the coalesced followers
    assert broker.telemetry.batch_sessions == 7
