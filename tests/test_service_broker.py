"""Offload broker service layer: coalescing ticks, broker↔serial parity,
cache persistence / warm restarts, elastic wiring, telemetry.

Everything here is deterministic (fake clocks, seeded traces) and runs
in tier-1 under the ``service`` marker.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (
    AdaptiveController,
    AppProfile,
    Environment,
    EnvQuantizer,
    PlacementCache,
    ResponseTimeModel,
    face_recognition_graph,
    mcop_reference,
    profile_fingerprint,
    random_wcg,
)
from repro.core.placement_cache import SNAPSHOT_VERSION
from repro.service import (
    BrokerSession,
    OffloadBroker,
    run_workload,
    user_traces,
)
from repro.service import broker as broker_mod

pytestmark = pytest.mark.service


def _face_profile() -> AppProfile:
    return AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )


def _profile(n: int, seed: int) -> AppProfile:
    return AppProfile.from_wcg_times(random_wcg(n, rng=np.random.default_rng(seed)))


def _broker(**kw) -> OffloadBroker:
    kw.setdefault("backend", "reference")
    kw.setdefault("clock", lambda: 0.0)
    return OffloadBroker(**kw)


# ----------------------------------------------------------------------
# Tick mechanics: coalescing and one dispatch per bucket
# ----------------------------------------------------------------------


def test_tick_issues_at_most_one_mcop_batch_call_per_bucket(monkeypatch):
    """R requests across K bins and two shape buckets → exactly one
    mcop_batch call per bucket, every future resolved correctly."""
    calls = []
    real = broker_mod.mcop_batch

    def counting(graphs, **kw):
        calls.append((len(graphs), kw.get("buckets")))
        return real(graphs, **kw)

    monkeypatch.setattr(broker_mod, "mcop_batch", counting)

    broker = _broker()
    small = _profile(8, seed=0)    # bucket 16
    large = _profile(40, seed=1)   # bucket 64
    broker.register("small", small, ResponseTimeModel())
    broker.register("large", large, ResponseTimeModel())

    futures = []
    envs = [Environment.symmetric(bw, 3.0) for bw in (8.0, 1.2, 0.3)]
    for env in envs:  # 3 distinct bins per tenant, 2 requests per bin
        for _ in range(2):
            futures.append(("small", env, broker.submit("small", env)))
            futures.append(("large", env, broker.submit("large", env)))

    report = broker.tick()
    assert report.requests == 12
    assert report.solved == 6          # one representative per (tenant, bin)
    assert report.coalesced == 6
    assert report.dispatches == 2      # one per bucket: 16 and 64
    assert report.buckets == (16, 64)
    assert len(calls) == 2
    assert sorted(n for n, _ in calls) == [3, 3]

    profs = {"small": small, "large": large}
    for name, env, fut in futures:
        assert fut.done
        g = ResponseTimeModel().build(profs[name], env)
        ref = mcop_reference(g)
        got = fut.result.result
        # same optimum (broker clamps, reference cut equals it here)
        assert got.min_cut == pytest.approx(
            min(ref.min_cut, g.total_cost(np.ones(g.n, bool))), rel=1e-9
        )


def test_second_tick_serves_same_bins_from_cache(monkeypatch):
    calls = []
    real = broker_mod.mcop_batch
    monkeypatch.setattr(
        broker_mod,
        "mcop_batch",
        lambda graphs, **kw: calls.append(len(graphs)) or real(graphs, **kw),
    )
    broker = _broker()
    broker.register("app", _face_profile(), ResponseTimeModel())
    env = Environment.symmetric(5.0, 3.0)
    f1 = broker.submit("app", env)
    broker.tick()
    # same quantizer bin, slightly different measurement
    f2 = broker.submit("app", Environment.symmetric(5.05, 3.0))
    r = broker.tick()
    assert r.dispatches == 0 and r.cache_hits == 1 and len(calls) == 1
    assert f2.result.cache_hit and not f2.result.coalesced
    assert (f2.result.result.local_mask == f1.result.result.local_mask).all()


def test_failed_dispatch_requeues_unresolved_requests(monkeypatch):
    """A solve exception must not strand waiters: unresolved requests go
    back on the queue and the next tick retries (already-served cache
    hits stay resolved)."""
    broker = _broker()
    broker.register("app", _face_profile(), ResponseTimeModel())
    warm_env = Environment.symmetric(8.0, 3.0)
    broker.submit("app", warm_env)
    broker.tick()  # populate the cache for the warm bin

    real = broker_mod.mcop_batch
    boom = {"armed": True}

    def flaky(graphs, **kw):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("transient device error")
        return real(graphs, **kw)

    monkeypatch.setattr(broker_mod, "mcop_batch", flaky)
    hit = broker.submit("app", warm_env)              # resolvable from cache
    miss = broker.submit("app", Environment.symmetric(0.3, 3.0))
    with pytest.raises(RuntimeError, match="transient"):
        broker.tick()
    assert hit.done and not miss.done
    assert broker.pending == 1                        # only the miss requeued
    broker.tick()                                     # retry succeeds
    assert miss.done and broker.pending == 0
    # the retried request's counters are not double-counted: one miss for
    # each cold bin, one hit for the warm-bin re-request
    st = broker.tenant("app").cache.stats
    assert (st.hits, st.misses) == (1, 2)


def test_coalescing_respects_graph_size_within_a_bin():
    """A raw-graph tenant may mix graph sizes inside one env bin; a
    follower must never receive a wrong-length mask."""
    broker = _broker()
    broker.register("raw")
    env = Environment.symmetric(4.0, 3.0)
    g_small = random_wcg(6, rng=np.random.default_rng(0))
    g_large = random_wcg(13, rng=np.random.default_rng(1))
    f_small = broker.submit_graph("raw", g_small, env)
    f_large = broker.submit_graph("raw", g_large, env)
    report = broker.tick()
    assert report.solved == 2 and report.coalesced == 0
    assert f_small.result.result.local_mask.shape == (6,)
    assert f_large.result.result.local_mask.shape == (13,)


def test_observe_recovers_after_solver_failure():
    """A solver exception inside observe() must leave the controller able
    to retry, not permanently convinced it already repartitioned."""
    profile = _face_profile()
    ctl = AdaptiveController(
        profile, ResponseTimeModel(), threshold=0.15, min_interval=2,
        backend="definitely-not-a-backend",
    )
    env = Environment.symmetric(8.0, 3.0)
    with pytest.raises(ValueError):
        ctl.observe(env)
    ctl.backend = "reference"
    event = ctl.observe(env)
    assert event.repartitioned and ctl.placement is event.result


def test_broker_rejects_unknown_backend_eagerly():
    with pytest.raises(ValueError):
        OffloadBroker(backend="cuda")


def test_future_and_registration_error_paths():
    broker = _broker()
    broker.register("app", _face_profile(), ResponseTimeModel())
    with pytest.raises(ValueError):
        broker.register("app", _face_profile(), ResponseTimeModel())
    with pytest.raises(ValueError):
        broker.register("half", _face_profile())  # cost_model missing
    broker.register("raw")  # graph-only tenant
    with pytest.raises(ValueError):
        broker.submit("raw", Environment.symmetric(1.0, 2.0))
    fut = broker.submit("app", Environment.symmetric(1.0, 2.0))
    assert not fut.done
    with pytest.raises(RuntimeError):
        fut.result
    assert broker.pending == 1
    broker.tick()
    assert broker.pending == 0 and fut.done


def test_tick_latency_uses_injected_clock():
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    broker = OffloadBroker(backend="reference", clock=clock)
    broker.register("app", _face_profile(), ResponseTimeModel())
    broker.submit("app", Environment.symmetric(4.0, 3.0))
    report = broker.tick()
    assert report.latency_s == pytest.approx(0.5)
    assert broker.telemetry.mean_tick_latency_s == pytest.approx(0.5)


# ----------------------------------------------------------------------
# Broker ↔ serial parity (satellite: bit-identical placements and costs)
# ----------------------------------------------------------------------


def _serial_events(profile, traces, *, threshold, min_interval, n_users, steps):
    """Reference semantics: per-controller observe() loops over a shared
    cache, users visited in the same order the broker queue sees them."""
    cache = PlacementCache()
    ctls = [
        AdaptiveController(
            profile,
            ResponseTimeModel(),
            threshold=threshold,
            min_interval=min_interval,
            backend="reference",
            cache=cache,
        )
        for _ in range(n_users)
    ]
    for t in range(steps):
        for u, ctl in enumerate(ctls):
            ctl.observe(traces[u][t])
    return [ctl.history for ctl in ctls], cache


def _assert_event_parity(serial_events, broker_events):
    for ev_s, ev_b in zip(serial_events, broker_events):
        assert len(ev_s) == len(ev_b)
        for a, b in zip(ev_s, ev_b):
            assert a.step == b.step
            assert a.repartitioned == b.repartitioned
            assert a.cache_hit == b.cache_hit
            assert (a.result.local_mask == b.result.local_mask).all()
            assert b.partial_cost == pytest.approx(a.partial_cost, rel=1e-12)
            assert b.gain == pytest.approx(a.gain, rel=1e-9, abs=1e-12)


def test_broker_matches_serial_observe_loops():
    """N users through the broker ≡ N per-controller observe() loops."""
    profile = _face_profile()
    n_users, steps = 6, 10
    broker = _broker()
    broker.register("app", profile, ResponseTimeModel())
    report = run_workload(
        broker, "app", n_users=n_users, steps=steps,
        threshold=0.15, min_interval=2, seed=11,
    )
    serial, cache = _serial_events(
        profile, report.traces,
        threshold=0.15, min_interval=2, n_users=n_users, steps=steps,
    )
    _assert_event_parity(serial, report.events)
    tenant_cache = broker.tenant("app").cache
    assert (tenant_cache.stats.hits, tenant_cache.stats.misses) == (
        cache.stats.hits, cache.stats.misses,
    )
    # coalescing really happened (many users share few regime bins)
    assert broker.telemetry.solved < report.n_repartitions


def test_broker_parity_cooldown_and_drift_edge_cases():
    """Cooldown suppressing a due repartition, sub-threshold drift, and a
    drift landing exactly when the cooldown expires."""
    profile = _face_profile()
    base = [
        (8.0, 3.0),   # step 1: first observe always repartitions
        (1.0, 3.0),   # step 2: huge drift but min_interval=3 → suppressed
        (1.0, 3.0),   # step 3: still cooling down
        (1.0, 3.0),   # step 4: cooldown expired + drifted → repartition
        (1.02, 3.0),  # step 5: 2% drift < threshold → no repartition
        (8.0, 3.0),   # step 6: cooldown blocks again
        (8.0, 3.0),   # step 7: repartition, back to the cached wifi bin
    ]
    traces = [[Environment.symmetric(b, f) for b, f in base] for _ in range(3)]
    broker = _broker()
    broker.register("app", profile, ResponseTimeModel())
    report = run_workload(
        broker, "app", n_users=3, steps=len(base),
        threshold=0.15, min_interval=3, traces=traces,
    )
    serial, _ = _serial_events(
        profile, traces, threshold=0.15, min_interval=3,
        n_users=3, steps=len(base),
    )
    _assert_event_parity(serial, report.events)
    flags = [e.repartitioned for e in report.events[0]]
    assert flags == [True, False, False, True, False, False, True]
    # user 0 solves each bin once; users 1–2 ride entirely on coalescing
    assert all(e.cache_hit for evs in report.events[1:] for e in evs
               if e.repartitioned)


def test_sessions_can_queue_multiple_steps_before_a_tick():
    """drain() commits in observation order and stops at unresolved
    futures; a late tick releases the backlog with serial semantics."""
    profile = _face_profile()
    broker = _broker()
    broker.register("app", profile, ResponseTimeModel())
    session = BrokerSession(broker, "app", threshold=0.15, min_interval=1)
    envs = [Environment.symmetric(b, 3.0) for b in (8.0, 8.1, 1.0)]
    for env in envs:
        session.observe(env)
    assert session.drain() == [] and session.pending == 3
    broker.tick()
    events = session.drain()
    assert [e.repartitioned for e in events] == [True, False, True]
    # deferred commits carry the observation's own step, not the latest
    assert [e.step for e in events] == [1, 2, 3]
    assert session.pending == 0

    serial = AdaptiveController(
        profile, ResponseTimeModel(), threshold=0.15, min_interval=1,
        backend="reference", cache=PlacementCache(),
    )
    for env in envs:
        serial.observe(env)
    _assert_event_parity([serial.history], [events])


# ----------------------------------------------------------------------
# Cache persistence: snapshot → restart → warm start
# ----------------------------------------------------------------------


def test_snapshot_roundtrip_preserves_hit_behavior(tmp_path):
    cache = PlacementCache()
    envs = [Environment.symmetric(b, 3.0) for b in (8.0, 1.2, 0.3)]
    masks = [np.array([True, False, i % 2 == 0]) for i in range(3)]
    for env, mask in zip(envs, masks):
        cache.put(env, mask)
    path = tmp_path / "cache.json"
    cache.save(path, fingerprint="abc")

    warm = PlacementCache.from_snapshot(path, fingerprint="abc")
    assert len(warm) == 3
    for env, mask in zip(envs, masks):
        got = warm.get(env, expected_n=3)
        assert got is not None and (got == mask).all()
    assert warm.stats.hits == 3 and warm.stats.misses == 0


def test_snapshot_guards_fall_back_to_cold_cache(tmp_path):
    cache = PlacementCache()
    cache.put(Environment.symmetric(5.0, 3.0), np.array([True, False]))
    doc = cache.snapshot(fingerprint="fp-a")

    # fingerprint mismatch → ignored, no raise
    assert PlacementCache().load(doc, fingerprint="fp-b") == 0
    # unknown schema version → ignored
    assert PlacementCache().load({**doc, "version": SNAPSHOT_VERSION + 1}) == 0
    # quantizer step mismatch → bins not comparable → ignored
    other = PlacementCache(EnvQuantizer(rel_step=0.25))
    assert other.load(doc) == 0
    # corrupted file → cold cache, no raise
    bad = tmp_path / "corrupt.json"
    bad.write_text('{"version": 1, "entries": [truncated')
    assert PlacementCache().load(bad) == 0
    # missing file → cold cache
    assert PlacementCache().load(tmp_path / "nope.json") == 0
    # non-dict document → cold cache
    assert PlacementCache().load([1, 2, 3]) == 0
    # caller without a fingerprint requirement can still load
    assert PlacementCache().load(doc) == 1


def test_snapshot_load_skips_malformed_entries_and_evicts_to_capacity():
    cache = PlacementCache()
    for i, bw in enumerate((1.0, 2.0, 4.0, 8.0)):
        cache.put(Environment.symmetric(bw, 3.0), np.array([True, i % 2 == 0]))
    doc = cache.snapshot()
    doc["entries"].insert(0, {"key": ["x"], "mask": [1]})      # bad key
    doc["entries"].insert(0, {"key": [1, 2], "mask": []})      # empty mask
    doc["entries"].insert(0, {"mask": [1]})                    # missing key

    small = PlacementCache(capacity=2)
    assert small.load(doc) == 4          # good entries loaded (then evicted)
    assert len(small) == 2               # evicted down to capacity...
    # ...keeping the newest entries (last written wins LRU)
    assert small.get(Environment.symmetric(8.0, 3.0)) is not None
    assert small.get(Environment.symmetric(1.0, 3.0)) is None

    # wrong-length entries are skipped when the caller pins a profile size
    sized = PlacementCache()
    assert sized.load(doc, expected_n=3) == 0


def test_profile_fingerprint_distinguishes_profiles():
    a, b = _profile(8, seed=0), _profile(8, seed=1)
    assert profile_fingerprint(a) == profile_fingerprint(_profile(8, seed=0))
    assert profile_fingerprint(a) != profile_fingerprint(b)
    g = face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    assert profile_fingerprint(g) == profile_fingerprint(g)
    with pytest.raises(TypeError):
        profile_fingerprint(object())


def test_warm_started_broker_replays_trace_with_zero_dispatches(tmp_path):
    """Acceptance: serving restart + warm cache ⇒ no solver dispatches."""
    profile = _face_profile()
    broker = _broker()
    broker.register("app", profile, ResponseTimeModel())
    report = run_workload(broker, "app", n_users=4, steps=8, seed=5)
    assert broker.telemetry.dispatches > 0

    path = tmp_path / "app.json"
    broker.save_snapshot("app", path)

    warm = _broker()
    warm.register("app", profile, ResponseTimeModel(), warm_start=path)
    replay = run_workload(
        warm, "app", n_users=4, steps=8, traces=report.traces
    )
    assert warm.telemetry.dispatches == 0
    assert warm.telemetry.solved == 0
    assert all(e.cache_hit for evs in replay.events for e in evs
               if e.repartitioned)
    # placements/costs identical to the cold run (cache_hit flags differ
    # by design: the warm run never misses)
    for ev_cold, ev_warm in zip(report.events, replay.events):
        for a, b in zip(ev_cold, ev_warm):
            assert a.repartitioned == b.repartitioned
            assert (a.result.local_mask == b.result.local_mask).all()
            assert b.partial_cost == pytest.approx(a.partial_cost, rel=1e-12)

    # a different profile's snapshot must NOT warm this tenant
    cold = _broker()
    cold.register("app", _profile(profile.n, seed=99), ResponseTimeModel(),
                  warm_start=path)
    assert len(cold.tenant("app").cache) == 0

    # same profile but a different OBJECTIVE must not warm either: the
    # snapshot's masks minimize response time, not energy
    from repro.core import EnergyModel, WeightedModel

    cold2 = _broker()
    cold2.register("app", profile, EnergyModel(), warm_start=path)
    assert len(cold2.tenant("app").cache) == 0
    # parametric models fold their parameters into the guard
    assert WeightedModel(0.3).fingerprint != WeightedModel(0.7).fingerprint


# ----------------------------------------------------------------------
# Elastic events through the broker
# ----------------------------------------------------------------------


def test_elastic_submit_resize_matches_sync_resize(qwen_stages):
    from repro.core.placement import TPUV5E_TIER
    from repro.runtime import ElasticMeshManager

    tl = dataclasses.replace(TPUV5E_TIER, name="local", chips=128)
    tr = dataclasses.replace(TPUV5E_TIER, name="remote", chips=128)

    sync = ElasticMeshManager(list(qwen_stages), tl, tr)
    ev_sync = sync.resize(step=100, remote_chips=16, reason="failure")

    mgr = ElasticMeshManager(list(qwen_stages), tl, tr)
    broker = _broker()
    broker.register("fleet")   # raw-graph tenant
    pending = mgr.submit_resize(
        broker, "fleet", step=100, remote_chips=16, reason="failure"
    )
    assert not pending.done
    with pytest.raises(RuntimeError):
        pending.resolve()      # tick hasn't run yet
    broker.tick()
    ev = pending.resolve()
    assert (ev.plan.stage_tier == ev_sync.plan.stage_tier).all()
    assert ev.plan.mcop_cost == pytest.approx(ev_sync.plan.mcop_cost, rel=1e-9)
    assert ev.reason == "failure" and mgr.plan is ev.plan
    assert len(mgr.events) == 1

    # a flapping fleet revisits the same (bw, F) bin → served from cache
    p2 = mgr.submit_resize(broker, "fleet", step=200, remote_chips=16,
                           reason="flap")
    r = broker.tick()
    assert r.dispatches == 0 and r.cache_hits == 1
    assert (p2.resolve().plan.stage_tier == ev.plan.stage_tier).all()

    with pytest.raises(RuntimeError):
        mgr.submit_resize(broker, "fleet", step=300, remote_chips=0)
    # a rejected resize must not corrupt the tier state
    assert mgr.tier_remote.chips == 16

    # equal F but a bigger fleet is a DIFFERENT bin: compute times scale
    # with absolute FLOPs while transfer times don't, so the cached mask
    # must not be reused
    p3 = mgr.submit_resize(broker, "fleet", step=400,
                           local_chips=256, remote_chips=32, reason="grow")
    assert mgr.speedup == pytest.approx(16 / 128)  # same F as step 100
    r = broker.tick()
    assert r.cache_hits == 0 and r.solved == 1
    p3.resolve()


def test_overlapping_pending_resizes_resolve_safely(qwen_stages):
    """Out-of-order resolves must record the tiers each plan was solved
    on and never roll manager.plan back to a stale plan."""
    from repro.core.placement import TPUV5E_TIER
    from repro.runtime import ElasticMeshManager

    stages = qwen_stages
    tl = dataclasses.replace(TPUV5E_TIER, name="local", chips=128)
    tr = dataclasses.replace(TPUV5E_TIER, name="remote", chips=128)
    mgr = ElasticMeshManager(stages, tl, tr)
    broker = _broker()
    broker.register("fleet")
    p_old = mgr.submit_resize(broker, "fleet", step=1, remote_chips=16,
                              reason="brownout")
    p_new = mgr.submit_resize(broker, "fleet", step=2, remote_chips=512,
                              reason="scale_up")
    broker.tick()
    ev_new = p_new.resolve()
    ev_old = p_old.resolve()   # resolved late, after a newer plan landed
    assert ev_old.tier_remote.chips == 16      # tiers captured at submit
    assert ev_new.tier_remote.chips == 512
    assert mgr.plan is ev_new.plan             # stale plan did not clobber
    # each pending solved its own fleet state
    sync16 = ElasticMeshManager(stages, tl, tr).resize(step=1, remote_chips=16)
    assert (ev_old.plan.stage_tier == sync16.plan.stage_tier).all()


# ----------------------------------------------------------------------
# Telemetry
# ----------------------------------------------------------------------


def test_telemetry_aggregates_and_summary():
    broker = _broker()
    broker.register("app", _face_profile(), ResponseTimeModel())
    report = run_workload(broker, "app", n_users=5, steps=6, seed=2)
    tel = broker.telemetry
    assert tel.ticks == 6
    assert tel.requests == report.n_repartitions
    assert tel.cache_hits + tel.coalesced + tel.solved == tel.requests
    assert 0.0 <= tel.coalesce_ratio <= 1.0
    assert tel.max_queue_depth <= 5
    assert len(tel.reports) == 6
    s = tel.summary()
    assert s["requests"] == tel.requests
    assert s["dispatches"] == tel.dispatches
    # per-event hits = direct cache hits + same-tick coalesced followers
    assert report.n_cache_hits == tel.cache_hits + tel.coalesced
