"""Cross-process serving plane: parity, SIGKILL warm restart, idempotency.

The headline contract (ISSUE 10): a solver process SIGKILLed mid-tick and
restarted against its placement-cache snapshot + journal tail must
reproduce the same replies BIT-identically (``==``, no tolerances) on the
reference backend, with cache stats never double-counted.  Everything
here drives the real ``examples/serve_broker.py`` entrypoint in real
subprocesses over real unix sockets; reads are timeout-bounded so a
protocol hang is a failure, not a CI deadlock.
"""

import os
import pathlib
import signal
import socket
import struct
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import AppProfile, Environment, ResponseTimeModel, random_wcg
from repro.service import (
    BrokerClient,
    BrokerSession,
    OffloadBroker,
    RetryPolicy,
    unix_address,
)
from repro.service.wire import FrameStream, PROTOCOL_VERSION, env_to_wire
from repro.service.workload import environment_trace

pytestmark = pytest.mark.service

REPO = pathlib.Path(__file__).resolve().parent.parent
SERVER = REPO / "examples" / "serve_broker.py"
TIMEOUT = 30.0
NODES, SEED = 12, 0


def _profile() -> AppProfile:
    # must mirror examples/serve_broker.py demo_tenant: both processes
    # build the tenant independently from the same seed
    return AppProfile.from_wcg_times(
        random_wcg(NODES, rng=np.random.default_rng(SEED))
    )


def _start_server(tmp: pathlib.Path, *, kill_at_tick=None,
                  snapshot_every=7) -> subprocess.Popen:
    """Launch the solver process and block until its READY barrier."""
    cmd = [
        sys.executable, str(SERVER),
        "--socket", str(tmp / "solver.sock"),
        "--journal", str(tmp / "journal.jsonl"),
        "--snapshot-dir", str(tmp / "snaps"),
        "--snapshot-every", str(snapshot_every),
        "--nodes", str(NODES), "--seed", str(SEED),
    ]
    if kill_at_tick is not None:
        cmd += ["--kill-at-tick", str(kill_at_tick)]
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True, env=env)
    deadline = time.monotonic() + TIMEOUT
    for line in proc.stdout:
        if line.startswith("READY"):
            return proc
        if time.monotonic() > deadline:
            break
    proc.kill()
    raise RuntimeError("server never became READY")


def _client(tmp: pathlib.Path, name="drv") -> BrokerClient:
    return BrokerClient(
        unix_address(tmp / "solver.sock"),
        tenants={"app": (_profile(), ResponseTimeModel())},
        client=name,
        timeout=TIMEOUT,
        retry=RetryPolicy(max_retries=2, base_backoff_s=0.01,
                          max_backoff_s=0.05),
    )


def _sig(reply) -> tuple:
    """Bit-exact signature of a BrokerReply — ``==`` means identical."""
    res = reply.result
    return (
        None
        if res is None
        else (
            struct.pack("<d", res.min_cut),
            np.asarray(res.local_mask, bool).tobytes(),
        ),
        reply.cache_hit,
        reply.coalesced,
        reply.tick,
        reply.rejected,
        reply.degraded,
        reply.timed_out,
    )


def _drive(client, envs, sigs, start=0, until=None):
    """submit+tick loop; ``sigs[i]`` gets request i's reply signature."""
    for i, env in enumerate(envs[start:until], start):
        fut = client.submit("app", env)
        client.tick()
        assert fut.done, f"request {i} unresolved after its tick"
        sigs[i] = _sig(fut.result)


TRACE = environment_trace(24, seed=11)
KILL_I = 15            # the submit whose tick the solver dies inside
KILL_TICK = KILL_I + 1


def test_sigkill_warm_restart_replies_bit_identical(tmp_path):
    # --- run A: uninterrupted --------------------------------------------
    dir_a = tmp_path / "a"
    dir_a.mkdir()
    proc = _start_server(dir_a)
    try:
        client = _client(dir_a)
        client.connect()
        uninterrupted: dict[int, tuple] = {}
        _drive(client, TRACE, uninterrupted)
        client.close()
    finally:
        proc.kill()
        proc.wait()

    # --- run B: SIGKILL mid-tick, restart, warm-start, continue ----------
    dir_b = tmp_path / "b"
    dir_b.mkdir()
    proc = _start_server(dir_b, kill_at_tick=KILL_TICK)
    crashed: dict[int, tuple] = {}
    client = _client(dir_b)
    client.connect()
    _drive(client, TRACE, crashed, until=KILL_I)
    # the killing tick: the solver SIGKILLs itself after mutating broker
    # state, before the journal tick append — the torn write
    fut = client.submit("app", TRACE[KILL_I])
    with pytest.raises(ConnectionError):
        client.tick()
    proc.wait(timeout=TIMEOUT)
    assert proc.returncode == -signal.SIGKILL

    proc = _start_server(dir_b)  # warm restart against snapshot + journal
    try:
        # the retried tick: reconnect resubmits the unresolved window and
        # the exactly-once logic re-runs (or skips) the interrupted tick
        client.tick()
        assert fut.done, "unresolved future survived the warm restart"
        crashed[KILL_I] = _sig(fut.result)
        assert client.resubmitted >= 1  # the window really was replayed
        _drive(client, TRACE, crashed, start=KILL_I + 1)

        # THE acceptance criterion: every reply — pre-crash, the
        # interrupted tick's, and the continuation — bit-identical
        assert crashed == uninterrupted

        # --- cache stats never double-counted on resubmission ------------
        tel0 = client.telemetry()["caches"]["app"]
        raw = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        raw.settimeout(TIMEOUT)
        raw.connect(str(dir_b / "solver.sock"))
        stream = FrameStream(raw)
        stream.send({"type": "hello", "version": PROTOCOL_VERSION,
                     "encoding": "json", "client": "dup"})
        assert stream.recv(TIMEOUT)["type"] == "hello_ok"
        # resubmit the interrupted request's id: served from the reply
        # log — reply first, then a replayed ack
        stream.send({"type": "submit", "id": f"drv-{KILL_I + 1}",
                     "tenant": "app", "env": env_to_wire(TRACE[KILL_I]),
                     "lane": "user", "deadline": None})
        reply = stream.recv(TIMEOUT)
        assert reply["type"] == "reply" and reply["tick"] == KILL_TICK
        ack = stream.recv(TIMEOUT)
        assert ack["type"] == "submit_ok" and ack["replayed"] is True
        stream.send({"type": "bye"})
        stream.close()
        tel1 = client.telemetry()["caches"]["app"]
        assert tel1 == tel0, "resubmission touched cache stats"
        client.close()
    finally:
        proc.kill()
        proc.wait()


def test_cross_process_session_parity(tmp_path):
    """BrokerSession over a real subprocess solver == the same session
    against an in-process broker, event for event, bit for bit."""
    trace = environment_trace(20, seed=7)

    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("app", _profile(), ResponseTimeModel())
    local = BrokerSession(broker, "app")
    local_events = []
    for env in trace:
        local.observe(env)
        broker.tick()
        local_events.extend(local.drain())

    proc = _start_server(tmp_path)
    try:
        client = _client(tmp_path, name="sess")
        client.connect()
        remote = BrokerSession(client, "app")  # the unmodified class
        remote_events = []
        for env in trace:
            remote.observe(env)
            client.tick()
            remote_events.extend(remote.drain())
        client.close()
    finally:
        proc.kill()
        proc.wait()

    assert len(remote_events) == len(local_events) == len(trace)
    for r, l in zip(remote_events, local_events):
        assert r.env == l.env
        assert r.partial_cost == l.partial_cost          # ==, no tolerance
        assert r.gain == l.gain
        assert r.repartitioned == l.repartitioned
        assert r.cache_hit == l.cache_hit
        assert r.result.min_cut == l.result.min_cut
        assert np.array_equal(r.result.local_mask, l.result.local_mask)


def test_reconnect_against_live_server_is_idempotent(tmp_path):
    """Dropping the connection mid-window and reconnecting to the SAME
    server must not double-submit: the inflight dedup path."""
    proc = _start_server(tmp_path)
    try:
        client = _client(tmp_path, name="flaky")
        client.connect()
        futs = [client.submit("app", Environment.symmetric(bw, 3.0))
                for bw in (8.0, 1.2, 0.3)]
        # simulate a dropped transport (the socket dies, the server and
        # its queue survive)
        client._stream.close()
        client._stream = None
        client.connect()           # resubmits all three; server dedups
        assert client.resubmitted == 3
        client.drain(max_ticks=8)
        assert all(f.done for f in futs)
        tel = client.telemetry()
        assert tel["summary"]["requests"] == 3, (
            "resubmission re-queued an already-queued id"
        )
        client.close()
    finally:
        proc.kill()
        proc.wait()


def test_ipc_serves_llm_stage_profile(tmp_path, qwen_stages):
    """The serving plane is model-agnostic: an LLM stage-graph tenant
    (the shared qwen fixture) placed over the wire matches in-process,
    bit for bit."""
    import threading

    from repro.core.placement import TPUV5E_TIER, build_stage_wcg
    from repro.service import SolverServer

    profile = AppProfile.from_wcg_times(
        build_stage_wcg(qwen_stages, TPUV5E_TIER, TPUV5E_TIER)
    )
    cm = ResponseTimeModel()
    envs = [Environment.symmetric(bw, 2.0) for bw in (4.0, 0.5, 4.0)]

    def llm_broker():
        b = OffloadBroker(backend="reference", clock=lambda: 0.0)
        b.register("llm", profile, cm)
        return b

    local = llm_broker()
    want = []
    for env in envs:
        fut = local.submit("llm", env)
        local.tick()
        want.append(_sig(fut.result))

    server = SolverServer(
        llm_broker(),
        address=unix_address(tmp_path / "llm.sock"),
        journal_path=tmp_path / "llm.jsonl",
        snapshot_dir=tmp_path / "llm_snaps",
    )
    server.bind()
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        client = BrokerClient(
            unix_address(tmp_path / "llm.sock"),
            tenants={"llm": (profile, cm)},
            client="llm-drv", timeout=TIMEOUT,
        )
        client.connect()
        got = []
        for env in envs:
            fut = client.submit("llm", env)
            client.tick()
            got.append(_sig(fut.result))
        client.close()
    finally:
        server.stop()
        thread.join(timeout=TIMEOUT)

    assert got == want
    assert got[2][1] is True                 # the revisit is a cache hit
