"""Fault-tolerant broker: deterministic chaos, retries, degradation.

The PR-7 acceptance suite.  The injector is a pure function of
(seed, site, tick, index), so every chaos scenario here replays
bit-identically; clocks are injected (no real sleeps).  The headline
contracts:

* rate-0 / disabled injection ⇒ the resilient broker's replies, reports
  and telemetry are bit-identical (``==``, no tolerances) to today's
  broker, across the Fig.-2 topologies × three cost models;
* at a 10% fault rate every submitted future still resolves — solved,
  degraded, timed-out or rejected, never an exception out of ``tick()``
  — and cache counters record each served request exactly once;
* a failing (bin, bucket) flush quarantines only its own requests;
* batched sessions served fallbacks converge to the optimal placement
  once the fault storm ends.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    AppProfile,
    EnergyModel,
    Environment,
    NonFiniteWeightError,
    PlacementCache,
    ResponseTimeModel,
    SessionBatch,
    WeightedModel,
    linear_graph,
    loop_graph,
    mesh_graph,
    random_wcg,
    tick_sessions,
    tree_graph,
)
from repro.core.cost_models import EnvArrays, validate_env_finite
from repro.core.graph import WCGBatch
from repro.service import (
    CircuitBreaker,
    FaultInjector,
    InjectedClock,
    OffloadBroker,
    ResiliencePolicy,
    RetryPolicy,
    ScriptedFaultInjector,
    run_workload,
    user_traces,
)

from tests._hyp import given, settings, st

pytestmark = pytest.mark.service

FIG2_TOPOLOGIES = {
    "linear": lambda: linear_graph(9, rng=np.random.default_rng(1)),
    "loop": lambda: loop_graph(8, rng=np.random.default_rng(2)),
    "tree": lambda: tree_graph(10, rng=np.random.default_rng(3)),
    "mesh": lambda: mesh_graph(3, 3, rng=np.random.default_rng(4)),
}

MODELS = {
    "time": ResponseTimeModel,
    "energy": EnergyModel,
    "weighted": lambda: WeightedModel(0.35),
}


def _broker(**kw) -> OffloadBroker:
    kw.setdefault("backend", "reference")
    kw.setdefault("clock", InjectedClock())
    return OffloadBroker(**kw)


def _profile(n: int, seed: int) -> AppProfile:
    return AppProfile.from_wcg_times(random_wcg(n, rng=np.random.default_rng(seed)))


def _env(bw: float = 2.0, speedup: float = 4.0) -> Environment:
    return Environment.symmetric(bw, speedup)


def _policy(**kw) -> ResiliencePolicy:
    kw.setdefault("retry", RetryPolicy(max_retries=2))
    return ResiliencePolicy(**kw)


def _reply_tuple(reply):
    """Hashable bit-exact projection of a BrokerReply for == comparison."""
    r = reply.result
    return (
        None if r is None else (r.min_cut, r.local_mask.tobytes()),
        reply.cache_hit,
        reply.coalesced,
        reply.tick,
        reply.rejected,
        reply.degraded,
        reply.timed_out,
    )


# ----------------------------------------------------------------------
# Injector: determinism, frequency, validation
# ----------------------------------------------------------------------


def test_injector_is_deterministic_across_instances():
    a = FaultInjector(seed=7, rate=0.3)
    b = FaultInjector(seed=7, rate=0.3)
    grid = [
        (site, tick, index)
        for site in ("solve", "pricing", "cache_load", "cache_store")
        for tick in range(20)
        for index in range(5)
    ]
    assert [a.decide(*c) for c in grid] == [b.decide(*c) for c in grid]
    # a different seed produces a different schedule somewhere
    c = FaultInjector(seed=8, rate=0.3)
    assert [a.decide(*x).fires for x in grid] != [
        c.decide(*x).fires for x in grid
    ]


def test_injector_fire_frequency_tracks_rate():
    inj = FaultInjector(seed=0, rate=0.10)
    fired = sum(
        inj.decide("solve", t, i).fires for t in range(200) for i in range(10)
    )
    assert 120 < fired < 280  # 2000 draws @ 10%: generous deterministic band


def test_injector_rate_zero_and_disabled_never_fire():
    assert not FaultInjector(seed=1, rate=0.0).decide("solve", 3).fires
    inj = FaultInjector(seed=1, rate=1.0, enabled=False)
    assert not inj.decide("solve", 3).fires
    inj.enabled = True
    assert inj.decide("solve", 3).fires


def test_injector_per_site_rates_and_validation():
    inj = FaultInjector(seed=0, rate=0.0, rates={"solve": 1.0})
    assert inj.decide("solve", 1).fires
    assert not inj.decide("pricing", 1).fires
    with pytest.raises(ValueError):
        FaultInjector(rate=1.5)
    with pytest.raises(ValueError):
        FaultInjector(rates={"nope": 0.5})
    with pytest.raises(ValueError):
        FaultInjector(kinds=("error", "meteor"))
    with pytest.raises(ValueError):
        inj.decide("nope", 0)


def test_latency_faults_carry_deterministic_delay():
    inj = FaultInjector(seed=3, rate=1.0, kinds=("latency",), latency_s=0.01)
    d = inj.decide("solve", 5, 2)
    assert d.fires and d.kind == "latency"
    assert 0.005 <= d.delay_s <= 0.015
    assert d.delay_s == inj.decide("solve", 5, 2).delay_s


# -- property suite (hypothesis; opt-in via -m property) -----------------


@given(
    seed=st.integers(min_value=0, max_value=2**31),
    tick=st.integers(min_value=0, max_value=10**6),
    index=st.integers(min_value=0, max_value=10**4),
    rate=st.floats(min_value=0.01, max_value=1.0),
)
@settings(max_examples=200, deadline=None)
def test_property_injector_determinism(seed, tick, index, rate):
    """Two injectors with equal seeds agree on every coordinate — the
    schedule is a pure function, not process or call-order state."""
    a = FaultInjector(seed=seed, rate=rate)
    b = FaultInjector(seed=seed, rate=rate)
    for site in ("solve", "pricing", "cache_load", "cache_store"):
        assert a.decide(site, tick, index) == b.decide(site, tick, index)


@given(
    seed=st.integers(min_value=0, max_value=2**31),
    tick=st.integers(min_value=0, max_value=10**6),
    index=st.integers(min_value=0, max_value=10**4),
)
@settings(max_examples=200, deadline=None)
def test_property_sites_draw_independent_streams(seed, tick, index):
    """The underlying uniforms decorrelate across sites: a fault at one
    site never forces (or forbids) one at another coordinate."""
    inj = FaultInjector(seed=seed, rate=0.5)
    us = [
        inj._u(site, tick, index, "fire")
        for site in ("solve", "pricing", "cache_load", "cache_store")
    ]
    assert len(set(us)) == len(us)
    assert inj._u("solve", tick, index, "fire") != inj._u(
        "solve", tick + 1, index, "fire"
    )


# ----------------------------------------------------------------------
# Policies: retry backoff, circuit breaker
# ----------------------------------------------------------------------


def test_retry_policy_backoff_schedule():
    p = RetryPolicy(max_retries=3, base_backoff_s=0.001, multiplier=2.0,
                    max_backoff_s=0.003)
    assert p.attempts == 4
    assert [p.backoff(a) for a in range(4)] == [0.001, 0.002, 0.003, 0.003]
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5)


def test_circuit_breaker_escalates_and_cools_down():
    br = CircuitBreaker(threshold=2, cooldown_ticks=3)
    assert br.backend("pallas", tick=1) == "pallas"
    br.record_failure("pallas", tick=1)
    assert not br.is_open("pallas", 1)
    assert br.record_failure("pallas", tick=1)  # second failure trips
    assert br.trips == 1
    assert br.is_open("pallas", 2)
    assert br.backend("pallas", tick=2) == "jax"
    # open jax too: escalate to the terminal reference backend
    br.record_failure("jax", tick=2)
    br.record_failure("jax", tick=2)
    assert br.backend("pallas", tick=3) == "reference"
    # reference is returned even if it somehow opens — nothing below it
    br.record_failure("reference", tick=3)
    br.record_failure("reference", tick=3)
    assert br.backend("pallas", tick=3) == "reference"
    # cooldown expiry re-admits pallas (opened at tick 1 for 3 ticks)
    assert br.backend("pallas", tick=5) == "pallas"
    # unknown backends pass through untouched
    assert br.backend("custom", tick=2) == "custom"
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)


def test_resilience_policy_validation():
    with pytest.raises(ValueError):
        ResiliencePolicy(degrade="panic")
    with pytest.raises(ValueError):
        ResiliencePolicy(deadline_ticks=0)


# ----------------------------------------------------------------------
# Finite-weight validation (satellite b)
# ----------------------------------------------------------------------


def test_wcgbatch_pack_rejects_nonfinite_naming_row():
    g = linear_graph(5, rng=np.random.default_rng(0))
    batch = WCGBatch.from_wcgs([g, g, g], m=5)
    w = np.array(batch.w_local, copy=True)
    w[1, 2] = np.nan
    offloadable = ~batch.pinned
    with pytest.raises(NonFiniteWeightError, match=r"row\(s\) 1"):
        WCGBatch.pack(w, batch.w_cloud, batch.adj, offloadable, m=5)
    try:
        WCGBatch.pack(w, batch.w_cloud, batch.adj, offloadable, m=5)
    except NonFiniteWeightError as e:
        assert e.rows == (1,)


def test_env_validation_rejects_nonfinite_naming_row():
    envs = EnvArrays.from_envs([_env(), _env(), _env()])
    bad = envs._replace(
        bandwidth_up=np.array([2.0, np.inf, 2.0], dtype=np.float64)
    )
    with pytest.raises(NonFiniteWeightError, match="row 1"):
        validate_env_finite(bad)
    validate_env_finite(envs)  # clean input passes


def test_legacy_broker_raises_on_nonfinite_env_and_requeues():
    broker = _broker()
    broker.register("app", _profile(6, 0), ResponseTimeModel())
    fut = broker.submit("app", Environment.symmetric(float("nan"), 4.0))
    with pytest.raises(NonFiniteWeightError):
        broker.tick()
    assert not fut.done and broker.pending == 1  # re-queued, not stranded


def test_resilient_broker_rejects_nonfinite_env_inline():
    broker = _broker(resilience=_policy())
    broker.register("app", _profile(6, 0), ResponseTimeModel())
    bad = broker.submit("app", Environment.symmetric(float("nan"), 4.0))
    good = broker.submit("app", _env())
    report = broker.tick()
    assert bad.done and bad.result.rejected
    assert good.done and good.result.result is not None
    assert report.rejected == 1


# ----------------------------------------------------------------------
# Retry / degradation through the broker tick
# ----------------------------------------------------------------------


def test_retry_recovers_transient_solve_fault_bit_identically():
    """One injected transient error: the retry solves clean inputs and
    the reply equals the fault-free broker's reply bitwise."""
    clean = _broker()
    clean.register("app", _profile(8, 1), ResponseTimeModel())
    want = clean.submit("app", _env())
    clean.tick()

    for kind in ("error", "corrupt"):
        broker = _broker(
            resilience=_policy(),
            fault_injector=ScriptedFaultInjector({("solve", 1, 0): kind}),
        )
        broker.register("app", _profile(8, 1), ResponseTimeModel())
        fut = broker.submit("app", _env())
        report = broker.tick()
        assert _reply_tuple(fut.result) == _reply_tuple(want.result)
        assert report.retries == 1 and report.faults == 1
        assert report.degraded == 0


def test_exhausted_retries_degrade_to_no_offload_plan():
    faults = ScriptedFaultInjector(
        {("solve", 1, i): "error" for i in range(3)}  # all 3 attempts
    )
    broker = _broker(resilience=_policy(), fault_injector=faults)
    broker.register("app", _profile(8, 1), ResponseTimeModel())
    fut = broker.submit("app", _env())
    report = broker.tick()
    reply = fut.result
    assert reply.degraded and not reply.rejected
    # cold cache: the fallback is the §4.3 no-offload plan — always valid
    assert reply.result.local_mask.all()
    assert report.degraded == 1 and report.retries == 2
    assert report.solved == 0 and report.dispatches == 0
    assert broker.telemetry.degraded_replies == 1
    # the tick never raised and nothing is stranded
    assert broker.pending == 0


def test_degraded_reply_serves_stale_cached_mask():
    faults = ScriptedFaultInjector(
        dict(
            [(("cache_load", 2, 0), "error")]  # force the miss...
            + [(("solve", 2, i), "error") for i in range(3)]  # ...then fail
        )
    )
    broker = _broker(resilience=_policy(), fault_injector=faults)
    broker.register("app", _profile(8, 1), ResponseTimeModel())
    first = broker.submit("app", _env())
    broker.tick()  # tick 1: clean solve warms the cache
    stale = first.result.result.local_mask
    fut = broker.submit("app", _env())
    broker.tick()  # tick 2: load lost, flush exhausted → stale fallback
    reply = fut.result
    assert reply.degraded
    assert np.array_equal(reply.result.local_mask, stale)


def test_quarantine_requeue_mode_retries_next_tick():
    faults = ScriptedFaultInjector(
        {("solve", 1, i): "error" for i in range(3)}
    )
    broker = _broker(
        resilience=_policy(degrade="requeue"), fault_injector=faults
    )
    broker.register("app", _profile(8, 1), ResponseTimeModel())
    fut = broker.submit("app", _env())
    broker.tick()
    assert not fut.done and broker.pending == 1  # back in the queue
    broker.tick()  # tick 2 has no scheduled faults
    assert fut.done and not fut.result.degraded
    assert fut.result.result is not None


def test_failing_bucket_quarantines_only_its_own_requests():
    """Two tenants in different shape buckets; the small bucket's flush
    exhausts its retries while the big bucket commits normally — and the
    surviving reply is bit-identical to a fault-free run."""
    clean = _broker(buckets=(8, 16))
    clean.register("small", _profile(6, 2), ResponseTimeModel())
    clean.register("big", _profile(12, 3), ResponseTimeModel())
    clean_small = clean.submit("small", _env())
    clean_big = clean.submit("big", _env())
    clean.tick()

    # buckets dispatch in size order: bucket 8 burns solve indices 0..2,
    # bucket 16 dispatches clean at index 3
    faults = ScriptedFaultInjector(
        {("solve", 1, i): "error" for i in range(3)}
    )
    broker = _broker(
        buckets=(8, 16), resilience=_policy(), fault_injector=faults
    )
    broker.register("small", _profile(6, 2), ResponseTimeModel())
    broker.register("big", _profile(12, 3), ResponseTimeModel())
    small = broker.submit("small", _env())
    big = broker.submit("big", _env())
    report = broker.tick()
    assert small.result.degraded and small.result.result.local_mask.all()
    assert _reply_tuple(big.result) == _reply_tuple(clean_big.result)
    assert report.degraded == 1 and report.solved == 1
    assert report.buckets == (16,)
    # the healthy bucket's commit was not rolled back: its bin now hits
    rehit = broker.submit("big", _env())
    broker.tick()
    assert rehit.result.cache_hit


def test_breaker_escalates_failing_backend_mid_tick(monkeypatch):
    """A genuinely failing backend trips the breaker mid-retry and the
    next attempt runs on the escalated backend."""
    breaker = CircuitBreaker(threshold=2, cooldown_ticks=4)
    broker = _broker(
        backend="jax",  # escalation chain: jax → reference
        resilience=_policy(breaker=breaker),
    )
    broker.register("app", _profile(8, 1), ResponseTimeModel())

    backends_used = []
    from repro.service import broker as broker_mod

    real = broker_mod.mcop_batch

    def flaky(batch, *, backend, buckets, **kw):
        backends_used.append(backend)
        if backend == "jax":
            raise RuntimeError("device lost")
        return real(batch, backend=backend, buckets=buckets, **kw)

    monkeypatch.setattr(broker_mod, "mcop_batch", flaky)
    fut = broker.submit("app", _env())
    report = broker.tick()
    # attempts 0 and 1 fail on jax (the 2nd trips the breaker), attempt
    # 2 runs on the escalated terminal reference backend and succeeds
    assert backends_used == ["jax", "jax", "reference"]
    assert report.breaker_trips == 1 and breaker.trips == 1
    assert report.retries == 2
    assert fut.result.result is not None and not fut.result.degraded


def test_latency_faults_charge_injected_clock_only():
    clock = InjectedClock()
    faults = ScriptedFaultInjector(
        {("solve", 1, 0): "latency"}, latency_s=0.5
    )
    broker = _broker(
        clock=clock, resilience=_policy(), fault_injector=faults
    )
    broker.register("app", _profile(8, 1), ResponseTimeModel())
    fut = broker.submit("app", _env())
    report = broker.tick()
    assert fut.result.result is not None and not fut.result.degraded
    assert report.faults == 1 and report.retries == 0
    assert report.latency_s >= 0.5  # the spike shows up in telemetry


# ----------------------------------------------------------------------
# Deadlines and shutdown drain
# ----------------------------------------------------------------------


def test_deadline_resolves_overdue_request_as_timed_out():
    broker = _broker(resilience=_policy(deadline_ticks=1))
    broker.register("app", _profile(6, 0), ResponseTimeModel())
    fut = broker.submit("app", _env())
    broker.tick(budget=0)  # still within deadline, stays queued
    assert not fut.done
    report = broker.tick(budget=0)
    assert fut.done and fut.result.timed_out and fut.result.result is None
    assert report.timed_out == 1
    assert broker.telemetry.timed_out_requests == 1
    assert broker.pending == 0


def test_per_request_deadline_overrides_policy_default():
    broker = _broker(resilience=_policy(deadline_ticks=50))
    broker.register("app", _profile(6, 0), ResponseTimeModel())
    fut = broker.submit("app", _env(), deadline=1)
    broker.tick(budget=0)
    broker.tick(budget=0)
    assert fut.done and fut.result.timed_out
    with pytest.raises(ValueError):
        broker.submit("app", _env(), deadline=0)


def test_drain_resolves_abandoned_futures_as_rejected():
    broker = _broker()
    broker.register("app", _profile(6, 0), ResponseTimeModel())
    futs = [broker.submit("app", _env(2.0 + i)) for i in range(3)]
    assert broker.drain() == 3
    assert all(f.done and f.result.rejected for f in futs)
    assert broker.pending == 0
    assert broker.telemetry.rejected_requests == 3
    assert broker.drain() == 0  # idempotent


# ----------------------------------------------------------------------
# Injection-disabled bit-identity (tentpole acceptance)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("topology", sorted(FIG2_TOPOLOGIES))
@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_rate_zero_resilient_broker_is_bit_identical(topology, model_name):
    """A fully-armed resilient broker with a rate-0 injector produces a
    bit-identical event stream and telemetry to today's broker."""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES[topology]())
    traces = user_traces(n_users=4, steps=6, seed=11)

    def run(**kw):
        broker = _broker(**kw)
        broker.register("app", profile, MODELS[model_name]())
        report = run_workload(
            broker, "app", n_users=4, steps=6,
            threshold=0.15, min_interval=2, traces=traces,
        )
        return report, broker

    plain_report, plain = run()
    armed_report, armed = run(
        resilience=_policy(breaker=CircuitBreaker()),
        fault_injector=FaultInjector(seed=123, rate=0.0),
    )
    for a, b in zip(plain_report.events, armed_report.events):
        for ea, eb in zip(a, b):
            assert ea.partial_cost == eb.partial_cost
            assert ea.gain == eb.gain
            assert ea.cache_hit == eb.cache_hit
            assert ea.repartitioned == eb.repartitioned
            assert np.array_equal(
                ea.result.local_mask, eb.result.local_mask
            )
    assert plain.telemetry.summary() == armed.telemetry.summary()
    for ra, rb in zip(plain.telemetry.reports, armed.telemetry.reports):
        assert dataclasses.asdict(ra) == dataclasses.asdict(rb)


def test_disabled_injector_session_tick_is_bit_identical():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    traces = user_traces(n_users=6, steps=5, seed=21)

    def run(**kw):
        broker = _broker(**kw)
        broker.register("app", profile, ResponseTimeModel())
        group = broker.register_batch("app", 6, threshold=0.15, min_interval=2)
        for t in range(5):
            envs = EnvArrays.from_envs([traces[u][t] for u in range(6)])
            group.observe(envs, arrived=np.arange(6) if t == 0 else None)
            broker.tick()
        return group.drain(), broker

    plain_reports, plain = run()
    armed_reports, armed = run(
        resilience=_policy(),
        fault_injector=FaultInjector(seed=5, rate=1.0, enabled=False),
    )
    for ra, rb in zip(plain_reports, armed_reports):
        assert np.array_equal(ra.placements, rb.placements)
        assert np.array_equal(ra.partial_cost, rb.partial_cost)
        assert np.array_equal(ra.min_cut, rb.min_cut)
        assert np.array_equal(ra.cache_hit, rb.cache_hit)
        assert (ra.hits, ra.solved, ra.coalesced) == (
            rb.hits, rb.solved, rb.coalesced,
        )
        assert rb.retries == 0 and rb.faults == 0
    assert plain.telemetry.summary() == armed.telemetry.summary()


# ----------------------------------------------------------------------
# Chaos: every future resolves under randomized faults
# ----------------------------------------------------------------------


@pytest.mark.parametrize("degrade", ["fallback", "requeue"])
def test_chaos_every_future_resolves(degrade):
    faults = FaultInjector(seed=42, rate=0.10)
    broker = _broker(
        resilience=_policy(
            degrade=degrade,
            deadline_ticks=6,
            breaker=CircuitBreaker(threshold=3, cooldown_ticks=4),
        ),
        fault_injector=faults,
    )
    profile = _profile(9, 7)
    broker.register("app", profile, ResponseTimeModel())
    traces = user_traces(n_users=5, steps=8, seed=9)
    futures = []
    for t in range(8):
        for u in range(5):
            futures.append(broker.submit("app", traces[u][t]))
        broker.tick()
    ticks = 0
    while broker.pending and ticks < 40:
        broker.tick()
        ticks += 1
    assert broker.pending == 0
    assert all(f.done for f in futures)
    served = 0
    for f in futures:
        r = f.result
        # exactly one terminal state, never an unresolved/exception path
        if r.rejected or r.timed_out:
            assert r.result is None
        else:
            assert r.result is not None
            assert r.result.local_mask.shape == (9,)
            served += 1
    # each served request recorded exactly one cache-stat event — faults
    # never double-count (re-queues retry uncounted work)
    stats = broker.tenant("app").cache.stats
    assert stats.hits + stats.misses == served
    assert broker.telemetry.faults > 0  # the storm actually happened


def test_chaos_session_groups_never_raise_and_converge():
    faults = FaultInjector(seed=13, rates={"solve": 0.5, "pricing": 0.2})
    broker = _broker(resilience=_policy(), fault_injector=faults)
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["tree"]())
    broker.register("app", profile, ResponseTimeModel())
    group = broker.register_batch("app", 4, threshold=0.15, min_interval=1)
    traces = user_traces(n_users=4, steps=6, seed=3)
    for t in range(6):
        envs = EnvArrays.from_envs([traces[u][t] for u in range(4)])
        if group.pending:  # a contained pricing failure kept the stage
            broker.tick()
        group.observe(envs, arrived=np.arange(4) if t == 0 else None)
        broker.tick()  # must never raise
    # end the storm, then force a drift no session can ignore: every
    # slot repartitions through a clean flush and lands on the true
    # optimum for the new environment
    faults.enabled = False
    if group.pending:
        broker.tick()
    extreme = EnvArrays.from_envs([_env(50.0, 50.0)] * 4)
    group.observe(extreme)
    broker.tick()
    reports = group.drain()
    assert reports, "group never completed a tick"
    final = reports[-1]
    assert final.faults == 0 and final.degraded is None
    assert final.repartitioned.all()

    # reference: a clean fresh batch observing the same environment
    clean = _broker()
    clean.register("app", profile, ResponseTimeModel())
    cgroup = clean.register_batch("app", 4, threshold=0.15, min_interval=1)
    cgroup.observe(extreme, arrived=np.arange(4))
    clean.tick()
    cfinal = cgroup.drain()[-1]
    assert np.array_equal(final.placements, cfinal.placements)
    assert np.array_equal(final.min_cut, cfinal.min_cut)


def test_session_flush_quarantine_degrades_and_recovers():
    """Direct tick_sessions: an exhausted flush serves fallbacks, rolls
    the drift anchors back, and the next clean tick solves for real."""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    model = ResponseTimeModel()
    cache = PlacementCache()
    batch = SessionBatch.create(3, profile.n, threshold=0.15)
    batch.activate(np.arange(3))
    envs = EnvArrays.from_envs([_env(2.0 + u) for u in range(3)])
    faults = ScriptedFaultInjector(
        {("solve", 1, i): "error" for i in range(3)}
    )
    policy = _policy()
    r1 = tick_sessions(
        batch, envs, profile=profile, model=model, cache=cache,
        backend="reference", faults=faults, resilience=policy, tick=1,
    )
    assert r1.degraded is not None and r1.degraded.all()
    assert r1.retries == 2 and r1.solved == 0
    assert r1.placements.all()  # cold cache → §4.3 all-local fallbacks
    assert cache.stats.misses == 3 and cache.stats.hits == 0

    # no faults scheduled at tick 2: anchors were rolled back, so every
    # session re-partitions and lands on the real optimum
    r2 = tick_sessions(
        batch, envs, profile=profile, model=model, cache=cache,
        backend="reference", faults=faults, resilience=policy, tick=2,
    )
    assert r2.degraded is None and r2.repartitioned.all()

    clean_batch = SessionBatch.create(3, profile.n, threshold=0.15)
    clean_batch.activate(np.arange(3))
    r_clean = tick_sessions(
        clean_batch, envs, profile=profile, model=model,
        cache=PlacementCache(), backend="reference",
    )
    assert np.array_equal(r2.placements, r_clean.placements)
    assert np.array_equal(r2.min_cut, r_clean.min_cut)
