"""Per-architecture smoke tests: reduced configs, same code paths.

For every one of the 10 assigned architectures: one train step (loss
finite, grads flow) and one prefill→decode round trip (shapes, no NaNs).
Full-size configs are exercised only via the dry-run (ShapeDtypeStructs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, SHAPES, get_config, reduce_config, valid_cells
from repro.models.transformer import build_model

ALL_ARCHS = sorted(ARCHITECTURES)


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.zeros((b, 4, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio_frames":
        batch["frame_embeds"] = jnp.zeros((b, 8, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_runs_and_is_finite(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, aux = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    # gradients exist and are finite for every leaf
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), (arch, path)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, max_len = 2, 8, 24
    cache = model.init_cache(b, max_len)
    batch = {k: v for k, v in _batch(cfg, b, s).items() if k != "labels"}
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (b, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2-7b", "zamba2-1.2b", "xlstm-1.3b"])
def test_decode_consistent_with_teacher_forcing(arch):
    """Greedy decode logits == full-forward logits at the same positions."""
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 1, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)

    # incremental: prefill s-1 tokens, decode the final one
    cache = model.init_cache(b, s + 4)
    _, cache = model.prefill(params, {"tokens": toks[:, : s - 1]}, cache)
    logits_inc, _ = model.decode_step(params, toks[:, s - 1 :], cache)

    # one-shot: prefill the full sequence; its last-position logits must match
    cache2 = model.init_cache(b, s + 4)
    logits_full, _ = model.prefill(params, {"tokens": toks}, cache2)

    np.testing.assert_allclose(
        np.asarray(logits_inc, np.float32),
        np.asarray(logits_full, np.float32),
        atol=5e-2,  # bf16 params
        rtol=5e-2,
    )


def test_param_counts_match_config_algebra():
    """Analytic param_count ≈ actual init sizes on reduced configs."""
    for arch in ALL_ARCHS:
        cfg = reduce_config(get_config(arch))
        model = build_model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        # same order of magnitude and within 40% — the analytic count is a
        # sizing model (norm scales etc. are approximated), not bookkeeping
        assert 0.6 < actual / analytic < 1.67, (arch, actual, analytic)


def test_valid_cells_covers_assignment():
    cells = valid_cells()
    assert len({a for a, _ in cells}) == 10
    # long_500k only for sub-quadratic archs
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"zamba2-1.2b", "xlstm-1.3b"}
    # every arch runs the other three shapes
    for arch in ALL_ARCHS:
        shapes = {s for a, s in cells if a == arch}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes
    assert len(cells) == 10 * 4 - 8  # 32 cells, 2 meshes each → 64 compiles


def test_full_configs_match_assignment_table():
    """Spot-check the published hyperparameters we were assigned."""
    q3 = get_config("qwen3-32b")
    assert (q3.n_layers, q3.d_model, q3.n_heads, q3.n_kv_heads) == (64, 5120, 64, 8)
    assert q3.d_ff == 25600 and q3.vocab_size == 151_936 and q3.qk_norm
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6
    assert ds.mla.kv_lora_rank == 512 and ds.attn_kind == "mla"
    gr = get_config("granite-34b")
    assert gr.n_layers == 88 and gr.n_kv_heads == 1
    ll = get_config("llama4-scout-17b-a16e")
    assert ll.moe.num_experts == 16 and ll.moe.top_k == 1
    za = get_config("zamba2-1.2b")
    assert za.ssm_state == 64 and za.supports_long_context
    xl = get_config("xlstm-1.3b")
    assert xl.n_layers == 48 and xl.d_ff == 0 and xl.supports_long_context
    sm = get_config("seamless-m4t-large-v2")
    assert sm.encoder_layers == 24 and sm.vocab_size == 256_206
    vl = get_config("qwen2-vl-72b")
    assert vl.rope_variant == "mrope" and vl.d_ff == 29568


def test_vocab_chunked_loss_matches_full():
    """The chunked cross-entropy (perf knob) is numerically identical."""
    from repro.models.transformer import Model

    cfg = reduce_config(get_config("qwen2-7b"), vocab_size=250)  # pad path
    m_full = Model(cfg)
    m_chunk = Model(cfg, vocab_chunk=64)
    params = m_full.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1 = m_full.train_loss(params, batch)[0]
    l2 = m_chunk.train_loss(params, batch)[0]
    assert abs(float(l1) - float(l2)) < 1e-5
    g = jax.grad(lambda p: m_chunk.train_loss(p, batch)[0])(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_remat_knob_changes_nothing_numerically():
    from repro.models.transformer import Model

    cfg = reduce_config(get_config("qwen2-7b"))
    m_on = Model(cfg, remat=True)
    m_off = Model(cfg, remat=False)
    params = m_on.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1 = m_on.train_loss(params, batch)[0]
    l2 = m_off.train_loss(params, batch)[0]
    assert abs(float(l1) - float(l2)) < 1e-5


def test_ring_window_cache_matches_full_window_attention():
    """Decode through a ring cache (width 8) for 20 steps == windowed
    attention over the full history at every step (wraparound exact)."""
    from repro.models import attention as attn_lib
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16, rope_theta=1e4,
    )
    p = attn_lib.init_attention(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    w, steps, b = 8, 20, 2
    xs = jnp.asarray(rng.normal(size=(b, steps, cfg.d_model)), jnp.float32)

    cache = attn_lib.KVCache(
        k=jnp.zeros((b, w, 2, 16), jnp.float32),
        v=jnp.zeros((b, w, 2, 16), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )
    outs = []
    for t in range(steps):
        pos = jnp.full((b, 1), t, jnp.int32)
        o, cache = attn_lib.attention_forward(
            cfg, p, xs[:, t : t + 1], positions=pos, cache=cache, ring=True
        )
        outs.append(o)
    ring_out = jnp.concatenate(outs, axis=1)

    # reference: full (non-cached) windowed attention, teacher-forced
    full_pos = jnp.broadcast_to(jnp.arange(steps)[None, :], (b, steps))
    ref_out, _ = attn_lib.attention_forward(
        cfg, p, xs, positions=full_pos, window=w
    )
    np.testing.assert_allclose(
        np.asarray(ring_out), np.asarray(ref_out), atol=2e-5, rtol=2e-5
    )


def test_flash_decode_partitioning_matches_naive():
    """The flash-decoding layout (§Perf C2) is a numerics-preserving
    re-partitioning of decode attention."""
    from repro.models import attention as attn_lib
    from repro.models.transformer import build_model as _bm

    cfg = reduce_config(get_config("qwen3-32b"))
    m = _bm(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab_size, (2, 9)), jnp.int32
    )
    cache = m.init_cache(2, 16)
    _, cache = m.prefill(params, {"tokens": toks[:, :8]}, cache)
    l_base, _ = m.decode_step(params, toks[:, 8:9], cache)
    attn_lib.set_decode_flash_partitioning(True)
    try:
        l_flash, _ = m.decode_step(params, toks[:, 8:9], cache)
    finally:
        attn_lib.set_decode_flash_partitioning(False)
    np.testing.assert_allclose(
        np.asarray(l_base, np.float32), np.asarray(l_flash, np.float32),
        atol=0.06, rtol=0.06,  # bf16 probs in the naive path vs f32 here
    )
