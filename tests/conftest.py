"""Shared fixtures for the test suite.

``qwen_stages`` is THE canonical framework-level stage list —
qwen2-7b at the 4k-token training shape, 8-layer groups — previously
copy-pasted into every elastic/broker/pipeline test.  The specs are
built once per session (stage_specs is pure but not free) and handed
out as a fresh shallow list; StageSpec is a frozen dataclass, so tests
cannot corrupt each other through the shared elements.
"""

import pytest


@pytest.fixture(scope="session")
def _qwen_stages_cached():
    from repro.configs import ARCHITECTURES, SHAPES
    from repro.profilers.program import stage_specs

    return stage_specs(ARCHITECTURES["qwen2-7b"], SHAPES["train_4k"], group=8)


@pytest.fixture
def qwen_stages(_qwen_stages_cached):
    """qwen2-7b / train_4k / group=8 stage specs, fresh list per test."""
    return list(_qwen_stages_cached)
