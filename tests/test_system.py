"""End-to-end behaviour: the whole stack wired together, plus dry-run units."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import ARCHITECTURES, reduce_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.models.transformer import build_model
from repro.serving import ServingConfig, ServingEngine
from repro.train import AdamWConfig, TrainConfig, train_loop


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """Train a tiny model, checkpoint it, restore, serve from the restore."""
    cfg = reduce_config(ARCHITECTURES["qwen3-32b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLMDataset(
        DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size), cfg
    )
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=15))
    state, hist = train_loop(
        lambda p, b: model.train_loss(p, b), params, data.take(15), tcfg
    )
    assert hist[-1]["loss"] < hist[0]["loss"]

    store = CheckpointStore(str(tmp_path))
    store.save(15, state.params)
    _, restored, _ = store.restore_latest(state.params)

    eng = ServingEngine(
        model, restored, ServingConfig(max_batch=2, max_prompt_len=8, max_len=24)
    )
    for i in range(3):
        eng.submit(np.arange(1, 5 + i), max_new_tokens=4)
    out = eng.run_to_completion()
    assert len(out) == 3 and all(len(v) == 4 for v in out.values())


def test_mcop_placement_drives_training_config():
    """The launcher path: profile → MCOP → plan, for a real assigned arch."""
    import dataclasses

    from repro.configs import SHAPES
    from repro.core.placement import TPUV5E_TIER, plan_placement
    from repro.profilers.program import stage_specs

    cfg = ARCHITECTURES["granite-34b"]
    stages = stage_specs(cfg, SHAPES["train_4k"], group=11)
    plan = plan_placement(
        stages,
        dataclasses.replace(TPUV5E_TIER, chips=64),
        dataclasses.replace(TPUV5E_TIER, chips=192),
    )
    # 88 layers / 11 = 8 stage groups + embed + head
    assert plan.stage_tier.shape[0] == 10
    assert np.isfinite(plan.mcop_cost)
    assert plan.result.local_mask[0]  # embed stays local


# ----------------------------------------------------------------------
# Dry-run units (the full dry-run runs out-of-band; these test its parts)
# ----------------------------------------------------------------------

SAMPLE_HLO = """
HloModule jit_step, is_scheduled=true

%fused (a: f32[128,256]) -> f32[128,256] {
  ROOT %r = f32[128,256] parameter(0)
}

ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[256,256]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%fused
  %rs = f32[64,256]{1,0} reduce-scatter(%p0), to_apply=%fused, dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = f32[128,256]{1,0} all-to-all(%p0), dimensions={0}
  %ags = (f32[128,256], f32[256,256]) all-gather-start(%p0), dimensions={0}
  %agd = f32[256,256]{1,0} all-gather-done(%ags)
  ROOT %out = f32[128,256]{1,0} add(%ar, %cp)
}
"""


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    coll = collective_bytes(SAMPLE_HLO)
    leaf = 128 * 256 * 4  # f32[128,256]
    assert coll["all-reduce"] == leaf
    assert coll["collective-permute"] == leaf
    assert coll["all-to-all"] == leaf
    assert coll["reduce-scatter"] == leaf
    # all-gather appears twice: sync op + async -start (done is skipped)
    assert coll["all-gather"] == 2 * leaf
    assert coll["num_ops"] == 6
    assert coll["total"] == 6 * leaf


def test_model_flops_convention():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import model_flops

    cfg = get_config("qwen2-7b")
    train = model_flops(cfg, SHAPES["train_4k"])
    assert train == pytest.approx(6.0 * cfg.active_param_count() * 4096 * 256)
    dec = model_flops(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2.0 * cfg.active_param_count() * 128)


def test_build_cell_shapes_are_allocation_free():
    """build_cell must work purely in eval_shape land."""
    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_local_mesh, use_mesh
    from repro.launch.specs import build_cell

    mesh = make_local_mesh(model=1)
    cfg = reduce_config(get_config("qwen2-7b"))
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        import dataclasses

        shape = dataclasses.replace(
            SHAPES[shape_name], seq_len=64, global_batch=4
        )
        cell = build_cell(cfg, shape, mesh)
        for leaf in jax.tree_util.tree_leaves(cell.arg_shapes):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_reduced_cell_lowers_and_compiles_on_local_mesh():
    """A miniature end-to-end dry-run on the real single device."""
    import dataclasses

    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_local_mesh, use_mesh
    from repro.launch.specs import build_cell

    mesh = make_local_mesh(model=1)
    cfg = reduce_config(get_config("qwen3-32b"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=4)
    cell = build_cell(cfg, shape, mesh)
    with use_mesh(mesh):
        lowered = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.arg_shapes)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    assert float(cost.get("flops", 0)) > 0
