"""Wire-protocol conformance + fuzz suite (`service/wire.py`, server edge).

The contract under test: hostile bytes — truncation at every offset,
seeded garbage, oversized declared lengths, version-mismatch hellos —
must surface as *typed* outcomes (`WireError` subclasses locally, typed
``error`` frames + clean disconnects at the server) and never as a hang
or a silently-unresolved future.  Every socket read in this file is
timeout-bounded, so a hang is a test failure, not a CI deadlock.
"""

import socket
import struct
import threading

import numpy as np
import pytest

from repro.core import AppProfile, Environment, ResponseTimeModel, random_wcg
from repro.service import (
    BrokerClient,
    OffloadBroker,
    SolverServer,
    unix_address,
)
from repro.service.wire import (
    DEFAULT_MAX_FRAME,
    ERROR_CODES,
    HEADER_SIZE,
    PROTOCOL_VERSION,
    BadFrame,
    FrameStream,
    FrameTooLarge,
    TruncatedFrame,
    VersionMismatch,
    WireError,
    decode_frame,
    encode_frame,
    env_to_wire,
    error_frame,
    reply_to_wire,
    supported_encodings,
    wire_to_env,
    wire_to_reply,
)
from _hyp import given, settings, st  # hypothesis or skip-shim (see _hyp.py)

pytestmark = pytest.mark.service

TIMEOUT = 10.0  # bound on every read: a hang is a failure, not a stall


# ----------------------------------------------------------------------
# codec round trips
# ----------------------------------------------------------------------
@pytest.mark.parametrize("encoding", supported_encodings())
def test_frame_round_trip(encoding):
    frame = {"type": "submit", "id": "c-1", "x": [1, 2.5, None, "s"]}
    data = encode_frame(frame, encoding=encoding)
    out, used = decode_frame(data)
    assert out == frame and used == len(data)


@pytest.mark.parametrize("encoding", supported_encodings())
def test_float64_round_trip_is_bit_exact(encoding):
    """The determinism contract: every float64 crosses the wire intact —
    what makes cross-process replies ``==`` in-process ones."""
    rng = np.random.default_rng(0)
    values = [
        float(v)
        for v in [
            *rng.standard_normal(64),
            *np.exp(rng.uniform(-300, 300, 32)),
            5e-324, 1.7976931348623157e308, 1 / 3, 0.1 + 0.2,
        ]
    ]
    frame = {"type": "t", "v": values}
    out, _ = decode_frame(encode_frame(frame, encoding=encoding))
    assert all(
        struct.pack("<d", a) == struct.pack("<d", b)
        for a, b in zip(out["v"], values)
    )


def test_env_and_reply_round_trip():
    from repro.service.broker import BrokerReply
    from repro.core.mcop import MCOPResult

    env = Environment(
        bandwidth_up=1 / 3, bandwidth_down=2.25, speedup=np.pi,
        p_compute=0.7, p_idle=0.01, p_transfer=0.3,
    )
    assert wire_to_env(env_to_wire(env)) == env
    reply = BrokerReply(
        MCOPResult(min_cut=1 / 7, local_mask=np.array([True, False, True]),
                   phases=[]),
        cache_hit=True, coalesced=False, tick=41, degraded=True,
    )
    out = wire_to_reply(reply_to_wire(reply))
    assert out.result.min_cut == reply.result.min_cut
    assert np.array_equal(out.result.local_mask, reply.result.local_mask)
    assert (out.cache_hit, out.coalesced, out.tick, out.rejected,
            out.degraded, out.timed_out) == (True, False, 41, False,
                                             True, False)


# ----------------------------------------------------------------------
# hostile bytes, locally
# ----------------------------------------------------------------------
def test_truncation_at_every_offset():
    data = encode_frame({"type": "ping", "nonce": "abc"})
    for cut in range(len(data)):
        with pytest.raises(TruncatedFrame):
            decode_frame(data[:cut])


def test_oversized_frames_refused_both_ways():
    with pytest.raises(FrameTooLarge):
        encode_frame({"type": "t", "blob": "x" * DEFAULT_MAX_FRAME})
    # a forged header declaring a huge payload is refused from the
    # header alone — no attempt to buffer the declared bytes
    forged = struct.pack("!IB", DEFAULT_MAX_FRAME + 1, 0)
    with pytest.raises(FrameTooLarge):
        decode_frame(forged)


def test_malformed_payloads_are_typed_errors():
    bad = [
        struct.pack("!IB", 4, 0) + b"nope",        # undecodable json
        struct.pack("!IB", 4, 9) + b"\0\0\0\0",    # unknown encoding tag
        struct.pack("!IB", 2, 0) + b"[]",          # not a dict
        struct.pack("!IB", 2, 0) + b"{}",          # no "type"
        struct.pack("!IB", 12, 0) + b'{"type": 42}',  # non-str type
    ]
    for data in bad:
        with pytest.raises(BadFrame):
            decode_frame(data)


def test_garbage_bytes_seeded_fuzz():
    """256 seeded random byte strings: every one must resolve to a typed
    WireError or a (frame, consumed) pair — nothing else escapes."""
    rng = np.random.default_rng(1234)
    for _ in range(256):
        blob = rng.bytes(int(rng.integers(0, 96)))
        try:
            frame, used = decode_frame(blob)
        except WireError:
            continue
        assert isinstance(frame, dict) and 0 < used <= len(blob)


def test_bit_flip_fuzz_on_valid_frames():
    """Seeded single-byte corruptions of a valid frame: decode either
    still succeeds (flip landed in a string) or raises a WireError."""
    data = bytearray(encode_frame({"type": "submit", "id": "x" * 32}))
    rng = np.random.default_rng(99)
    for _ in range(256):
        i = int(rng.integers(len(data)))
        corrupted = bytearray(data)
        corrupted[i] ^= int(rng.integers(1, 256))
        try:
            decode_frame(bytes(corrupted))
        except WireError:
            pass


def test_error_frame_codes_are_closed_set():
    for code in ERROR_CODES:
        assert error_frame(code, "m")["type"] == "error"
    with pytest.raises(ValueError):
        error_frame("made_up_code")


@settings(max_examples=200, deadline=None)
@given(st.binary(max_size=2 * HEADER_SIZE + 64))
def test_decode_frame_total_on_arbitrary_bytes(blob):
    """Property: decode_frame is total over arbitrary byte strings —
    typed WireError or a well-formed (dict, consumed) result."""
    try:
        frame, used = decode_frame(blob)
    except WireError:
        return
    assert isinstance(frame, dict) and isinstance(frame.get("type"), str)
    assert HEADER_SIZE <= used <= len(blob)


# ----------------------------------------------------------------------
# server-side conformance (live socket, bounded reads)
# ----------------------------------------------------------------------
@pytest.fixture
def live_server(tmp_path):
    profile = AppProfile.from_wcg_times(
        random_wcg(10, rng=np.random.default_rng(0))
    )
    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("app", profile, ResponseTimeModel())
    server = SolverServer(
        broker,
        address=unix_address(tmp_path / "srv.sock"),
        journal_path=tmp_path / "journal.jsonl",
        snapshot_dir=tmp_path / "snaps",
    )
    server.bind()
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield server, profile
    server.stop()
    thread.join(timeout=10)


def _raw(server) -> FrameStream:
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(TIMEOUT)
    sock.connect(server.address[1])
    return FrameStream(sock)


def _hello(stream, **overrides) -> dict:
    hello = {"type": "hello", "version": PROTOCOL_VERSION,
             "encoding": "json", "client": "conformance"}
    hello.update(overrides)
    stream.send(hello)
    return stream.recv(TIMEOUT)


def test_version_mismatch_hello_gets_typed_error_and_close(live_server):
    server, _ = live_server
    stream = _raw(server)
    reply = _hello(stream, version=PROTOCOL_VERSION + 13)
    assert reply["type"] == "error"
    assert reply["code"] == "version_mismatch"
    assert reply["server_version"] == PROTOCOL_VERSION
    assert stream.recv(TIMEOUT) is None  # clean disconnect
    stream.close()


def test_first_frame_must_be_hello(live_server):
    server, _ = live_server
    stream = _raw(server)
    stream.send({"type": "ping"})
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "error" and reply["code"] == "not_ready"
    assert stream.recv(TIMEOUT) is None
    stream.close()


def test_garbage_bytes_get_error_frame_then_disconnect(live_server):
    server, _ = live_server
    stream = _raw(server)
    assert _hello(stream)["type"] == "hello_ok"
    stream.sock.sendall(b"\xff" * 64)  # nonsense header: huge length
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "error" and reply["code"] in ("bad_frame",
                                                          "too_large")
    assert stream.recv(TIMEOUT) is None
    stream.close()


def test_oversized_frame_is_refused_without_buffering(live_server):
    server, _ = live_server
    stream = _raw(server)
    assert _hello(stream)["type"] == "hello_ok"
    stream.sock.sendall(struct.pack("!IB", DEFAULT_MAX_FRAME + 1, 0))
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "error" and reply["code"] == "too_large"
    assert stream.recv(TIMEOUT) is None
    stream.close()


def test_content_errors_keep_the_connection_open(live_server):
    server, _ = live_server
    stream = _raw(server)
    assert _hello(stream)["type"] == "hello_ok"
    # unknown frame type
    stream.send({"type": "frobnicate"})
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "error" and reply["code"] == "unknown_type"
    # unknown tenant
    stream.send({"type": "submit", "id": "q-1", "tenant": "ghost",
                 "env": env_to_wire(Environment.symmetric(2.0, 3.0))})
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "error" and reply["code"] == "unknown_tenant"
    # malformed submit (no id)
    stream.send({"type": "submit", "tenant": "app"})
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "error" and reply["code"] == "bad_frame"
    # ...and the stream still serves: ping works
    stream.send({"type": "ping", "nonce": "still-alive"})
    reply = stream.recv(TIMEOUT)
    assert reply["type"] == "pong" and reply["nonce"] == "still-alive"
    stream.send({"type": "bye"})
    assert stream.recv(TIMEOUT) is None
    stream.close()


def test_fuzz_storm_never_wedges_the_server(live_server):
    """Seeded garbage blasted over N connections, then a clean client:
    the reactor must still serve real work afterwards."""
    server, profile = live_server
    rng = np.random.default_rng(7)
    for _ in range(8):
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(TIMEOUT)
        sock.connect(server.address[1])
        try:
            sock.sendall(rng.bytes(int(rng.integers(1, 512))))
        except OSError:
            pass
        sock.close()
    client = BrokerClient(
        unix_address(server.address[1]),
        tenants={"app": (profile, ResponseTimeModel())},
        client="post-fuzz", timeout=TIMEOUT,
    )
    client.connect()
    fut = client.submit("app", Environment.symmetric(2.0, 3.0))
    client.tick()
    assert fut.done and fut.result.result is not None
    client.close()


def test_no_unresolved_futures_after_drain(live_server):
    """Every submitted future resolves within a bounded number of ticks
    — the 'never an unresolved future' clause, deadline-bounded."""
    server, profile = live_server
    client = BrokerClient(
        unix_address(server.address[1]),
        tenants={"app": (profile, ResponseTimeModel())},
        client="drainer", timeout=TIMEOUT,
    )
    client.connect()
    futures = [
        client.submit("app", Environment.symmetric(bw, 3.0), deadline=4)
        for bw in (8.0, 1.2, 0.3, 8.0, 1.2)
    ]
    client.drain(max_ticks=16)
    assert client.unresolved == 0
    assert all(f.done for f in futures)
    client.close()


def test_hello_negotiates_encoding_and_lists_tenants(live_server):
    server, _ = live_server
    stream = _raw(server)
    ok = _hello(stream, encoding="msgpack")
    assert ok["type"] == "hello_ok"
    assert ok["encoding"] in supported_encodings()
    assert ok["tenants"] == ["app"]
    assert ok["version"] == PROTOCOL_VERSION
    stream.send({"type": "bye"})
    stream.close()


def test_client_rejects_version_mismatch(live_server, monkeypatch):
    server, profile = live_server
    import repro.service.client as client_mod

    monkeypatch.setattr(client_mod, "PROTOCOL_VERSION", PROTOCOL_VERSION + 1)
    client = BrokerClient(
        unix_address(server.address[1]),
        tenants={"app": (profile, ResponseTimeModel())},
        timeout=TIMEOUT,
    )
    with pytest.raises(VersionMismatch):
        client.connect()
