"""Array-native environment→placement pipeline: WCGBatch, batch-first
cost models, the fused ``solve_envs`` program, broker priority lanes and
atomic snapshot writes.

The parity suite is the acceptance gate for the fusion refactor:
``solve_envs`` must return bit-identical placements to the object path
(per-environment ``cost_model.build`` + ``mcop_batch``) across all
Fig.-2 topologies × all three cost models.
"""

import json
import os

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or skip-shim (see _hyp.py)

from repro.core import (
    AppProfile,
    EnergyModel,
    Environment,
    PlacementCache,
    ResponseTimeModel,
    WCGBatch,
    WeightedModel,
    linear_graph,
    loop_graph,
    mcop_batch,
    mcop_reference,
    mesh_graph,
    random_wcg,
    solve_envs,
    tree_graph,
)

FIG2_TOPOLOGIES = {
    "linear": lambda: linear_graph(9, rng=np.random.default_rng(1)),
    "loop": lambda: loop_graph(8, rng=np.random.default_rng(2)),
    "tree": lambda: tree_graph(10, rng=np.random.default_rng(3)),
    "mesh": lambda: mesh_graph(3, 3, rng=np.random.default_rng(4)),
}

MODELS = {
    "time": ResponseTimeModel,
    "energy": EnergyModel,
    "weighted": lambda: WeightedModel(0.35),
}


def _envs(k: int = 7) -> list[Environment]:
    bands = np.geomspace(0.2, 20.0, k)
    return [
        Environment.symmetric(float(b), 1.5 + (i % 3)) for i, b in enumerate(bands)
    ]


# ----------------------------------------------------------------------
# Tentpole parity: solve_envs ≡ object path, all topologies × models
# ----------------------------------------------------------------------


@pytest.mark.parametrize("topology", sorted(FIG2_TOPOLOGIES))
@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_solve_envs_matches_object_path(topology, model_name):
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES[topology]())
    model = MODELS[model_name]()
    envs = _envs()
    fused = solve_envs(profile, model, envs, backend="jax")
    object_path = mcop_batch(
        [model.build(profile, e) for e in envs], backend="jax"
    )
    reference = [mcop_reference(model.build(profile, e)) for e in envs]
    for f, o, r, env in zip(fused, object_path, reference, envs):
        assert (f.local_mask == o.local_mask).all(), (topology, model_name, env)
        assert (f.local_mask == r.local_mask).all()
        assert f.min_cut == pytest.approx(o.min_cut, rel=1e-4)
        # the fused cut is the true Eq.-2 cost of the fused placement
        g = model.build(profile, env)
        assert f.min_cut == pytest.approx(g.total_cost(f.local_mask), rel=1e-4)


def test_solve_envs_reference_backend_is_exact():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["tree"]())
    model = ResponseTimeModel()
    envs = _envs(5)
    for f, env in zip(solve_envs(profile, model, envs, backend="reference"), envs):
        r = mcop_reference(model.build(profile, env))
        assert f.min_cut == r.min_cut and (f.local_mask == r.local_mask).all()


def test_solve_envs_pallas_backend_matches_reference():
    g = random_wcg(7, edge_prob=0.4, rng=np.random.default_rng(11))
    profile = AppProfile.from_wcg_times(g)
    model = ResponseTimeModel()
    envs = _envs(3)
    fused = solve_envs(profile, model, envs, backend="pallas", buckets=(8,))
    for f, env in zip(fused, envs):
        r = mcop_reference(model.build(profile, env))
        assert (f.local_mask == r.local_mask).all()
        assert f.min_cut == pytest.approx(r.min_cut, rel=1e-4)


def test_solve_envs_empty_and_bad_backend():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    assert solve_envs(profile, ResponseTimeModel(), []) == []
    with pytest.raises(ValueError):
        solve_envs(profile, ResponseTimeModel(), _envs(2), backend="cuda")


def test_scalar_build_is_batch_of_one():
    """The object API survives as a thin wrapper: build() rows equal
    build_batch() rows bit-for-bit."""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["mesh"]())
    envs = _envs(4)
    for model_name in sorted(MODELS):
        model = MODELS[model_name]()
        batch = model.build_batch(profile, envs)
        for i, env in enumerate(envs):
            g = model.build(profile, env)
            row = batch.wcg(i)
            assert (g.w_local == row.w_local).all()
            assert (g.w_cloud == row.w_cloud).all()
            assert (g.adj == row.adj).all()
            assert (g.offloadable == row.offloadable).all()
            assert g.names == row.names


# ----------------------------------------------------------------------
# WCGBatch: packing, direct mcop_batch dispatch, vectorized pricing
# ----------------------------------------------------------------------


def _mixed_graphs():
    gs = [
        random_wcg(
            int(rng.integers(2, 13)),
            edge_prob=0.4,
            n_unoffloadable=int(rng.integers(0, 3)),
            rng=rng,
        )
        for rng in (np.random.default_rng(s) for s in range(6))
    ]
    gs[0].offloadable[:] = True  # anchor-fallback row
    return gs


def test_wcgbatch_roundtrip_smoke():
    """Fixed-seed numpy fallback of the hypothesis property below."""
    gs = _mixed_graphs()
    batch = WCGBatch.from_wcgs(gs, m=16)
    assert len(batch) == len(gs) and batch.m == 16
    for g, g2 in zip(gs, batch.to_wcgs()):
        assert (g.w_local == g2.w_local).all()
        assert (g.w_cloud == g2.w_cloud).all()
        assert (g.adj == g2.adj).all()
        assert (g.offloadable == g2.offloadable).all()


@given(st.integers(0, 2**31 - 1), st.integers(2, 14))
@settings(max_examples=25, deadline=None)
def test_wcgbatch_roundtrip_property(seed, n):
    """WCG ↔ WCGBatch round-trips exactly, padding and pinning included."""
    rng = np.random.default_rng(seed)
    g = random_wcg(
        n,
        edge_prob=float(rng.uniform(0.1, 0.8)),
        n_unoffloadable=int(rng.integers(0, n)),
        rng=rng,
    )
    if rng.integers(2):
        g.offloadable[:] = True
    batch = WCGBatch.from_wcgs([g], m=16)
    g2 = batch.wcg(0)
    assert (g.w_local == g2.w_local).all()
    assert (g.w_cloud == g2.w_cloud).all()
    assert (g.adj == g2.adj).all()
    assert (g.offloadable == g2.offloadable).all()
    # anchored pinning never leaks back into the round-tripped graph but
    # guarantees the solver an anchor on every row
    pin = batch.anchored_pinned()
    assert pin[0, : g.n].any()


def test_mcop_batch_accepts_wcgbatch_directly():
    gs = _mixed_graphs()
    direct = mcop_batch(WCGBatch.from_wcgs(gs, m=16))
    packed = mcop_batch(gs, buckets=(16,))
    for a, b, g in zip(direct, packed, gs):
        assert a.min_cut == b.min_cut
        assert (a.local_mask == b.local_mask).all()
        assert a.local_mask.shape == (g.n,)
    with pytest.raises(ValueError):
        mcop_batch(WCGBatch.from_wcgs(gs), backend="cuda")


def test_wcgbatch_total_cost_matches_scalar():
    gs = _mixed_graphs()
    batch = WCGBatch.from_wcgs(gs, m=16)
    masks = np.ones((len(gs), 16), dtype=bool)
    rng = np.random.default_rng(7)
    for i, g in enumerate(gs):
        masks[i, : g.n] = rng.integers(0, 2, g.n).astype(bool) | ~g.offloadable
    costs = batch.total_cost(masks)
    for i, g in enumerate(gs):
        assert costs[i] == pytest.approx(g.total_cost(masks[i, : g.n]), rel=1e-12)


def test_wcgbatch_shape_validation():
    g = random_wcg(5, rng=np.random.default_rng(0))
    with pytest.raises(ValueError):
        WCGBatch.from_wcgs([])
    with pytest.raises(ValueError):
        WCGBatch.from_wcgs([g], m=3)  # pad target below graph size
    batch = WCGBatch.from_wcgs([g])
    with pytest.raises(ValueError):
        batch.total_cost(np.ones((2, 5), bool))


# ----------------------------------------------------------------------
# Broker priority lanes (elastic ahead of user within a tick)
# ----------------------------------------------------------------------


def test_broker_elastic_lane_flushes_first(monkeypatch):
    from repro.service import OffloadBroker
    from repro.service import broker as broker_mod

    dispatched = []
    real = broker_mod.mcop_batch

    def spy(graphs, **kw):
        dispatched.append(graphs)
        return real(graphs, **kw)

    monkeypatch.setattr(broker_mod, "mcop_batch", spy)

    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("fleet")
    env = Environment.symmetric(4.0, 3.0)
    g_user = random_wcg(8, rng=np.random.default_rng(0))
    g_elastic = random_wcg(8, rng=np.random.default_rng(1))
    # user submits FIRST; same tenant/bin/size so the pair coalesces —
    # the lane decides which request becomes the representative solve
    f_user = broker.submit_graph("fleet", g_user, env)
    f_el = broker.submit_graph("fleet", g_elastic, env, lane="elastic")
    report = broker.tick()
    assert report.elastic == 1
    assert report.solved == 1 and report.coalesced == 1
    assert broker.telemetry.elastic_requests == 1
    assert "elastic_requests" in broker.telemetry.summary()
    (batch,) = dispatched
    assert len(batch) == 1
    assert (batch.wcg(0).adj == g_elastic.adj).all()   # elastic won the lane
    assert not f_el.result.coalesced and f_user.result.coalesced


def test_broker_deferred_build_failure_requeues_everything():
    """A failing deferred build (bad environment) must honor the tick's
    containment contract: no future resolves, nothing is dropped."""
    from repro.service import OffloadBroker

    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    broker.register("app", profile, ResponseTimeModel())
    broker.register("raw")
    # negative bandwidth → negative edge weights → WCG validation raises
    bad = broker.submit("app", Environment.symmetric(-1.0, 3.0))
    ok = broker.submit_graph(
        "raw",
        random_wcg(6, rng=np.random.default_rng(3)),
        Environment.symmetric(2.0, 3.0),
    )
    with pytest.raises(ValueError, match="non-negative"):
        broker.tick()
    assert not bad.done and not ok.done
    assert broker.pending == 2  # both re-queued, neither stranded


def test_submit_resize_rides_elastic_lane(qwen_stages):
    from repro.core.placement import TPUV5E_TIER
    from repro.runtime import ElasticMeshManager
    from repro.service import OffloadBroker

    stages = qwen_stages
    mgr = ElasticMeshManager(stages, TPUV5E_TIER, TPUV5E_TIER)
    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("fleet")
    pending = mgr.submit_resize(broker, "fleet", step=1, remote_chips=16)
    report = broker.tick()
    assert report.elastic == 1
    pending.resolve()


# ----------------------------------------------------------------------
# Atomic snapshot writes
# ----------------------------------------------------------------------


def test_cache_save_is_atomic(tmp_path, monkeypatch):
    cache = PlacementCache()
    cache.put(Environment.symmetric(5.0, 3.0), np.array([True, False, True]))
    path = tmp_path / "snap.json"
    cache.save(path, fingerprint="fp")
    good = path.read_text()
    assert json.loads(good)["fingerprint"] == "fp"
    assert list(tmp_path.iterdir()) == [path]  # no temp litter on success

    # a crash mid-replace must leave the previous snapshot intact and
    # clean up the temporary file
    def boom(src, dst):
        raise OSError("simulated crash")

    monkeypatch.setattr(os, "replace", boom)
    cache.put(Environment.symmetric(1.0, 3.0), np.array([False, True, False]))
    with pytest.raises(OSError, match="simulated"):
        cache.save(path, fingerprint="fp")
    assert path.read_text() == good
    assert list(tmp_path.iterdir()) == [path]

    monkeypatch.undo()
    cache.save(path, fingerprint="fp")
    warm = PlacementCache.from_snapshot(path, fingerprint="fp")
    assert len(warm) == 2
