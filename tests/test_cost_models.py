"""Cost models (Eqs. 4–9) and the adaptive repartitioning loop (Fig. 1)."""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or skip-shim (see _hyp.py)

from repro.core import (
    AppProfile,
    AdaptiveController,
    EnergyModel,
    Environment,
    ResponseTimeModel,
    WeightedModel,
    brute_force,
    mcop_reference,
    no_offloading,
    offloading_gain,
    paper_example_graph,
    random_wcg,
)
from repro.core.cost_models import PAPER_POWERS


def _profile(n=7, seed=0):
    g = random_wcg(n, rng=np.random.default_rng(seed))
    return AppProfile.from_wcg_times(g)


def test_response_time_model_eq4():
    prof = _profile()
    env = Environment.symmetric(bandwidth=2.0, speedup=4.0)
    g = ResponseTimeModel().build(prof, env)
    assert np.allclose(g.w_cloud, prof.t_local / 4.0)       # T_c = T_l / F
    # edge: (in_ij + out_ij)/B both directions, symmetrised
    i, j = np.nonzero(prof.data_in)
    if i.size:
        a, b = i[0], j[0]
        expect = (
            prof.data_in[a, b] / 2.0 + prof.data_out[a, b] / 2.0
            + prof.data_in[b, a] / 2.0 + prof.data_out[b, a] / 2.0
        )
        assert g.adj[a, b] == pytest.approx(expect)


def test_energy_model_eq6_uses_paper_powers():
    prof = _profile()
    env = Environment.symmetric(bandwidth=1.0, speedup=2.0)
    g = EnergyModel().build(prof, env)
    assert np.allclose(g.w_local, PAPER_POWERS["p_compute"] * prof.t_local)
    assert np.allclose(g.w_cloud, PAPER_POWERS["p_idle"] * prof.t_local / 2.0)


@pytest.mark.parametrize("omega", [0.0, 0.25, 0.7, 1.0])
def test_weighted_model_interpolates_smoke(omega):
    """Fixed-ω numpy fallback of the hypothesis property below."""
    prof = _profile()
    env = Environment.symmetric(bandwidth=1.5, speedup=3.0)
    gw = WeightedModel(omega).build(prof, env)
    gt = ResponseTimeModel().build(prof, env)
    ge = EnergyModel().build(prof, env)
    expect = (
        omega * gt.w_local / gt.w_local.sum()
        + (1 - omega) * ge.w_local / ge.w_local.sum()
    )
    assert np.allclose(gw.w_local, expect)


@given(st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_weighted_model_interpolates(omega):
    """Eq. 8: ω=1 → normalised time model; ω=0 → normalised energy model."""
    prof = _profile()
    env = Environment.symmetric(bandwidth=1.5, speedup=3.0)
    gw = WeightedModel(omega).build(prof, env)
    gt = ResponseTimeModel().build(prof, env)
    ge = EnergyModel().build(prof, env)
    t_norm = gt.w_local.sum()
    e_norm = ge.w_local.sum()
    expect = omega * gt.w_local / t_norm + (1 - omega) * ge.w_local / e_norm
    assert np.allclose(gw.w_local, expect)


def test_weighted_model_rejects_bad_omega():
    with pytest.raises(ValueError):
        WeightedModel(1.5)


def test_offloading_gain_definition():
    assert offloading_gain(10.0, 4.0) == pytest.approx(0.6)
    assert offloading_gain(0.0, 4.0) == 0.0


def test_gain_increases_with_bandwidth():
    """Fig. 19(a): offloading gain is non-decreasing in B."""
    prof = _profile(n=8, seed=3)
    model = ResponseTimeModel()
    gains = []
    for bw in [0.1, 0.5, 1.0, 3.0, 10.0, 100.0]:
        env = Environment.symmetric(bandwidth=bw, speedup=3.0)
        g = model.build(prof, env)
        res = mcop_reference(g)
        gains.append(offloading_gain(no_offloading(g).cost, res.min_cut))
    assert all(b >= a - 1e-9 for a, b in zip(gains, gains[1:]))
    assert gains[-1] > 0.0


def test_gain_increases_with_speedup():
    """Fig. 19(b): offloading gain is non-decreasing in F."""
    prof = _profile(n=8, seed=4)
    model = ResponseTimeModel()
    gains = []
    for f in [1.01, 1.5, 2.0, 4.0, 8.0, 32.0]:
        env = Environment.symmetric(bandwidth=3.0, speedup=f)
        g = model.build(prof, env)
        res = mcop_reference(g)
        gains.append(offloading_gain(no_offloading(g).cost, res.min_cut))
    assert all(b >= a - 1e-9 for a, b in zip(gains, gains[1:]))


def test_energy_model_gain_exceeds_time_gain_at_moderate_bandwidth():
    """Fig. 19: the energy objective typically benefits most (P_i ≪ P_m)."""
    prof = _profile(n=8, seed=5)
    env = Environment.symmetric(bandwidth=3.0, speedup=3.0)
    gt = ResponseTimeModel().build(prof, env)
    ge = EnergyModel().build(prof, env)
    gain_t = offloading_gain(no_offloading(gt).cost, mcop_reference(gt).min_cut)
    gain_e = offloading_gain(no_offloading(ge).cost, mcop_reference(ge).min_cut)
    assert gain_e >= gain_t - 1e-9


# ----------------------------------------------------------------------
# Adaptive controller (paper Fig. 1 workflow)
# ----------------------------------------------------------------------


def test_adaptive_controller_repartitions_on_drift():
    prof = _profile(n=8, seed=6)
    ctl = AdaptiveController(prof, ResponseTimeModel(), threshold=0.10)
    e1 = ctl.observe(Environment.symmetric(1.0, 3.0))
    assert e1.repartitioned  # first observation always partitions
    e2 = ctl.observe(Environment.symmetric(1.05, 3.0))
    assert not e2.repartitioned  # 5% drift < 10% threshold
    e3 = ctl.observe(Environment.symmetric(2.0, 3.0))
    assert e3.repartitioned  # 100% drift


def test_adaptive_controller_cooldown():
    prof = _profile(n=8, seed=7)
    ctl = AdaptiveController(
        prof, ResponseTimeModel(), threshold=0.01, min_interval=3
    )
    ctl.observe(Environment.symmetric(1.0, 3.0))
    e = ctl.observe(Environment.symmetric(5.0, 3.0))
    assert not e.repartitioned  # cooldown holds even though drift is huge
    ctl.observe(Environment.symmetric(5.0, 3.0))
    e = ctl.observe(Environment.symmetric(5.0, 3.0))
    assert e.repartitioned  # cooldown expired


def test_adaptive_partition_is_fresh_mcop_after_each_repartition():
    """After a repartition the controller's cost equals a fresh MCOP run
    (and respects the optimum as a lower bound — MCOP is heuristic)."""
    prof = _profile(n=7, seed=8)
    ctl = AdaptiveController(prof, ResponseTimeModel(), threshold=0.10)
    for bw in [0.2, 1.0, 5.0, 25.0]:
        ev = ctl.observe(Environment.symmetric(bw, 3.0))
        if ev.repartitioned:
            g = ResponseTimeModel().build(prof, ev.env)
            # controller applies the §4.3 "only when beneficial" clamp
            expect = min(mcop_reference(g).min_cut, no_offloading(g).cost)
            assert ev.partial_cost == pytest.approx(expect, rel=1e-9)
            assert ev.partial_cost >= brute_force(g).cost - 1e-9


def test_stale_partition_costs_reported_honestly():
    """When drift stays under threshold, the cost reported is the OLD
    placement re-priced at the NEW environment (the paper's online cost)."""
    prof = _profile(n=7, seed=9)
    ctl = AdaptiveController(prof, ResponseTimeModel(), threshold=0.5)
    ctl.observe(Environment.symmetric(1.0, 3.0))
    ev = ctl.observe(Environment.symmetric(1.3, 3.0))
    assert not ev.repartitioned
    g_new = ResponseTimeModel().build(prof, Environment.symmetric(1.3, 3.0))
    assert ev.partial_cost == pytest.approx(
        g_new.total_cost(ctl.placement.local_mask), rel=1e-12
    )
