"""Fused pricing/telemetry pipeline + weighted-fair broker scheduling.

Two acceptance gates live here:

* **Pricing parity** — :class:`~repro.core.pricing.PriceReport` numbers
  (and therefore every sweep/broker event) must equal the scalar
  ``_emit``-style path (``g.total_cost`` + §7.1 baselines +
  ``offloading_gain``) *bitwise*, across all Fig.-2 topologies × all
  three cost models.
* **Scheduler behavior** — deterministic WFQ rotation under asymmetric
  tenant weights, backpressure rejection past the queued-bin cap, and
  broker tick events bit-identical to the serial pricing path under the
  new scheduler.
"""

import numpy as np
import pytest

from repro.core import (
    AdaptiveController,
    AppProfile,
    EnergyModel,
    Environment,
    PlacementCache,
    ResponseTimeModel,
    WeightedModel,
    linear_graph,
    loop_graph,
    mesh_graph,
    mcop_reference,
    offloading_gain,
    price_batch,
    price_trace,
    random_wcg,
    tree_graph,
)
from repro.core import baselines
from repro.service import (
    OffloadBroker,
    QueueEntry,
    WeightedFairScheduler,
    run_workload,
)

pytestmark = pytest.mark.service

FIG2_TOPOLOGIES = {
    "linear": lambda: linear_graph(9, rng=np.random.default_rng(1)),
    "loop": lambda: loop_graph(8, rng=np.random.default_rng(2)),
    "tree": lambda: tree_graph(10, rng=np.random.default_rng(3)),
    "mesh": lambda: mesh_graph(3, 3, rng=np.random.default_rng(4)),
}

MODELS = {
    "time": ResponseTimeModel,
    "energy": EnergyModel,
    "weighted": lambda: WeightedModel(0.35),
}


def _envs(k: int = 7) -> list[Environment]:
    bands = np.geomspace(0.2, 20.0, k)
    return [
        Environment.symmetric(float(b), 1.5 + (i % 3)) for i, b in enumerate(bands)
    ]


# ----------------------------------------------------------------------
# Pricing parity: PriceReport ≡ scalar _emit numbers (bitwise)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("topology", sorted(FIG2_TOPOLOGIES))
@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_price_trace_matches_scalar_emit(topology, model_name):
    """One fused evaluation == K × (total_cost + no-offload + full-offload
    + gain), bit for bit — the numbers `_emit` used to compute per event."""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES[topology]())
    model = MODELS[model_name]()
    envs = _envs()
    rng = np.random.default_rng(9)
    trace = []
    for env in envs:
        mask = rng.integers(0, 2, profile.n).astype(bool) | ~profile.offloadable
        trace.append((env, mask))
    report = price_trace(profile, model, trace)
    assert len(report) == len(trace)
    for i, (env, mask) in enumerate(trace):
        g = model.build(profile, env)
        partial = g.total_cost(mask)
        no_off = baselines.no_offloading(g).cost
        full = baselines.full_offloading(g).cost
        # exact equality, not approx: the fused path IS the scalar path
        assert report.partial_cost[i] == partial
        assert report.no_offload_cost[i] == no_off
        assert report.full_offload_cost[i] == full
        assert report.gain[i] == offloading_gain(no_off, partial)
        assert report.row(i) == (partial, no_off, full, offloading_gain(no_off, partial))


def test_price_trace_empty_and_shape_validation():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    report = price_trace(profile, ResponseTimeModel(), [])
    assert len(report) == 0
    with pytest.raises(ValueError):
        price_trace(
            profile,
            ResponseTimeModel(),
            [(Environment.symmetric(1.0, 2.0), np.ones(3, bool))],
        )


def test_price_batch_is_a_pytree():
    import jax

    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["tree"]())
    model = ResponseTimeModel()
    envs = _envs(3)
    masks = np.ones((3, profile.n), dtype=bool)
    report = price_batch(model.build_batch(profile, envs), masks)
    leaves = jax.tree_util.tree_leaves(report)
    assert len(leaves) == 4
    doubled = jax.tree_util.tree_map(lambda a: a * 2.0, report)
    assert (np.asarray(doubled.partial_cost) == 2.0 * report.partial_cost).all()


@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_sweep_events_bitidentical_to_observe(model_name):
    """The rewritten sweep (one fused pricing evaluation) must emit events
    EQUAL to serial observe — stronger than the existing approx tests."""
    trace = [
        (8.0, 3.0), (7.6, 3.0), (1.2, 3.0), (1.1, 3.0), (0.3, 3.0),
        (0.3, 1.5), (6.0, 3.0), (8.0, 3.0), (1.2, 3.0), (0.3, 3.0),
    ]
    envs = [Environment.symmetric(b, f) for b, f in trace]
    g = random_wcg(8, rng=np.random.default_rng(3))
    prof = AppProfile.from_wcg_times(g)
    for cache in (None, "fresh"):
        mk = lambda: AdaptiveController(
            prof, MODELS[model_name](), threshold=0.15, min_interval=2,
            backend="reference",
            cache=PlacementCache() if cache else None,
        )
        serial, batched = mk(), mk()
        ev_s = [serial.observe(e) for e in envs]
        ev_b = batched.sweep(envs)
        for a, b in zip(ev_s, ev_b):
            assert a.partial_cost == b.partial_cost
            assert a.no_offload_cost == b.no_offload_cost
            assert a.full_offload_cost == b.full_offload_cost
            assert a.gain == b.gain
            assert a.result.min_cut == b.result.min_cut
            assert (a.result.local_mask == b.result.local_mask).all()
            assert (a.repartitioned, a.cache_hit) == (b.repartitioned, b.cache_hit)


# ----------------------------------------------------------------------
# WFQ scheduler: deterministic rotation, weights, backpressure
# ----------------------------------------------------------------------


def test_wfq_rotation_under_asymmetric_weights_is_deterministic():
    sched = WeightedFairScheduler()
    sched.ensure_tenant("heavy", weight=3.0)
    sched.ensure_tenant("light", weight=1.0)
    for i in range(8):
        sched.submit(QueueEntry("heavy", f"h{i}", bin_key=i))
        sched.submit(QueueEntry("light", f"l{i}", bin_key=i))
    order = [e.item for e in sched.drain(budget=12)]
    # 3:1 rotation, FIFO within a tenant, registration order across them
    assert order == [
        "h0", "h1", "h2", "l0",
        "h3", "h4", "h5", "l1",
        "h6", "h7", "l2",      # heavy runs dry → light drains its credit
        "l3",
    ]
    assert sched.pending == 4
    # the remainder drains FIFO once heavy is empty
    assert [e.item for e in sched.drain()] == ["l4", "l5", "l6", "l7"]
    assert sched.pending == 0


def test_wfq_fractional_weight_accumulates_deficit():
    sched = WeightedFairScheduler()
    sched.ensure_tenant("a", weight=1.0)
    sched.ensure_tenant("b", weight=0.5)
    for i in range(4):
        sched.submit(QueueEntry("a", f"a{i}", bin_key=i))
        sched.submit(QueueEntry("b", f"b{i}", bin_key=i))
    # b earns 0.5 credit per round: serves on every second round
    assert [e.item for e in sched.drain(budget=6)] == [
        "a0", "a1", "b0", "a2", "a3", "b1",
    ]


def test_wfq_budgeted_drains_do_not_starve_late_tenants():
    """The rotation cursor persists across drains: with 3 equal-weight
    tenants and budget=2, repeated ticks must serve all three evenly
    instead of restarting at registration order every time."""
    sched = WeightedFairScheduler()
    for t in ("a", "b", "c"):
        sched.ensure_tenant(t, weight=1.0)
        for i in range(4):
            sched.submit(QueueEntry(t, f"{t}{i}", bin_key=i))
    served: dict[str, int] = {"a": 0, "b": 0, "c": 0}
    while sched.pending:
        for e in sched.drain(budget=2):
            served[e.tenant] += 1
    assert served == {"a": 4, "b": 4, "c": 4}
    # and the per-drain interleaving is the persisted rotation
    for t in ("a", "b", "c"):
        for i in range(2):
            sched.submit(QueueEntry(t, f"{t}{i}", bin_key=i))
    first = [e.item for e in sched.drain(budget=2)]
    second = [e.item for e in sched.drain(budget=2)]
    third = [e.item for e in sched.drain(budget=2)]
    assert [first, second, third] == [["a0", "b0"], ["c0", "a1"], ["b1", "c1"]]


def test_wfq_priority_lane_preempts_and_requeue_preserves_order():
    sched = WeightedFairScheduler()
    sched.ensure_tenant("t", weight=1.0)
    sched.submit(QueueEntry("t", "u0", bin_key=0))
    sched.submit(QueueEntry("t", "e0", bin_key=0, lane="elastic"))
    sched.submit(QueueEntry("t", "u1", bin_key=1))
    drained = sched.drain()
    assert [e.item for e in drained] == ["e0", "u0", "u1"]
    sched.requeue(drained)
    assert [e.item for e in sched.drain()] == ["e0", "u0", "u1"]


def test_wfq_backpressure_counts_distinct_bins():
    sched = WeightedFairScheduler(max_queued_bins=2)
    assert sched.submit(QueueEntry("t", "a", bin_key="bin1"))
    assert sched.submit(QueueEntry("t", "b", bin_key="bin2"))
    # joining an existing bin is free (it coalesces)…
    assert sched.submit(QueueEntry("t", "c", bin_key="bin1"))
    # …but opening a third bin is rejected
    assert not sched.submit(QueueEntry("t", "d", bin_key="bin3"))
    # the priority lane is exempt
    assert sched.submit(QueueEntry("t", "e", bin_key="bin3", lane="elastic"))
    assert sched.queued_bins == 2
    sched.drain()
    assert sched.queued_bins == 0
    assert sched.submit(QueueEntry("t", "f", bin_key="bin3"))


def test_wfq_validation():
    with pytest.raises(ValueError):
        WeightedFairScheduler(quantum=0.0)
    with pytest.raises(ValueError):
        WeightedFairScheduler(max_queued_bins=0)
    sched = WeightedFairScheduler()
    with pytest.raises(KeyError):
        sched.set_weight("ghost", 2.0)
    sched.ensure_tenant("t")
    with pytest.raises(ValueError):
        sched.set_weight("t", -1.0)


# ----------------------------------------------------------------------
# Broker over the scheduler: budget shares, rejection futures, parity
# ----------------------------------------------------------------------


def _face_profile() -> AppProfile:
    from repro.core import face_recognition_graph

    return AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )


def test_broker_budgeted_tick_respects_weights():
    profile = _face_profile()
    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("heavy", profile, ResponseTimeModel(), weight=3.0)
    broker.register("light", profile, ResponseTimeModel(), weight=1.0)
    envs = [Environment.symmetric(0.2 * (i + 1), 3.0) for i in range(8)]
    futs = []
    for env in envs:
        futs.append(broker.submit("heavy", env))
        futs.append(broker.submit("light", env))
    report = broker.tick(budget=8)
    assert report.requests == 8 and report.queue_depth == 16
    assert dict(report.shares) == {"heavy": 6, "light": 2}
    assert broker.pending == 8
    report2 = broker.tick()  # no budget: drains the rest
    assert report2.requests == 8
    assert all(f.done for f in futs)


def test_broker_rejects_past_queued_bin_cap():
    profile = _face_profile()
    broker = OffloadBroker(
        backend="reference", clock=lambda: 0.0, max_queued_bins=2
    )
    broker.register("app", profile, ResponseTimeModel())
    ok1 = broker.submit("app", Environment.symmetric(8.0, 3.0))
    ok2 = broker.submit("app", Environment.symmetric(1.0, 3.0))
    # same bin as ok1 (within the 10% quantizer step): admitted, coalesces
    ok3 = broker.submit("app", Environment.symmetric(8.05, 3.0))
    rej = broker.submit("app", Environment.symmetric(0.1, 3.0))
    assert rej.done and rej.result.rejected and rej.result.result is None
    assert not any(f.done for f in (ok1, ok2, ok3))
    assert broker.queued_bins == 2
    report = broker.tick()
    assert report.rejected == 1 and report.requests == 3
    assert broker.telemetry.rejected_requests == 1
    assert "rejected_requests" in broker.telemetry.summary()
    assert ok3.result.coalesced and not ok3.result.rejected
    # a later tick reports no stale rejections, and the freed bins admit
    assert broker.tick().rejected == 0
    assert not broker.submit("app", Environment.symmetric(0.1, 3.0)).done


def test_session_survives_backpressure_rejection():
    """A rejected solve degrades the session step to a non-repartition
    (decision effects rolled back, current placement kept); a rejection
    before any placement exists raises instead of corrupting the loop."""
    from repro.service import BrokerSession

    profile = _face_profile()
    broker = OffloadBroker(
        backend="reference", clock=lambda: 0.0, max_queued_bins=1
    )
    broker.register("app", profile, ResponseTimeModel())
    session = BrokerSession(broker, "app", threshold=0.1, min_interval=1)
    session.observe(Environment.symmetric(8.0, 3.0))   # occupies the only bin
    other = BrokerSession(broker, "app", threshold=0.1, min_interval=1)
    with pytest.raises(RuntimeError, match="rejected the first placement"):
        other.observe(Environment.symmetric(1.0, 3.0))  # new bin, no fallback

    broker.tick()
    (first,) = session.drain()
    assert first.repartitioned
    # queue is empty again; install a placement, then overflow the cap
    session.observe(Environment.symmetric(1.0, 3.0))    # bin now queued
    session.observe(Environment.symmetric(0.2, 3.0))    # second bin: rejected
    broker.tick()
    events = session.drain()
    assert [e.repartitioned for e in events] == [True, False]
    # the rejected step kept (and repriced) the queued step's placement
    assert events[1].result is events[0].result
    # rollback means the drift detector retries: the next observation of
    # the same environment repartitions once capacity frees up
    session.observe(Environment.symmetric(0.2, 3.0))
    broker.tick()
    (retry,) = session.drain()
    assert retry.repartitioned


def test_broker_events_bitidentical_to_serial_under_scheduler():
    """Acceptance: tick events == serial pricing path, exactly."""
    profile = _face_profile()
    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("app", profile, ResponseTimeModel())
    report = run_workload(
        broker, "app", n_users=5, steps=8, threshold=0.15, min_interval=2, seed=13
    )
    cache = PlacementCache()
    ctls = [
        AdaptiveController(
            profile, ResponseTimeModel(), threshold=0.15, min_interval=2,
            backend="reference", cache=cache,
        )
        for _ in range(5)
    ]
    for t in range(8):
        for u, ctl in enumerate(ctls):
            ctl.observe(report.traces[u][t])
    for u, ctl in enumerate(ctls):
        assert len(ctl.history) == len(report.events[u])
        for a, b in zip(ctl.history, report.events[u]):
            assert a.partial_cost == b.partial_cost
            assert a.no_offload_cost == b.no_offload_cost
            assert a.full_offload_cost == b.full_offload_cost
            assert a.gain == b.gain
            assert a.result.min_cut == b.result.min_cut
            assert (a.result.local_mask == b.result.local_mask).all()


def test_broker_reply_min_cut_matches_reference_clamp():
    """Representative replies keep the solver's cut; hits/followers carry
    the repriced number — both equal to the reference pipeline."""
    profile = AppProfile.from_wcg_times(random_wcg(7, rng=np.random.default_rng(2)))
    model = ResponseTimeModel()
    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("app", profile, model)
    env = Environment.symmetric(2.0, 3.0)
    rep = broker.submit("app", env)
    fol = broker.submit("app", Environment.symmetric(2.02, 3.0))
    broker.tick()
    g = model.build(profile, env)
    expected = baselines.clamp_no_offloading(g, mcop_reference(g))
    assert rep.result.result.min_cut == expected.min_cut
    assert (rep.result.result.local_mask == expected.local_mask).all()
    g2 = model.build(profile, Environment.symmetric(2.02, 3.0))
    expected_f = baselines.reprice_clamped(g2, expected.local_mask)
    assert fol.result.result.min_cut == expected_f.min_cut
    assert (fol.result.result.local_mask == expected_f.local_mask).all()
