"""Paper §5.5 case study — the reproduction's ground truth.

The paper prints, for its 6-vertex example, every phase's induced vertex
ordering, itemised cut value (Figs. 6–10), and the optimal partition
{a, c} local / {b, d, e, f} cloud at cost 22 (Fig. 11, confirmed again by
the GUI run in Fig. 16).  These tests assert all of it, phase by phase.
"""

import numpy as np
import pytest

from repro.core import (
    WCG,
    brute_force,
    branch_and_bound,
    chain_dp,
    face_recognition_graph,
    full_offloading,
    linear_graph,
    maxflow_optimal,
    mcop_jax,
    mcop_reference,
    no_offloading,
    paper_example_graph,
)
from repro.kernels import mcop_min_cut


@pytest.fixture(scope="module")
def g():
    return paper_example_graph()


def test_local_cost_total_is_45(g):
    assert g.local_cost_total == 45.0


def test_phase_cut_values_match_figs_6_to_10(g):
    result = mcop_reference(g)
    cuts = [ph.cut_value for ph in result.phases]
    assert cuts == [40.0, 35.0, 29.0, 22.0, 27.0]


def test_phase1_induced_ordering_matches_fig6(g):
    result = mcop_reference(g)
    assert result.phases[0].order == ["a", "c", "b", "e", "d", "f"]
    assert result.phases[0].s == "d"
    assert result.phases[0].t == "f"


def test_phase_orderings_match_figs_7_to_10(g):
    result = mcop_reference(g)
    assert result.phases[1].order == ["a", "c", "b", "e", "{df}"]
    assert result.phases[2].order == ["a", "c", "b", "{def}"]
    assert result.phases[3].order == ["a", "c", "{bdef}"]
    assert result.phases[4].order == ["a", "{bcdef}"]


def test_optimal_cut_is_22_between_ac_and_bdef(g):
    result = mcop_reference(g)
    assert result.min_cut == 22.0
    local = {g.names[i] for i in result.local_indices}
    cloud = {g.names[i] for i in result.cloud_indices}
    assert local == {"a", "c"}
    assert cloud == {"b", "d", "e", "f"}


def test_total_cost_of_optimal_placement_equals_cut_value(g):
    result = mcop_reference(g)
    assert g.total_cost(result.local_mask) == pytest.approx(result.min_cut)


def test_gui_comparison_costs(g):
    """Fig. 15/16: partial vs no-offloading vs full-offloading costs."""
    no = no_offloading(g)
    full = full_offloading(g)
    part = mcop_reference(g)
    assert no.cost == 45.0
    assert part.min_cut == 22.0
    assert part.min_cut < full.cost  # partial beats full offloading here
    assert part.min_cut < no.cost


def test_all_backends_agree_on_paper_example(g):
    ref = mcop_reference(g)
    jx = mcop_jax(g)
    bf = brute_force(g)
    mf = maxflow_optimal(g)
    bb = branch_and_bound(g)
    kcut, kmask = mcop_min_cut(g.adj, g.w_local, g.w_cloud, g.offloadable)
    for cost in (jx.min_cut, bf.cost, mf.cost, bb.cost, kcut):
        assert cost == pytest.approx(22.0)
    assert (kmask == ref.local_mask).all()
    assert (bf.local_mask == ref.local_mask).all()


def test_unoffloadable_vertex_always_local(g):
    result = mcop_reference(g)
    g.validate_placement(result.local_mask)  # raises if 'a' went to cloud


def test_face_recognition_graph_partitions_sensibly():
    """§7.2: F=2, B=1 MB/s; main and checkAgainst stay local."""
    g = face_recognition_graph(speedup=2.0, bandwidth_mbps=1.0)
    res = mcop_reference(g)
    names_local = {g.names[i] for i in res.local_indices}
    assert "main" in names_local and "checkAgainst" in names_local
    # optimality vs oracle
    assert res.min_cut == pytest.approx(brute_force(g).cost)
    # higher bandwidth must not increase the optimal cost
    g_fast = face_recognition_graph(speedup=2.0, bandwidth_mbps=8.0)
    res_fast = mcop_reference(g_fast)
    assert res_fast.min_cut <= res.min_cut + 1e-9


def test_chain_dp_matches_brute_on_linear():
    g = linear_graph(8, rng=np.random.default_rng(3))
    assert chain_dp(g).cost == pytest.approx(brute_force(g).cost)
