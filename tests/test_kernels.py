"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import brute_force, mcop_reference, paper_example_graph, random_wcg
from repro.kernels import flash_attention, mamba_chunk_scan, mcop_min_cut, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.mcop_phase import mcop_phase_kernel


# ----------------------------------------------------------------------
# Flash attention
# ----------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, Hkv, Sq, Sk, hd, causal, window, dtype, block)
    (1, 2, 2, 16, 16, 8, True, None, jnp.float32, 8),
    (2, 4, 2, 33, 47, 16, True, None, jnp.float32, 16),
    (2, 4, 1, 40, 40, 32, True, 8, jnp.float32, 16),
    (1, 8, 8, 64, 64, 64, False, None, jnp.float32, 32),
    (1, 4, 2, 128, 128, 16, True, None, jnp.bfloat16, 64),
    (3, 2, 2, 17, 63, 8, False, 16, jnp.float32, 16),
    (1, 16, 4, 96, 96, 128, True, None, jnp.float32, 32),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(i) for i in range(len(FLASH_CASES))])
def test_flash_attention_matches_reference(case):
    b, h, hkv, sq, sk, hd, causal, window, dtype, blk = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, h, sq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, hd)), dtype)
    out = flash_attention_kernel(
        q, k, v, causal=causal, window=window, block_q=blk, block_k=blk
    )
    exp = ref.flash_reference(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_model_layout_wrapper():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 24, 4, 16)), jnp.float32)  # (B,S,H,hd)
    k = jnp.asarray(rng.normal(size=(2, 24, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 24, 2, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    exp = ref.flash_reference(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_flash_matches_model_chunked_attention():
    """The kernel agrees with the model-side jnp online-softmax path too."""
    from repro.models.attention import chunked_attention

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 32, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    b = chunked_attention(q, k, v, mask_kind="causal", chunk_q=8, chunk_k=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------
# Mamba chunk scan
# ----------------------------------------------------------------------

MAMBA_CASES = [
    # (B, S, H, P, N, chunk)
    (1, 8, 1, 4, 2, 4),
    (2, 32, 3, 8, 4, 8),
    (1, 64, 2, 16, 16, 16),
    (2, 24, 4, 8, 8, 24),      # single chunk
    (1, 128, 1, 32, 8, 32),
]


@pytest.mark.parametrize("case", MAMBA_CASES, ids=[str(i) for i in range(len(MAMBA_CASES))])
def test_mamba_chunk_scan_matches_token_recurrence(case):
    b, s, h, p, n, chunk = case
    rng = np.random.default_rng(hash(case) % 2**31)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 1.0, size=(b, s, h)), jnp.float32)
    ld = -jnp.asarray(rng.uniform(0.01, 0.8, size=(b, s, h)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, h, p, n)), jnp.float32)
    y, hT = mamba_chunk_scan(x, dt, ld, bm, cm, h0, chunk=chunk)
    nc = s // min(chunk, s)
    q = s // nc
    yr, hr = ref.mamba_chunk_scan_reference(
        x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4),
        dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2),
        ld.reshape(b, nc, q, h).transpose(0, 3, 1, 2),
        bm.reshape(b, nc, q, n),
        cm.reshape(b, nc, q, n),
        h0,
    )
    yr = yr.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr), atol=1e-4, rtol=1e-4)


def test_mamba_kernel_matches_model_ssd_path():
    """Kernel output == the model's chunked SSD math for one layer core."""
    from repro.configs import ARCHITECTURES, reduce_config
    from repro.models import ssm

    cfg = reduce_config(ARCHITECTURES["zamba2-1.2b"])
    p = ssm.init_mamba2(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y_model, st_model = ssm.mamba2_forward(cfg, p, x)

    # recompute through the kernel using the same projections
    z, xbc, dt_raw = ssm._mamba_project(cfg, p, x)
    xbc, conv_state = ssm._causal_conv(p, xbc, None, valid_len=x.shape[1])
    xs, bmat, cmat = ssm._split_xbc(cfg, xbc)
    d_inner, n_heads, n_state = ssm._mamba_dims(cfg)
    hd = cfg.mamba_headdim
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    ld = dt * a
    xh = xs.reshape(2, 32, n_heads, hd)
    h0 = jnp.zeros((2, n_heads, hd, n_state), jnp.float32)
    y, hT = mamba_chunk_scan(xh, dt, ld, bmat, cmat, h0, chunk=cfg.ssm_chunk)
    y = y + np.asarray(p["d_skip"])[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(2, 32, d_inner).astype(x.dtype)
    from repro.models import common

    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    y = common.linear(p["out_proj"], y)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_model, np.float32), atol=2e-4, rtol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(hT), np.asarray(st_model.h), atol=1e-4, rtol=1e-4
    )


# ----------------------------------------------------------------------
# MCOP phase kernel
# ----------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_mcop_phase_kernel_matches_reference(seed):
    g = random_wcg(9, rng=np.random.default_rng(seed))
    gains = g.w_local - g.w_cloud
    alive = np.ones(g.n, bool)
    src = int(np.nonzero(~g.offloadable)[0][0])
    cut_k, s_k, t_k = mcop_phase_kernel(
        jnp.asarray(g.adj, jnp.float32), gains, alive, src, g.w_local.sum()
    )
    cut_r, s_r, t_r = ref.mcop_phase_reference(
        g.adj, gains, alive, src, g.w_local.sum()
    )
    assert float(cut_k) == pytest.approx(cut_r, rel=1e-5)
    assert (int(s_k), int(t_k)) == (s_r, t_r)


@pytest.mark.parametrize("n,seed", [(5, 0), (8, 1), (12, 2), (15, 3), (10, 4)])
def test_mcop_kernel_full_algorithm_matches_reference(n, seed):
    """The kernel-backed MCOP is the SAME algorithm as mcop_reference —
    same (possibly suboptimal, see test_mcop_property) cut, same mask."""
    g = random_wcg(n, rng=np.random.default_rng(seed + 100))
    cut, mask = mcop_min_cut(g.adj, g.w_local, g.w_cloud, g.offloadable)
    ref_res = mcop_reference(g)
    assert cut == pytest.approx(ref_res.min_cut, rel=1e-5)
    assert (mask == ref_res.local_mask).all()
    assert g.total_cost(mask) == pytest.approx(cut, rel=1e-5)
    # never better than the true optimum (up to the kernel's f32 rounding)
    assert cut >= brute_force(g).cost * (1 - 1e-5) - 1e-4


def test_mcop_kernel_paper_example():
    g = paper_example_graph()
    cut, mask = mcop_min_cut(g.adj, g.w_local, g.w_cloud, g.offloadable)
    assert cut == pytest.approx(22.0)
    assert (mask == mcop_reference(g).local_mask).all()


# ----------------------------------------------------------------------
# Interpret-mode selection
# ----------------------------------------------------------------------


def test_default_interpret_env_override(monkeypatch):
    """REPRO_PALLAS_INTERPRET forces/suppresses interpret mode without
    code edits (the TPU-validation knob); unset falls back to backend
    detection, garbage raises."""
    from repro.kernels import ops

    try:
        for raw, want in [
            ("1", True), ("true", True), ("YES", True), (" on ", True),
            ("0", False), ("false", False), ("No", False), ("off", False),
        ]:
            monkeypatch.setenv("REPRO_PALLAS_INTERPRET", raw)
            ops.default_interpret.cache_clear()
            assert ops.default_interpret() is want, raw

        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "maybe")
        ops.default_interpret.cache_clear()
        with pytest.raises(ValueError):
            ops.default_interpret()

        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        ops.default_interpret.cache_clear()
        assert ops.default_interpret() is (not ops.on_tpu())
    finally:
        ops.default_interpret.cache_clear()


# ----------------------------------------------------------------------
# Compiled (non-interpret) tier + blocked grid + fused build+solve
# ----------------------------------------------------------------------


def _sw_batch(b=10, n=9, seed=42):
    rng = np.random.default_rng(seed)
    graphs = [random_wcg(n, rng=rng) for _ in range(b)]
    adj = np.stack([g.adj for g in graphs]).astype(np.float32)
    wl = np.stack([g.w_local for g in graphs]).astype(np.float32)
    wc = np.stack([g.w_cloud for g in graphs]).astype(np.float32)
    pin = np.stack([~g.offloadable for g in graphs])
    return graphs, adj, wl, wc, pin


def test_mcop_kernel_compiled_noninterpret_path(monkeypatch):
    """REPRO_PALLAS_INTERPRET=0 routes the batch kernel through the real
    Pallas compile pipeline.  Platforms whose backend cannot lower the
    kernel (CPU: "Only interpret mode is supported") skip with that
    reason — on TPU this test runs the compiled tier for real and pins
    it to the interpret tier bitwise."""
    from repro.kernels import ops
    from repro.kernels.mcop_phase import mcop_stoer_wagner_kernel

    _, adj, wl, wc, pin = _sw_batch()
    cuts_i, masks_i = mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=True)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    ops.default_interpret.cache_clear()
    try:
        assert ops.default_interpret() is False
        try:
            cuts_c, masks_c = mcop_stoer_wagner_kernel(adj, wl, wc, pin)
            cuts_c = np.asarray(cuts_c)
        except Exception as e:  # noqa: BLE001 — platform refusal, not a bug
            pytest.skip(f"compiled Pallas unavailable on this platform: {e}")
        assert np.array_equal(cuts_c, np.asarray(cuts_i))
        assert np.array_equal(np.asarray(masks_c), np.asarray(masks_i))
    finally:
        ops.default_interpret.cache_clear()


def test_mcop_kernel_block_graphs_bitwise_invariant():
    """The blocked grid (g graphs per program instance) is a pure
    scheduling choice: g=1, g=3 (forces tail padding on b=10) and the
    auto choice must produce bit-identical cuts and masks, all matching
    the numpy oracle."""
    from repro.kernels.mcop_phase import (
        default_block_graphs,
        mcop_stoer_wagner_kernel,
    )

    graphs, adj, wl, wc, pin = _sw_batch()
    runs = {}
    for g in (1, 3, None):
        cuts, masks = mcop_stoer_wagner_kernel(
            adj, wl, wc, pin, interpret=True, block_graphs=g
        )
        runs[g] = (np.asarray(cuts), np.asarray(masks))
    base_cuts, base_masks = runs[1]
    for g in (3, None):
        assert np.array_equal(runs[g][0], base_cuts), g
        assert np.array_equal(runs[g][1], base_masks), g
    for i, wcg in enumerate(graphs):
        assert base_cuts[i] == pytest.approx(
            mcop_reference(wcg).min_cut, rel=1e-5
        )
    assert default_block_graphs(16, True) == 1  # interpret stays g=1


def test_mcop_kernel_block_graphs_env_override(monkeypatch):
    from repro.kernels.mcop_phase import default_block_graphs

    monkeypatch.setenv("REPRO_MCOP_BLOCK_GRAPHS", "4")
    assert default_block_graphs(16, True) == 4
    monkeypatch.setenv("REPRO_MCOP_BLOCK_GRAPHS", "0")
    with pytest.raises(ValueError):
        default_block_graphs(16, True)


def test_fused_kernel_solve_envs_parity():
    """backend="pallas_fused" (in-kernel WCG weight build) must agree
    with the host-build "jax" path: identical masks, cut values equal to
    f32 reassociation tolerance, across all three cost-model kinds."""
    from repro.core import (
        AppProfile,
        EnergyModel,
        ResponseTimeModel,
        WeightedModel,
        linear_graph,
    )
    from repro.core.cost_models import EnvArrays
    from repro.core.mcop import solve_envs

    rng = np.random.default_rng(6)
    profile = AppProfile.from_wcg_times(linear_graph(9, rng=rng))
    envs = EnvArrays(*(rng.uniform(0.5, 5.0, 7) for _ in range(6)))
    for model in (ResponseTimeModel(), EnergyModel(), WeightedModel(0.35)):
        fused = solve_envs(profile, model, envs, backend="pallas_fused")
        plain = solve_envs(profile, model, envs, backend="jax")
        for rf, rp in zip(fused, plain):
            assert np.array_equal(rf.local_mask, rp.local_mask), model
            assert rf.min_cut == pytest.approx(rp.min_cut, rel=1e-6), model


def test_fused_kernel_rejects_unknown_model_kind():
    from repro.core import AppProfile, linear_graph
    from repro.core.cost_models import CostModel, EnvArrays
    from repro.core.mcop import solve_envs

    class Exotic(CostModel):
        name = "exotic"

        @property
        def fingerprint(self):
            return ("exotic",)

        def weights(self, graph, env):  # pragma: no cover - never called
            raise NotImplementedError

        def batch_weights(self, t_local, data_in, data_out, env):
            raise NotImplementedError  # pragma: no cover

    rng = np.random.default_rng(6)
    profile = AppProfile.from_wcg_times(linear_graph(6, rng=rng))
    envs = EnvArrays(*(rng.uniform(0.5, 5.0, 3) for _ in range(6)))
    with pytest.raises(ValueError, match="exotic"):
        solve_envs(profile, Exotic(), envs, backend="pallas_fused")
