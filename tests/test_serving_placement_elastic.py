"""Serving engine, placement mapper, elastic manager, compression wire math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, SHAPES, reduce_config
from repro.configs.base import ShapeConfig
from repro.core.placement import (
    StageSpec,
    TierSpec,
    TPUV5E_TIER,
    build_stage_wcg,
    plan_placement,
)
from repro.core import brute_force
from repro.models.transformer import build_model
from repro.profilers.program import app_profile_from_config, stage_specs
from repro.runtime import (
    ElasticMeshManager,
    HeartbeatMonitor,
    init_compression_state,
    int8_compress,
    int8_decompress,
    topk_compress_with_ef,
    wire_bytes,
)
from repro.serving import ServingConfig, ServingEngine


# ----------------------------------------------------------------------
# Serving engine
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduce_config(ARCHITECTURES["qwen2-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_serves_more_requests_than_slots(engine_setup):
    cfg, model, params = engine_setup
    eng = ServingEngine(model, params,
                        ServingConfig(max_batch=2, max_prompt_len=8, max_len=24))
    for i in range(5):
        eng.submit(np.arange(1, 4 + (i % 3)), max_new_tokens=4)
    out = eng.run_to_completion()
    assert len(out) == 5
    assert all(len(v) == 4 for v in out.values())


def test_engine_greedy_is_deterministic(engine_setup):
    cfg, model, params = engine_setup

    def run():
        eng = ServingEngine(model, params,
                            ServingConfig(max_batch=2, max_prompt_len=8, max_len=20))
        eng.submit(np.array([5, 6, 7]), max_new_tokens=6)
        return eng.run_to_completion()[0]

    assert run() == run()


def test_engine_eos_stops_early(engine_setup):
    cfg, model, params = engine_setup
    eng = ServingEngine(model, params,
                        ServingConfig(max_batch=1, max_prompt_len=8, max_len=40))
    # find the greedy first token, then use it as eos
    uid = eng.submit(np.array([3, 1, 4]), max_new_tokens=4)
    first = eng.run_to_completion()[uid][0]
    eng2 = ServingEngine(model, params,
                         ServingConfig(max_batch=1, max_prompt_len=8, max_len=40))
    uid2 = eng2.submit(np.array([3, 1, 4]), max_new_tokens=16, eos_id=int(first))
    out = eng2.run_to_completion()[uid2]
    assert len(out) == 1 and out[0] == first


# ----------------------------------------------------------------------
# Placement mapper + program profiler
# ----------------------------------------------------------------------


def _tiers(local_chips=64, remote_chips=192):
    return (
        dataclasses.replace(TPUV5E_TIER, name="local", chips=local_chips),
        dataclasses.replace(TPUV5E_TIER, name="remote", chips=remote_chips),
    )


def test_stage_wcg_pins_and_prices(engine_setup):
    cfg, _, _ = engine_setup
    full = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(full, SHAPES["train_4k"], group=4)
    tl, tr = _tiers()
    g = build_stage_wcg(stages, tl, tr)
    assert g.n == len(stages)
    assert not g.offloadable[0]            # embed pinned local
    assert (g.w_local > 0).all() and (g.w_cloud > 0).all()
    # remote tier has 3× chips ⇒ cloud cost lower
    assert (g.w_cloud[1:-1] < g.w_local[1:-1]).all()


def test_plan_placement_contiguity_penalty_nonnegative():
    full = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(full, SHAPES["train_4k"], group=4)
    tl, tr = _tiers()
    plan = plan_placement(stages, tl, tr)
    assert plan.contiguity_penalty >= -1e-9
    assert plan.contiguous_cost >= plan.mcop_cost - 1e-9


def test_plan_placement_exact_mode_matches_brute_force():
    full = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(full, SHAPES["decode_32k"], group=7)
    tl, tr = _tiers()
    plan = plan_placement(stages, tl, tr, exact=True)
    g = build_stage_wcg(stages, tl, tr)
    assert plan.mcop_cost == pytest.approx(brute_force(g).cost, rel=1e-9)
    # MCOP itself agrees (it is exact too)
    plan2 = plan_placement(stages, tl, tr)
    assert plan2.mcop_cost == pytest.approx(plan.mcop_cost, rel=1e-9)


def test_fat_link_offloads_slim_link_stays_local():
    """The paper's core claim at system scale: placement follows bandwidth."""
    full = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(full, SHAPES["train_4k"], group=4)
    tl, tr = _tiers(local_chips=16, remote_chips=240)

    fat = plan_placement(stages, tl, tr, inter_tier_bw=1e15)
    # with free comm and a 15× faster remote tier, everything offloadable moves
    assert fat.stage_tier[1:].sum() >= len(stages) - 2

    slim = plan_placement(stages, tl, tr, inter_tier_bw=1.0)  # 1 B/s
    assert slim.stage_tier.sum() == 0  # nothing crosses a dead link


def test_app_profile_from_config_shapes():
    full = ARCHITECTURES["deepseek-v2-236b"]
    prof = app_profile_from_config(full, SHAPES["train_4k"], group=10)
    assert prof.n == 2 + full.n_layers // 10
    assert prof.t_local.min() > 0
    assert not prof.offloadable[0]


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_stage_specs_for_every_arch_and_shape(arch):
    cfg = ARCHITECTURES[arch]
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.supports_long_context:
            continue
        stages = stage_specs(cfg, shape, group=max(cfg.n_layers // 4, 1))
        assert all(s.flops > 0 for s in stages)
        assert all(s.bytes_hbm > 0 for s in stages)
        tl, tr = _tiers()
        plan = plan_placement(stages, tl, tr)
        assert np.isfinite(plan.mcop_cost)


# ----------------------------------------------------------------------
# Elastic manager / heartbeat monitor
# ----------------------------------------------------------------------


def test_heartbeat_failure_and_straggler_detection():
    t = [0.0]
    mon = HeartbeatMonitor(range(4), deadline=10.0, clock=lambda: t[0])
    for d in range(4):
        mon.heartbeat(d, step_time=1.0)
    # device 3 goes silent; device 2 slows to 4× median
    for _ in range(6):
        t[0] += 5.0
        for d in (0, 1):
            mon.heartbeat(d, step_time=1.0)
        mon.heartbeat(2, step_time=4.0)
    assert mon.failed() == [3]
    assert mon.stragglers() == [2]
    assign = mon.reassignment(9)
    assert sum(assign.values()) == 9
    assert assign[3] == 0
    assert assign[2] < assign[0]


def test_reassignment_fails_with_no_devices():
    t = [0.0]
    mon = HeartbeatMonitor([0], deadline=1.0, clock=lambda: t[0])
    mon.mark_failed(0)
    with pytest.raises(RuntimeError):
        mon.reassignment(4)


def test_elastic_resize_triggers_repartition():
    full = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(full, SHAPES["train_4k"], group=4)
    tl, tr = _tiers(local_chips=128, remote_chips=128)
    mgr = ElasticMeshManager(stages, tl, tr)
    before = mgr.plan.stage_tier.copy()
    assert mgr.speedup == pytest.approx(1.0)
    # remote pod loses 7/8 of its chips → F crashes → work moves local
    ev = mgr.resize(step=100, remote_chips=16, reason="failure")
    assert ev.plan.stage_tier.sum() <= before.sum()
    assert mgr.speedup == pytest.approx(16 / 128)
    # scale the remote pod way up → offload again
    ev2 = mgr.resize(step=200, remote_chips=512, reason="scale_up")
    assert ev2.plan.stage_tier.sum() >= ev.plan.stage_tier.sum()
    assert len(mgr.events) == 2


def test_elastic_total_chip_loss_raises():
    full = ARCHITECTURES["qwen2-7b"]
    stages = stage_specs(full, SHAPES["train_4k"], group=8)
    tl, tr = _tiers()
    mgr = ElasticMeshManager(stages, tl, tr)
    with pytest.raises(RuntimeError):
        mgr.resize(step=1, remote_chips=0)


# ----------------------------------------------------------------------
# Compression
# ----------------------------------------------------------------------


def test_topk_error_feedback_conserves_signal():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    st = init_compression_state(g)
    sent, st = topk_compress_with_ef(g, st, frac=0.1)
    # sent + residual == original (nothing lost, only deferred)
    np.testing.assert_allclose(
        np.asarray(sent["w"]) + np.asarray(st.residual["w"]),
        np.asarray(g["w"]),
        atol=1e-6,
    )
    nz = int((np.asarray(sent["w"]) != 0).sum())
    assert nz <= int(64 * 64 * 0.1) + 1


def test_topk_residual_flushes_over_steps():
    """Repeatedly compressing the same grad eventually transmits everything."""
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(32, 32)), jnp.float32)}
    st = init_compression_state(g)
    total = np.zeros((32, 32), np.float32)
    for _ in range(30):
        sent, st = topk_compress_with_ef(g, st, frac=0.1)
        total += np.asarray(sent["w"])
    # total transmitted ≈ 30 × g − residual; residual stays bounded
    resid = np.abs(np.asarray(st.residual["w"])).max()
    assert resid < 30 * np.abs(np.asarray(g["w"])).max()
    np.testing.assert_allclose(
        total + np.asarray(st.residual["w"]), 30 * np.asarray(g["w"]), rtol=1e-4, atol=1e-3
    )


def test_int8_quantization_unbiased():
    g = {"w": jnp.asarray(np.random.default_rng(2).normal(size=(256,)), jnp.float32)}
    acc = np.zeros(256, np.float32)
    n = 64
    for i in range(n):
        q8, sc = int8_compress(g, jax.random.PRNGKey(i))
        acc += np.asarray(int8_decompress(q8, sc)["w"])
    err = np.abs(acc / n - np.asarray(g["w"])).max()
    scale = float(np.abs(np.asarray(g["w"])).max()) / 127
    assert err < 3 * scale / np.sqrt(n) + 1e-4   # CLT bound on SR noise


def test_wire_bytes_accounting():
    g = {"w": jnp.zeros((1000,), jnp.float32)}
    assert wire_bytes(g, scheme="none") == 2000           # bf16 dense
    assert wire_bytes(g, scheme="int8") == 1000 + 4
    assert wire_bytes(g, scheme="topk", frac=0.01) == 60  # 10 × (4+2)


def test_weighted_model_placement_on_stage_graph():
    """Integration: program profiler → ω-weighted cost model → MCOP →
    the same invariants the paper's GUI demonstrates, on a real arch."""
    from repro.core import (
        EnergyModel,
        Environment,
        ResponseTimeModel,
        WeightedModel,
        mcop_reference,
        no_offloading,
    )
    from repro.profilers.program import app_profile_from_config

    cfg = ARCHITECTURES["qwen3-32b"]
    prof = app_profile_from_config(cfg, SHAPES["train_4k"], group=16)
    env = Environment.symmetric(bandwidth=50e9, speedup=3.0)
    costs = {}
    for model in (ResponseTimeModel(), EnergyModel(), WeightedModel(0.5)):
        g = model.build(prof, env)
        res = mcop_reference(g)
        costs[model.name] = min(res.min_cut, no_offloading(g).cost)
        assert np.isfinite(costs[model.name])
        g.validate_placement(res.local_mask)
    # ω=0.5 weighted cost is normalised: between 0 and ~1 for sane envs
    assert 0.0 < costs["weighted"] <= 1.0 + 1e-9


def test_flash_decode_flag_safe_for_mla_and_ring_archs():
    """decode_flash only rewires the plain-GQA path; MLA (deepseek) and
    ring-window (zamba) decode must be unaffected and finite."""
    from repro.models import attention as attn_lib
    from repro.models.transformer import build_model

    for arch in ("deepseek-v2-236b", "zamba2-1.2b"):
        cfg = reduce_config(ARCHITECTURES[arch])
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cache = m.init_cache(1, 12)
        _, cache = m.prefill(params, {"tokens": jnp.ones((1, 4), jnp.int32)}, cache)
        attn_lib.set_decode_flash_partitioning(True)
        try:
            logits, _ = m.decode_step(params, jnp.ones((1, 1), jnp.int32), cache)
        finally:
            attn_lib.set_decode_flash_partitioning(False)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
