"""Observability plane: metrics, tracing, and the two hard contracts.

The PR-8 acceptance suite.  The headline contracts:

* a broker with no tracer/registry attached is **bit-identical** to the
  pre-observability code — replies, workload events, telemetry — across
  the Fig.-2 topologies × three cost models, with and without a fault
  storm (the instrumented call sites receive shared null objects and
  never read a clock);
* with instruments attached, the ``BrokerTelemetry`` fields and their
  mirrored registry counters can never disagree (seeded on bind), and
  every ``degraded`` event in an exported trace is attributable to a
  same-tick ``fault`` event — the ``tools/tracequery.py --audit`` CI
  gate, exercised here end to end through a scripted fault schedule.

The enabled-path throughput budget (1.15× of detached) is gated in
``benchmarks/broker.py`` (``broker/traced_*``), not here — wall-clock
ratios don't belong in tier-1.
"""

import dataclasses
import importlib.util
import json
import pathlib

import numpy as np
import pytest

from repro.core import (
    AppProfile,
    Environment,
    PlacementCache,
    ResponseTimeModel,
)
from repro.core.cost_models import EnvArrays
from repro.obs import MetricsRegistry, Tracer
from repro.obs.metrics import (
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
    Histogram,
)
from repro.obs.trace import NULL_SPAN
from repro.service import (
    CircuitBreaker,
    FaultInjector,
    InjectedClock,
    ScriptedFaultInjector,
    run_workload,
    user_traces,
)
from tests.test_faults import (
    FIG2_TOPOLOGIES,
    MODELS,
    _broker,
    _env,
    _policy,
    _profile,
    _reply_tuple,
)

pytestmark = pytest.mark.service

_TOOLS = pathlib.Path(__file__).resolve().parent.parent / "tools"


def _load_tool(name: str):
    """Import a ``tools/`` script (not a package) by file path."""
    spec = importlib.util.spec_from_file_location(name, _TOOLS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ----------------------------------------------------------------------
# Metrics: instruments, quantiles, merge, disabled mode
# ----------------------------------------------------------------------


def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs")
    c.inc()
    c.inc(2)
    assert reg.value("reqs") == 3
    assert reg.counter("reqs") is c  # get-or-create
    assert reg.counter("reqs", tenant="a") is not c  # labels split series
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth", tenant="a")
    g.set(5)
    g.add(-2)
    assert reg.value("depth", tenant="a") == 3
    assert reg.value("absent", default=7.5) == 7.5


def test_histogram_quantiles_bracket_observations():
    h = Histogram("lat")
    values = [10e-6 * (1.3**i) for i in range(60)]  # ~10µs .. ~53s: in range
    h.observe_many(values)
    assert h.count == 60
    assert (h.min, h.max) == (values[0], values[-1])
    exact = sorted(values)
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        true = exact[min(int(q * len(exact)), len(exact) - 1)]
        # growth-2 buckets: the estimate lands within one bucket (2x)
        assert true / 2 <= est <= true * 2
    # a single observation reports itself at every quantile (clamping)
    one = Histogram("one")
    one.observe(0.25)
    assert one.p50 == one.p90 == one.p99 == 0.25
    # out-of-range values land in under/overflow, quantiles stay clamped
    wide = Histogram("wide")
    wide.observe_many([1e-9, 1e9])
    assert wide.underflow == 1 and wide.overflow == 1
    assert 1e-9 <= wide.p50 <= 1e9


def test_histogram_merge_equals_union_and_rejects_geometry_mismatch():
    a, b, union = Histogram("x"), Histogram("x"), Histogram("x")
    va, vb = [1e-5, 3e-4, 0.02], [7e-3, 0.5, 4.0]
    a.observe_many(va)
    b.observe_many(vb)
    union.observe_many(va + vb)
    a.merge(b)
    assert a.counts == union.counts
    assert (a.count, a.sum, a.min, a.max) == (
        union.count, union.sum, union.min, union.max,
    )
    assert a.p50 == union.p50 and a.p99 == union.p99
    with pytest.raises(ValueError):
        a.merge(Histogram("x", growth=10.0, n_buckets=8))


def test_disabled_registry_hands_out_shared_nulls():
    reg = MetricsRegistry(enabled=False)
    assert reg.counter("c") is NULL_COUNTER
    assert reg.gauge("g") is NULL_GAUGE
    assert reg.histogram("h") is NULL_HISTOGRAM
    reg.counter("c").inc(5)
    reg.gauge("g").set(9)
    reg.histogram("h").observe(1.0)
    with reg.timer("t"):
        pass
    assert NULL_COUNTER.value == 0
    assert NULL_GAUGE.value == 0
    assert NULL_HISTOGRAM.count == 0
    assert reg.snapshot() == {"counters": [], "gauges": [], "histograms": []}


def test_timer_charges_injected_clock_delta():
    clock = InjectedClock()
    reg = MetricsRegistry(clock=clock)
    with reg.timer("dur", stage="solve"):
        clock.advance(0.125)
    h = reg.get_histogram("dur", stage="solve")
    assert h.count == 1 and h.sum == 0.125
    assert h.p50 == 0.125  # clamped to the single observation


def test_registry_merge_is_fleet_aggregation():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("reqs").inc(3)
    b.counter("reqs").inc(4)
    b.counter("only_b", tenant="t").inc(1)
    a.gauge("depth").set(2)
    b.gauge("depth").set(5)
    a.histogram("h").observe(1e-3)
    b.histogram("h").observe(1e-2)
    a.merge(b)
    assert a.value("reqs") == 7
    assert a.value("only_b", tenant="t") == 1
    assert a.value("depth") == 7  # cross-worker gauges add by convention
    assert a.get_histogram("h").count == 2
    # snapshot is JSON-serializable as-is (the worker wire format)
    json.dumps(a.snapshot())


# ----------------------------------------------------------------------
# Tracer: nesting, events, ring, exporters
# ----------------------------------------------------------------------


def test_span_nesting_parent_ids_and_innermost_events():
    clock = InjectedClock()
    tr = Tracer(clock=clock)
    with tr.span("broker.tick", tick=0) as root:
        clock.advance(1.0)
        with tr.span("stage.solve_flush", bucket=16) as child:
            clock.advance(0.5)
            tr.event("fault", site="solve", tick=0)
        root.set(requests=3)
    finished = tr.spans()
    assert [s.name for s in finished] == ["stage.solve_flush", "broker.tick"]
    child, root = finished
    assert root.parent_id is None and child.parent_id == root.span_id
    assert child.duration == 0.5 and root.duration == 1.5
    assert root.attrs["requests"] == 3
    # the event attached to the innermost open span, not the root
    assert root.events == []
    assert child.events[0]["name"] == "fault"
    assert child.events[0]["attrs"]["site"] == "solve"


def test_orphan_event_becomes_zero_duration_span():
    tr = Tracer(clock=InjectedClock())
    tr.event("degraded", tenant="app", tick=4)
    (s,) = tr.spans()
    assert s.duration == 0.0
    assert s.attrs["orphan_event"] is True and s.attrs["tenant"] == "app"


def test_ring_retains_only_newest_spans():
    tr = Tracer(clock=InjectedClock(), capacity=4)
    for i in range(10):
        with tr.span("s", i=i):
            pass
    assert len(tr) == 4
    assert [s.attrs["i"] for s in tr.spans()] == [6, 7, 8, 9]
    tr.clear()
    assert len(tr) == 0
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_disabled_tracer_returns_null_span():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_SPAN
    tr.event("fault")
    assert len(tr) == 0
    # the null span is inert under every instrumented operation
    with NULL_SPAN as s:
        s.set(a=1)
        s.event("e")


def test_export_jsonl_and_chrome_roundtrip(tmp_path):
    clock = InjectedClock()
    tr = Tracer(clock=clock)
    with tr.span("broker.tick", tick=0):
        clock.advance(0.01)
        tr.event("fault", site="solve", kind="error", tick=0)
    out = tmp_path / "trace.jsonl"
    assert tr.export_jsonl(out) == 1
    (doc,) = [json.loads(line) for line in out.read_text().splitlines()]
    assert doc["type"] == "span" and doc["name"] == "broker.tick"
    assert doc["dur"] == 0.01
    assert doc["events"][0]["name"] == "fault"
    chrome = tmp_path / "trace.json"
    assert tr.export_chrome(chrome) == 2  # one "X" span + one "i" instant
    events = json.loads(chrome.read_text())["traceEvents"]
    assert sorted(e["ph"] for e in events) == ["X", "i"]
    (x,) = [e for e in events if e["ph"] == "X"]
    assert x["dur"] == pytest.approx(0.01 * 1e6)  # µs


# ----------------------------------------------------------------------
# PlacementCache: one stat funnel, eviction counts, registry binding
# ----------------------------------------------------------------------


def test_get_many_matches_scalar_path_through_one_funnel(monkeypatch):
    envs_list = [Environment.symmetric(0.5 * (1.6**i), 3.0) for i in range(6)]
    mask = np.random.default_rng(0).random(8) < 0.5

    def make() -> PlacementCache:
        c = PlacementCache(capacity=64)
        c.put(envs_list[0], mask)
        c.put(envs_list[3], ~mask)
        return c

    scalar_cache = make()
    scalar = [scalar_cache.get(e, expected_n=8) for e in envs_list]

    batch_cache = make()
    calls: list[dict] = []
    orig = PlacementCache.record_many

    def spy(self, **kw):
        calls.append(kw)
        return orig(self, **kw)

    monkeypatch.setattr(PlacementCache, "record_many", spy)
    got = batch_cache.get_many(EnvArrays.from_envs(envs_list), expected_n=8)

    # the whole batch funnels through ONE shared increment
    assert len(calls) == 1
    assert calls[0]["hits"] + calls[0]["misses"] == len(envs_list)
    # identical masks and identical accounting vs the scalar loop
    for ga, gb in zip(got, scalar):
        assert (ga is None) == (gb is None)
        if ga is not None:
            assert np.array_equal(ga, gb)
    assert batch_cache.stats == scalar_cache.stats


def test_cache_eviction_counts_and_registry_binding_seeds_history():
    cache = PlacementCache(capacity=2)
    e0, e1, e2 = (Environment.symmetric(bw, 3.0) for bw in (0.3, 2.0, 9.0))
    assert len({cache.key(e) for e in (e0, e1, e2)}) == 3  # distinct bins
    mask = np.ones(6, dtype=bool)
    cache.put(e0, mask)
    cache.get(e0, expected_n=6)  # hit
    cache.get(e1, expected_n=6)  # miss — both BEFORE binding
    reg = MetricsRegistry()
    cache.bind_metrics(reg, tenant="app")
    assert reg.value("cache_hits", tenant="app") == 1  # seeded
    assert reg.value("cache_misses", tenant="app") == 1
    cache.put(e1, mask)
    cache.put(e2, mask)  # capacity 2 → evicts e0's entry
    assert cache.stats.evictions == 1
    assert reg.value("cache_evictions", tenant="app") == 1
    assert reg.value("cache_size", tenant="app") == len(cache) == 2
    cache.get(e2, expected_n=6)
    assert reg.value("cache_hits", tenant="app") == cache.stats.hits == 2


# ----------------------------------------------------------------------
# Detached bit-identity (tentpole acceptance)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("topology", sorted(FIG2_TOPOLOGIES))
@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_attached_observability_is_bit_identical(topology, model_name):
    """Tracer + registry attached produce the same event stream, replies
    and telemetry as the detached broker — observing never perturbs."""
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES[topology]())
    traces = user_traces(n_users=4, steps=6, seed=11)

    def run(**kw):
        broker = _broker(**kw)
        broker.register("app", profile, MODELS[model_name]())
        report = run_workload(
            broker, "app", n_users=4, steps=6,
            threshold=0.15, min_interval=2, traces=traces,
        )
        return report, broker

    plain_report, plain = run()
    traced_report, traced = run(
        tracer=Tracer(clock=InjectedClock(), capacity=8192),
        metrics=MetricsRegistry(clock=InjectedClock()),
    )
    for a, b in zip(plain_report.events, traced_report.events):
        for ea, eb in zip(a, b):
            assert ea.partial_cost == eb.partial_cost
            assert ea.gain == eb.gain
            assert ea.cache_hit == eb.cache_hit
            assert ea.repartitioned == eb.repartitioned
            assert np.array_equal(ea.result.local_mask, eb.result.local_mask)
    assert plain.telemetry.summary() == traced.telemetry.summary()
    for ra, rb in zip(plain.telemetry.reports, traced.telemetry.reports):
        assert dataclasses.asdict(ra) == dataclasses.asdict(rb)
    # ...and the attached run actually captured the tick structure
    assert traced.tracer.spans("broker.tick")
    assert traced.metrics.value("broker_ticks") == traced.telemetry.ticks


def test_chaos_replies_bit_identical_with_observability_attached():
    """Same contract under a live fault storm: the randomized injector
    fires identically whether or not instruments are attached."""
    profile = _profile(10, 3)

    def run(**kw):
        broker = _broker(
            resilience=_policy(
                degrade="fallback",
                deadline_ticks=6,
                breaker=CircuitBreaker(threshold=3, cooldown_ticks=4),
            ),
            fault_injector=FaultInjector(seed=2024, rate=0.2),
            **kw,
        )
        broker.register("app", profile, ResponseTimeModel())
        futs = []
        for t in range(6):
            for i in range(4):
                futs.append(
                    broker.submit("app", _env(0.5 + 0.7 * i + 0.1 * t))
                )
            broker.tick()
        guard = 0
        while broker.pending and guard < 24:
            broker.tick()
            guard += 1
        assert all(f.done for f in futs)
        return [_reply_tuple(f.result) for f in futs], broker

    plain, _ = run()
    traced, broker = run(
        tracer=Tracer(clock=InjectedClock(), capacity=8192),
        metrics=MetricsRegistry(clock=InjectedClock()),
    )
    assert plain == traced
    tel = broker.telemetry
    assert tel.faults > 0  # the storm actually fired
    assert broker.metrics.value("broker_faults") == tel.faults
    fault_events = [
        e
        for s in broker.tracer.spans()
        for e in s.events
        if e["name"] == "fault"
    ]
    assert len(fault_events) == tel.faults


def test_session_batch_tick_bit_identical_with_observability():
    profile = AppProfile.from_wcg_times(FIG2_TOPOLOGIES["linear"]())
    traces = user_traces(n_users=6, steps=5, seed=21)

    def run(**kw):
        broker = _broker(**kw)
        broker.register("app", profile, ResponseTimeModel())
        group = broker.register_batch("app", 6, threshold=0.15, min_interval=2)
        for t in range(5):
            envs = EnvArrays.from_envs([traces[u][t] for u in range(6)])
            group.observe(envs, arrived=np.arange(6) if t == 0 else None)
            broker.tick()
        return group.drain(), broker

    plain_reports, _ = run()
    traced_reports, traced = run(
        tracer=Tracer(clock=InjectedClock(), capacity=8192),
        metrics=MetricsRegistry(clock=InjectedClock()),
    )
    for ra, rb in zip(plain_reports, traced_reports):
        assert ra.placements.tobytes() == rb.placements.tobytes()
        assert ra.min_cut.tobytes() == rb.min_cut.tobytes()
        assert np.array_equal(ra.cache_hit, rb.cache_hit)
        assert (ra.hits, ra.solved, ra.coalesced) == (
            rb.hits, rb.solved, rb.coalesced,
        )
    # the batched session path produced its own stage spans and counters
    names = {s.name for s in traced.tracer.spans()}
    assert {"stage.batch_group", "stage.drift"} <= names
    assert traced.metrics.value("broker_batch_sessions") > 0


# ----------------------------------------------------------------------
# Telemetry ↔ registry views can never disagree
# ----------------------------------------------------------------------


def test_telemetry_fields_mirror_registry_counters():
    metrics = MetricsRegistry(clock=InjectedClock())
    broker = _broker(metrics=metrics)
    profile = _profile(9, 5)
    broker.register("app", profile, ResponseTimeModel())
    traces = user_traces(n_users=4, steps=5, seed=13)
    run_workload(
        broker, "app", n_users=4, steps=5,
        threshold=0.15, min_interval=2, traces=traces,
    )
    tel = broker.telemetry
    assert tel.requests > 0
    views = {
        "broker_ticks": tel.ticks,
        "broker_requests": tel.requests,
        "broker_cache_hits": tel.cache_hits,
        "broker_coalesced": tel.coalesced,
        "broker_solved": tel.solved,
        "broker_dispatches": tel.dispatches,
        "broker_degraded_replies": tel.degraded_replies,
        "broker_rejected_requests": tel.rejected_requests,
    }
    for name, want in views.items():
        assert metrics.value(name) == want, name
    # per-tenant cache counters were bound by register()
    cache = broker._tenants["app"].cache
    assert metrics.value("cache_hits", tenant="app") == cache.stats.hits
    assert metrics.value("cache_misses", tenant="app") == cache.stats.misses
    # one tick-latency sample per tick; quantile view reads the histogram
    h = metrics.get_histogram("broker_tick_latency_s")
    assert h is not None and h.count == tel.ticks
    assert tel.tick_latency_quantiles() == (h.p50, h.p90, h.p99)
    # solver dispatches carried (backend, bucket) labels
    snap = metrics.snapshot()
    dispatch_rows = [
        c for c in snap["counters"] if c["name"] == "solve_envs_dispatches"
    ]
    assert tel.dispatches == 0 or dispatch_rows == [] or all(
        set(c["labels"]) == {"backend", "bucket", "devices"}
        for c in dispatch_rows
    )
    # queue gauges were published
    assert metrics.get_gauge("broker_queue_depth") is not None


def test_bind_metrics_after_history_seeds_counters():
    broker = _broker()
    broker.register("app", _profile(8, 2), ResponseTimeModel())
    for i in range(3):
        broker.submit("app", _env(1.0 + i))
        broker.tick()
    tel = broker.telemetry
    assert tel.metrics is None and tel.tick_latency_quantiles() == (0, 0, 0)
    reg = MetricsRegistry()
    tel.bind_metrics(reg)
    assert reg.value("broker_ticks") == tel.ticks
    assert reg.value("broker_requests") == tel.requests
    assert reg.value("broker_solved") == tel.solved
    # post-bind ticks keep the views equal
    broker.submit("app", _env(9.0))
    broker.tick()
    assert reg.value("broker_requests") == tel.requests


# ----------------------------------------------------------------------
# Degraded-reply provenance + tools/tracequery.py (the CI audit gate)
# ----------------------------------------------------------------------


def test_degraded_reply_trace_provenance_and_audit(tmp_path, capsys):
    tracequery = _load_tool("tracequery")
    clock = InjectedClock()
    tracer = Tracer(clock=clock)
    broker = _broker(
        clock=clock,
        resilience=_policy(),
        fault_injector=ScriptedFaultInjector(
            {("solve", 1, i): "error" for i in range(3)}  # all 3 attempts
        ),
        tracer=tracer,
        metrics=MetricsRegistry(clock=clock),
    )
    broker.register("app", _profile(8, 1), ResponseTimeModel())
    fut = broker.submit("app", _env())
    broker.tick()
    assert fut.result.degraded

    out = tmp_path / "trace.jsonl"
    assert tracer.export_jsonl(out) > 0
    spans = tracequery.load_spans(out)
    (row,) = tracequery.degraded_provenance(spans)
    assert row["tick"] == 1
    assert row["fault_events"], "degraded event must carry fault provenance"
    assert all(a["site"] == "solve" for a in row["fault_events"])
    assert row["retry_events"] == 2  # attempts 2 and 3
    assert tracequery.audit(spans) == []
    assert tracequery.main([str(out), "--audit"]) == 0
    assert "audit ok" in capsys.readouterr().out


def test_tracequery_audit_flags_unattributed_degraded(tmp_path):
    tracequery = _load_tool("tracequery")
    span = {
        "type": "span",
        "name": "broker.tick",
        "span_id": 1,
        "parent_id": None,
        "ts": 0.0,
        "dur": 0.01,
        "attrs": {"tick": 3},
        "events": [
            {
                "name": "degraded",
                "ts": 0.005,
                "attrs": {"tenant": "app", "tick": 3, "stale": False},
            }
        ],
    }
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps(span) + "\nnot json, skipped with warning\n")
    (orphan,) = tracequery.audit(tracequery.load_spans(bad))
    assert orphan["tick"] == 3
    assert tracequery.main([str(bad), "--audit"]) == 1  # CI gate trips
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert tracequery.main([str(empty)]) == 2


def test_chaos_trace_tool_is_deterministic(tmp_path):
    """Two runs of the CI chaos-storm exporter with the same seed write
    byte-identical artifacts (shared InjectedClock: no real time)."""
    chaos_trace = _load_tool("chaos_trace")
    tracequery = _load_tool("tracequery")
    paths = []
    for tag in ("a", "b"):
        out = tmp_path / f"trace_{tag}.jsonl"
        rc = chaos_trace.main(
            ["--out", str(out), "--rate", "0.5", "--steps", "4",
             "--users", "4", "--seed", "7", "--retries", "1"]
        )
        assert rc == 0
        paths.append(out)
    assert paths[0].read_bytes() == paths[1].read_bytes()
    # and the artifact passes the same audit CI runs
    spans = tracequery.load_spans(paths[0])
    assert spans and tracequery.audit(spans) == []
