"""Trainer, optimizer, data pipeline and checkpointing behaviour."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import ARCHITECTURES, reduce_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.models.transformer import build_model
from repro.train import (
    AdamWConfig,
    TrainConfig,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    init_opt_state,
    init_train_state,
    make_train_step,
    train_loop,
)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(ARCHITECTURES["qwen2-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ----------------------------------------------------------------------
# Optimizer units
# ----------------------------------------------------------------------


def test_adamw_matches_manual_formula():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=1, total_steps=10**9,
                      min_lr_frac=1.0)
    p = {"w": jnp.asarray([[2.0]])}
    g = {"w": jnp.asarray([[0.5]])}
    st = init_opt_state(p)
    new_p, st, m = adamw_update(cfg, p, g, st)
    mu = 0.1 * 0.5
    nu = 0.01 * 0.25
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.99)
    expect = 2.0 - 0.1 * (mhat / (np.sqrt(nhat) + 1e-8) + 0.0 * 2.0)
    assert float(new_p["w"][0, 0]) == pytest.approx(expect, rel=1e-5)


def test_grad_clip_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}  # norm 10
    clipped, norm = clip_by_global_norm(g, 5.0)
    assert float(norm) == pytest.approx(10.0)
    total = np.sqrt(sum(float(jnp.sum(x**2)) for x in jax.tree_util.tree_leaves(clipped)))
    assert total == pytest.approx(5.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.asarray(0))) < float(lr(jnp.asarray(9)))     # warmup
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_microbatch_accumulation_matches_full_batch(small_model):
    cfg, model, params = small_model
    data = SyntheticLMDataset(
        DataConfig(seq_len=16, global_batch=8, vocab_size=cfg.vocab_size), cfg
    )
    batch = data.batch(0)

    def loss_fn(p, b):
        return model.train_loss(p, b)[0]

    g_full = jax.grad(loss_fn)(params, batch)

    def split(x):
        return x.reshape(4, 2, *x.shape[1:])

    micro = jax.tree_util.tree_map(split, batch)
    g_acc = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(4):
        mb = jax.tree_util.tree_map(lambda x: x[i], micro)
        g = jax.grad(loss_fn)(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(jnp.float32) / 4, g_acc, g
        )
    for (pa, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(g_full)[0],
        jax.tree_util.tree_flatten_with_path(g_acc)[0],
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b), atol=3e-2, rtol=3e-2
        )


def test_loss_descends_on_learnable_data(small_model):
    cfg, model, params = small_model
    data = SyntheticLMDataset(
        DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size), cfg
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=40), n_micro=1
    )
    _, hist = train_loop(
        lambda p, b: model.train_loss(p, b), params, data.take(40), tcfg
    )
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_compression_modes_still_train(small_model):
    cfg, model, params = small_model
    data = SyntheticLMDataset(
        DataConfig(seq_len=16, global_batch=4, vocab_size=cfg.vocab_size), cfg
    )
    for mode in ("topk", "int8"):
        tcfg = TrainConfig(
            optimizer=AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=20),
            compression=mode,
            topk_frac=0.05,
        )
        _, hist = train_loop(
            lambda p, b: model.train_loss(p, b), params, data.take(15), tcfg
        )
        assert np.isfinite([h["loss"] for h in hist]).all()
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.1, mode


# ----------------------------------------------------------------------
# Data pipeline
# ----------------------------------------------------------------------


def test_data_determinism_and_restart_safety():
    cfg = DataConfig(seq_len=64, global_batch=8, vocab_size=1000, seed=7)
    d1 = SyntheticLMDataset(cfg)
    d2 = SyntheticLMDataset(cfg)
    for step in (0, 3, 17):
        np.testing.assert_array_equal(
            np.asarray(d1.batch(step)["tokens"]), np.asarray(d2.batch(step)["tokens"])
        )


def test_data_host_sharding_partitions_global_batch():
    base = DataConfig(seq_len=8, global_batch=8, vocab_size=100, seed=1)
    full = SyntheticLMDataset(base)
    import dataclasses

    shards = [
        SyntheticLMDataset(dataclasses.replace(base, num_hosts=4, host_index=i))
        for i in range(4)
    ]
    got = [np.asarray(s.batch(5)["tokens"]) for s in shards]
    assert all(g.shape == (2, 8) for g in got)
    # different hosts produce different (non-overlapping) data
    assert not np.array_equal(got[0], got[1])


def test_labels_are_next_token_shifted():
    d = SyntheticLMDataset(DataConfig(seq_len=16, global_batch=2, vocab_size=50))
    b = d.batch(0)
    t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])
    assert (l[:, -1] == -100).all()


def test_markov_structure_is_learnable_signal():
    d = SyntheticLMDataset(DataConfig(seq_len=512, global_batch=4, vocab_size=64))
    t = np.asarray(d.batch(0)["tokens"])
    succ = (t[:, 1:] == (t[:, :-1] * 31 + 17) % 64).mean()
    assert succ > 0.2  # ~30% of transitions follow the deterministic rule


# ----------------------------------------------------------------------
# Checkpointing
# ----------------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention(small_model, tmp_path):
    _, _, params = small_model
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        store.save(s, params)
    assert store.steps() == [2, 3]  # keep=2 garbage-collected step 1
    _, restored, _ = store.restore_latest(params)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
        assert str(a.dtype) == str(b.dtype)
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_incomplete_checkpoint_is_invisible(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(5, {"w": jnp.ones((3,))})
    # simulate a crash mid-save: orphan .tmp directory
    os.makedirs(tmp_path / "step_000000007.tmp")
    assert store.latest_step() == 5


def test_checkpoint_corruption_detected(tmp_path):
    store = CheckpointStore(str(tmp_path))
    path = store.save(1, {"w": jnp.arange(8, dtype=jnp.float32)})
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError, match="checksum"):
        store.restore(1, {"w": jnp.zeros(8, jnp.float32)})


def test_async_save_completes(tmp_path, small_model):
    _, _, params = small_model
    store = CheckpointStore(str(tmp_path))
    store.save_async(9, params)
    store.wait()
    assert store.latest_step() == 9


def test_resume_reproduces_uninterrupted_run(small_model, tmp_path):
    """Fault-tolerance: crash at step 5, restore, continue → same losses."""
    cfg, model, params0 = small_model
    data = SyntheticLMDataset(
        DataConfig(seq_len=16, global_batch=4, vocab_size=cfg.vocab_size), cfg
    )
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    step_fn = make_train_step(lambda p, b: model.train_loss(p, b), tcfg)
    step_fn = jax.jit(step_fn)
    rngs = [jax.random.PRNGKey(100 + i) for i in range(10)]

    def run(params, opt, lo, hi, losses):
        comp = None
        for s in range(lo, hi):
            params, opt, comp, m = step_fn(params, opt, comp, data.batch(s), rngs[s])
            losses.append(float(m["loss"]))
        return params, opt

    # uninterrupted
    losses_a: list = []
    pa, oa = run(params0, init_opt_state(params0), 0, 10, losses_a)

    # interrupted at 5 + restore
    losses_b: list = []
    pb, ob = run(params0, init_opt_state(params0), 0, 5, losses_b)
    store = CheckpointStore(str(tmp_path))
    store.save(5, (pb, ob))
    _, (pb2, ob2), _ = store.restore_latest((pb, ob))
    pb2, ob2 = run(pb2, ob2, 5, 10, losses_b)

    np.testing.assert_allclose(losses_a, losses_b, rtol=1e-5, atol=1e-5)
