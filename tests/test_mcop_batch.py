"""Batched MCOP engine: mcop_batch vs the numpy oracle, the full Pallas
Stoer–Wagner kernel, the quantized placement cache, and the batched
adaptive sweep / placement tier sweep."""

import numpy as np
import pytest

from repro.core import (
    WCG,
    AdaptiveController,
    AppProfile,
    Environment,
    EnvQuantizer,
    PlacementCache,
    ResponseTimeModel,
    mcop_batch,
    mcop_reference,
    paper_example_graph,
    random_wcg,
)
from repro.core.placement import (
    StageSpec,
    TPUV5E_TIER,
    plan_placement,
    plan_placement_batch,
)


def _mixed_batch(bucket: int, count: int, seed0: int) -> list[WCG]:
    """Random graphs with mixed sizes/pinned sets filling one bucket."""
    out = []
    for k in range(count):
        rng = np.random.default_rng(seed0 + k)
        n = int(rng.integers(2, bucket + 1))
        out.append(
            random_wcg(
                n,
                edge_prob=float(rng.choice([0.1, 0.3, 0.6])),
                speedup=float(rng.choice([1.5, 2.0, 4.0])),
                n_unoffloadable=int(rng.integers(1, max(2, n // 3 + 1))),
                rng=rng,
            )
        )
    return out


def _assert_matches_reference(graphs, results):
    for g, r in zip(graphs, results):
        ref = mcop_reference(g)
        assert r.min_cut == pytest.approx(ref.min_cut, rel=1e-4, abs=1e-4)
        assert (r.local_mask == ref.local_mask).all()
        assert g.total_cost(r.local_mask) == pytest.approx(
            ref.min_cut, rel=1e-4, abs=1e-4
        )


# ----------------------------------------------------------------------
# mcop_batch vs mcop_reference
# ----------------------------------------------------------------------


@pytest.mark.parametrize("bucket", [16, 64])
def test_mcop_batch_matches_reference_per_bucket(bucket):
    """≥20 random graphs per bucket, mixed sizes and pinned-vertex sets."""
    graphs = _mixed_batch(bucket, count=22, seed0=1000 * bucket)
    _assert_matches_reference(graphs, mcop_batch(graphs))


def test_mcop_batch_mixed_buckets_preserves_order():
    graphs = _mixed_batch(16, 6, 10) + _mixed_batch(64, 6, 20) + _mixed_batch(16, 4, 30)
    _assert_matches_reference(graphs, mcop_batch(graphs))


def test_mcop_batch_edge_cases():
    cases = []
    # n=2: one pinned, one free
    cases.append(random_wcg(2, n_unoffloadable=1, rng=np.random.default_rng(0)))
    # all pinned but one
    cases.append(random_wcg(7, n_unoffloadable=6, rng=np.random.default_rng(1)))
    # no pinned vertices at all (anchor falls back to vertex 0)
    g = random_wcg(6, rng=np.random.default_rng(2))
    g.offloadable[:] = True
    cases.append(g)
    # the paper's worked example
    cases.append(paper_example_graph())
    _assert_matches_reference(cases, mcop_batch(cases))


def test_mcop_batch_pallas_backend_matches_reference():
    graphs = _mixed_batch(12, 6, 500) + [paper_example_graph()]
    results = mcop_batch(graphs, backend="pallas", buckets=(12,))
    _assert_matches_reference(graphs, results)


def test_mcop_batch_pallas_large_weights_not_swallowed_by_sentinel():
    """Graphs priced in FLOPs/bytes (cuts ≫ 2³⁰) must not collapse into the
    kernel's best-cut sentinel — regression for the old 2**30 POS_INF."""
    g = random_wcg(8, edge_prob=0.5, rng=np.random.default_rng(42))
    g.w_local *= 1e12
    g.w_cloud *= 1e12
    g.adj *= 1e12
    ref = mcop_reference(g)
    res = mcop_batch([g], backend="pallas", buckets=(8,))[0]
    assert res.min_cut == pytest.approx(ref.min_cut, rel=1e-4)
    assert (res.local_mask == ref.local_mask).all()


def test_mcop_batch_rejects_unknown_backend():
    with pytest.raises(ValueError):
        mcop_batch([paper_example_graph()], backend="cuda")


def test_full_kernel_direct_paper_example():
    from repro.kernels import mcop_stoer_wagner_kernel

    g = paper_example_graph()
    cuts, masks = mcop_stoer_wagner_kernel(
        g.adj[None], g.w_local[None], g.w_cloud[None], (~g.offloadable)[None]
    )
    assert float(cuts[0]) == pytest.approx(22.0)
    assert (np.asarray(masks[0]) == mcop_reference(g).local_mask).all()


# ----------------------------------------------------------------------
# Placement cache: quantization and hit/miss semantics
# ----------------------------------------------------------------------


def test_quantizer_bins_follow_relative_step():
    q = EnvQuantizer(rel_step=0.10)
    base = Environment.symmetric(8.0, 3.0)
    near = Environment.symmetric(8.2, 3.0)      # ~2.5% off — same bin
    far = Environment.symmetric(12.0, 3.0)      # 50% off — different bin
    assert q.key(base) == q.key(near)
    assert q.key(base) != q.key(far)
    assert q.key(base) != q.key(Environment.symmetric(8.0, 4.0))


def test_cache_hit_miss_counters_and_repricing():
    cache = PlacementCache()
    env = Environment.symmetric(5.0, 3.0)
    assert cache.get(env) is None
    mask = np.array([True, False, True])
    cache.put(env, mask)
    # same bin → hit, including a slightly different environment
    got = cache.get(Environment.symmetric(5.05, 3.0))
    assert got is not None and (got == mask).all()
    # different bin → miss
    assert cache.get(Environment.symmetric(50.0, 3.0)) is None
    st = cache.stats
    assert (st.hits, st.misses) == (1, 2)
    assert st.hit_rate == pytest.approx(1 / 3)


def test_cache_wrong_shape_mask_is_a_miss():
    """Sharing a cache across different-sized profiles must never surface a
    wrong-length mask — and the lookup counts as a miss, not a hit."""
    cache = PlacementCache()
    env = Environment.symmetric(2.0, 3.0)
    cache.put(env, np.array([True, False, True]))
    assert cache.get(env, expected_n=8) is None
    assert cache.get(env, expected_n=3) is not None
    st = cache.stats
    assert (st.hits, st.misses) == (1, 1)


def test_cache_lru_eviction():
    cache = PlacementCache(capacity=2)
    m = np.array([True])
    for bw in (1.0, 10.0, 100.0):
        cache.put(Environment.symmetric(bw, 3.0), m)
    assert len(cache) == 2
    assert cache.get(Environment.symmetric(1.0, 3.0)) is None  # evicted
    assert cache.get(Environment.symmetric(100.0, 3.0)) is not None


# ----------------------------------------------------------------------
# Batched adaptive sweep
# ----------------------------------------------------------------------


_TRACE = [
    (8.0, 3.0), (7.6, 3.0), (1.2, 3.0), (1.1, 3.0), (0.3, 3.0),
    (0.3, 1.5), (6.0, 3.0), (8.0, 3.0), (1.2, 3.0), (0.3, 3.0),
]


def _controller(**kw):
    g = random_wcg(8, rng=np.random.default_rng(3))
    prof = AppProfile.from_wcg_times(g)
    return AdaptiveController(
        prof, ResponseTimeModel(), threshold=0.15, min_interval=2, **kw
    )


@pytest.mark.parametrize("backend", ["reference", "jax"])
def test_sweep_matches_serial_observe(backend):
    envs = [Environment.symmetric(b, f) for b, f in _TRACE]
    serial = _controller(backend=backend)
    batched = _controller(backend=backend)
    ev_s = [serial.observe(e) for e in envs]
    ev_b = batched.sweep(envs)
    for a, b in zip(ev_s, ev_b):
        assert a.repartitioned == b.repartitioned
        assert b.partial_cost == pytest.approx(a.partial_cost, rel=1e-5)
        assert (a.result.local_mask == b.result.local_mask).all()


def test_sweep_cache_semantics_match_serial():
    envs = [Environment.symmetric(b, f) for b, f in _TRACE]
    c_serial, c_batched = PlacementCache(), PlacementCache()
    serial = _controller(cache=c_serial)
    batched = _controller(cache=c_batched)
    ev_s = [serial.observe(e) for e in envs]
    ev_b = batched.sweep(envs)
    assert [e.cache_hit for e in ev_s] == [e.cache_hit for e in ev_b]
    assert (c_serial.stats.hits, c_serial.stats.misses) == (
        c_batched.stats.hits, c_batched.stats.misses,
    )
    for a, b in zip(ev_s, ev_b):
        assert b.partial_cost == pytest.approx(a.partial_cost, rel=1e-9)


def test_shared_cache_serves_second_controller():
    envs = [Environment.symmetric(b, f) for b, f in _TRACE]
    cache = PlacementCache()
    first = _controller(cache=cache)
    ev1 = first.sweep(envs)
    misses_after_first = cache.stats.misses
    second = _controller(cache=cache)
    ev2 = second.sweep(envs)
    # every repartition of user 2 is served from user 1's placements
    assert all(e.cache_hit for e in ev2 if e.repartitioned)
    assert cache.stats.misses == misses_after_first
    # repriced costs are honest: identical envs → identical costs
    for a, b in zip(ev1, ev2):
        assert b.partial_cost == pytest.approx(a.partial_cost, rel=1e-9)


# ----------------------------------------------------------------------
# Placement tier sweep
# ----------------------------------------------------------------------


def _stages(n=6):
    return [
        StageSpec(
            name=f"s{i}",
            flops=(1.0 + i) * 1e15,
            bytes_hbm=(0.5 + i) * 1e12,
            act_bytes_out=2e9,
            pinned_tier=0 if i == 0 else None,
        )
        for i in range(n)
    ]


def test_plan_placement_batch_matches_serial_plans():
    stages = _stages()
    tl = TPUV5E_TIER
    tr = TPUV5E_TIER
    bws = [1e8, 1e9, 5e9, 1e15]
    plans = plan_placement_batch(
        stages, tl, tr, inter_tier_bws=bws, backend="reference"
    )
    for bw, plan in zip(bws, plans):
        ref = plan_placement(stages, tl, tr, inter_tier_bw=bw)
        assert plan.mcop_cost == pytest.approx(ref.mcop_cost, rel=1e-6)
        assert (plan.stage_tier == ref.stage_tier).all()
        assert plan.contiguous_boundary == ref.contiguous_boundary
