"""Hypothesis import shim for the property-test modules.

When hypothesis is installed (``pip install -r requirements-dev.txt``),
this re-exports the real ``given``/``settings``/``st`` — with every
``@given`` test additionally tagged ``@pytest.mark.property`` so tier-1
(``pytest -x -q``, see pytest.ini) stays fast and deterministic while the
property suite runs opt-in via ``pytest -m property``.

When hypothesis is missing (the minimal container), strategy expressions
still evaluate at module import (via the ``_Any`` stand-in) and every
``@given`` test becomes a runtime ``pytest.importorskip("hypothesis")``
skip — the numpy-based smoke tests in the same modules keep running.
"""

import pytest

try:
    from hypothesis import given as _hyp_given, settings, strategies as st

    HAVE_HYPOTHESIS = True

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.property(_hyp_given(*args, **kwargs)(fn))

        return deco

except ModuleNotFoundError:  # pragma: no cover — exercised in minimal envs
    HAVE_HYPOTHESIS = False

    class _Any:
        """Absorbs any attribute access / call so module-level strategy
        expressions (``st.floats(...)``, ``@st.composite``) still parse."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Any()

    def given(*_args, **_kwargs):
        def deco(fn):
            # no functools.wraps: copying fn's signature would make pytest
            # treat hypothesis-drawn arguments as fixtures
            def skipper():
                pytest.importorskip("hypothesis")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return pytest.mark.property(skipper)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
