"""Sharded solver fleet: plan math + bit-identical multi-device parity.

The PR-9 acceptance suite.  The host-side tests pin down the pure-numpy
shard plan (round-robin placement, inert padding, exact inverse) and the
``mesh=`` argument normalization.  The parity tests run in subprocesses
behind ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax
freezes the device count at first import — the main pytest process must
keep the real single CPU) and compare the 8-way sharded solve plane
against the forced single-device path with ``==`` — no tolerances:

* ``solve_envs`` across the Fig.-2 topologies × three cost models, with
  an uneven K=13 batch (padding + round-robin both engaged);
* the packed ``mcop_batch``/``WCGBatch`` flush path;
* a full ``tick_sessions`` tick — every event column, prices, cache
  counters — plus the empty-miss-set second tick (no solve dispatched;
  the sharded plane must stay out of the way entirely).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.mcop_shard import ShardPlan, resolve_mesh, shard_plan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.service


def run_sub(body: str, devices: int = 8, timeout: int = 420) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


# ----------------------------------------------------------------------
# Shard plan: pure host math
# ----------------------------------------------------------------------


def test_shard_plan_round_robin_property():
    plan = shard_plan(13, 8)
    assert plan.pad == 3 and plan.k == 13 and plan.rows_per_shard == 2
    # device-major layout: position p of the permuted batch belongs to
    # device p // rows_per_shard, and must hold a row whose original
    # index i satisfies i % shards == that device
    for p, i in enumerate(plan.perm):
        assert i % plan.shards == p // plan.rows_per_shard, (p, i)


def test_shard_plan_inverse_restores_order():
    for k, d in [(13, 8), (16, 8), (5, 2), (1, 4), (64, 8)]:
        plan = shard_plan(k, d)
        x = np.arange(k + plan.pad)
        assert np.array_equal(x[plan.perm][plan.inverse], x)
        assert (k + plan.pad) % d == 0


def test_shard_plan_no_pad_when_divisible():
    plan = shard_plan(16, 8)
    assert plan.pad == 0 and plan.rows_per_shard == 2


def test_shard_plan_rejects_degenerate_inputs():
    with pytest.raises(ValueError):
        shard_plan(0, 8)
    with pytest.raises(ValueError):
        shard_plan(8, 0)


def test_shard_plan_is_a_namedtuple_with_stable_fields():
    plan = shard_plan(4, 2)
    assert isinstance(plan, ShardPlan)
    assert plan._fields == ("shards", "k", "pad", "perm", "inverse")


# ----------------------------------------------------------------------
# mesh= argument normalization (single-device host: auto collapses)
# ----------------------------------------------------------------------


def test_resolve_mesh_false_forces_single_device():
    assert resolve_mesh(False) is None


def test_resolve_mesh_auto_is_none_on_single_device_host():
    import jax

    if jax.device_count() > 1:
        pytest.skip("host sees a real fleet; auto resolves to it")
    assert resolve_mesh(None) is None


def test_resolve_mesh_collapses_one_shard_mesh():
    from repro.launch.mesh import make_solver_mesh

    import jax

    mesh = make_solver_mesh(jax.devices()[:1])
    assert resolve_mesh(mesh) is None


def test_resolve_mesh_rejects_junk():
    with pytest.raises(TypeError):
        resolve_mesh(8)


# ----------------------------------------------------------------------
# 8-device parity (subprocess): solve_envs + mcop_batch, all topologies
# ----------------------------------------------------------------------


def test_sharded_solve_envs_and_mcop_batch_bit_identical_on_8_devices():
    run_sub(
        """
        import numpy as np, jax
        from repro.core import (AppProfile, EnergyModel, ResponseTimeModel,
                                WeightedModel, linear_graph, loop_graph,
                                mesh_graph, tree_graph)
        from repro.core.cost_models import EnvArrays
        from repro.core.mcop import WCGBatch, mcop_batch, solve_envs
        from repro.core.mcop_shard import default_solver_mesh
        from repro.obs import Tracer

        assert jax.device_count() == 8
        mesh = default_solver_mesh()
        assert mesh is not None

        TOPOLOGIES = {
            'linear': linear_graph(9, rng=np.random.default_rng(1)),
            'loop': loop_graph(8, rng=np.random.default_rng(2)),
            'tree': tree_graph(10, rng=np.random.default_rng(3)),
            'mesh': mesh_graph(3, 3, rng=np.random.default_rng(4)),
        }
        MODELS = {'time': ResponseTimeModel(), 'energy': EnergyModel(),
                  'weighted': WeightedModel(0.35)}
        rng = np.random.default_rng(7)
        k = 13  # uneven on 8 shards: pad=3 + round-robin both engaged
        envs = EnvArrays(*(rng.uniform(0.5, 5.0, k) for _ in range(6)))

        for tname, graph in TOPOLOGIES.items():
            profile = AppProfile.from_wcg_times(graph)
            for mname, model in MODELS.items():
                tr = Tracer()
                sharded = solve_envs(profile, model, envs, backend='jax',
                                     mesh=mesh, tracer=tr)
                single = solve_envs(profile, model, envs, backend='jax',
                                    mesh=False)
                for rs, r1 in zip(sharded, single):
                    assert rs.min_cut == r1.min_cut, (tname, mname)
                    assert np.array_equal(rs.local_mask, r1.local_mask)
                spans = tr.spans('solve_envs.shard')
                assert len(spans) == 8, (tname, mname, len(spans))
                assert {s.attrs['shard'] for s in spans} == set(range(8))
                assert all(s.attrs['devices'] == 8 for s in spans)

        # packed WCGBatch flush path (mcop_batch), both array backends
        graphs = [linear_graph(4 + (i % 10), rng=np.random.default_rng(10 + i))
                  for i in range(13)]
        batch = WCGBatch.from_wcgs(graphs, m=16)
        for backend in ('jax', 'pallas'):
            sharded = mcop_batch(batch, backend=backend, mesh=mesh)
            single = mcop_batch(batch, backend=backend, mesh=False)
            for rs, r1 in zip(sharded, single):
                assert rs.min_cut == r1.min_cut, backend
                assert np.array_equal(rs.local_mask, r1.local_mask)
        print('OK')
        """
    )


def test_sharded_tick_sessions_bit_identical_on_8_devices():
    run_sub(
        """
        import numpy as np, jax
        from repro.core import (AppProfile, EnvQuantizer, PlacementCache,
                                ResponseTimeModel, SessionBatch,
                                tree_graph, tick_sessions)
        from repro.core.cost_models import EnvArrays
        from repro.core.mcop_shard import default_solver_mesh

        assert jax.device_count() == 8
        mesh = default_solver_mesh()
        profile = AppProfile.from_wcg_times(
            tree_graph(10, rng=np.random.default_rng(3)))
        rng = np.random.default_rng(5)
        k = 13

        def drive(mesh_arg):
            batch = SessionBatch.create(k, profile.n, threshold=0.15,
                                        min_interval=2)
            batch.activate(np.arange(k))
            cache = PlacementCache(EnvQuantizer())
            envs = EnvArrays(*(np.asarray(c) for c in
                               (rng.uniform(0.5, 5.0, (6, k)))))
            reps = []
            # tick 0: k fresh sessions -> solve flush through the fleet;
            # tick 1: same envs, cooldown holds -> EMPTY miss set (the
            # sharded plane must not dispatch anything)
            for t in range(2):
                reps.append(tick_sessions(
                    batch, envs, profile=profile,
                    model=ResponseTimeModel(), cache=cache,
                    backend='jax', mesh=mesh_arg, tick=t))
            return reps, cache.stats

        rng_state = rng.bit_generator.state
        sharded, stats_sh = drive(mesh)
        rng.bit_generator.state = rng_state  # identical envs both runs
        single, stats_1 = drive(False)

        assert stats_sh == stats_1
        for t, (rs, r1) in enumerate(zip(sharded, single)):
            assert rs.solved == r1.solved and rs.coalesced == r1.coalesced
            assert np.array_equal(rs.repartitioned, r1.repartitioned), t
            assert np.array_equal(rs.placements, r1.placements), t
            assert np.array_equal(rs.partial_cost, r1.partial_cost), t
            assert np.array_equal(rs.min_cut, r1.min_cut, equal_nan=True), t
            assert np.array_equal(rs.no_offload_cost, r1.no_offload_cost), t
            assert np.array_equal(rs.full_offload_cost, r1.full_offload_cost), t
        assert sharded[0].solved > 0      # tick 0 really flushed
        assert sharded[1].solved == 0     # tick 1 really was empty
        print('OK')
        """
    )
