"""Distribution tests: sharding rules, pjit train step, pipeline — on 8
virtual host devices.

jax fixes the device count at first init, so these run in *subprocesses*
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the main
pytest process keeps the real single CPU (as required: only dryrun.py and
these child processes ever see virtual devices).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 420) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_param_shardings_place_leaves_on_mesh():
    run_sub(
        """
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import ARCHITECTURES, reduce_config
        from repro.models.transformer import build_model
        from repro.runtime import param_shardings, shard_params
        from repro.launch.mesh import make_local_mesh, use_mesh

        mesh = make_local_mesh(data=2, model=4)
        # widen the reduced config so dims divide the mesh axes
        cfg = reduce_config(ARCHITECTURES['qwen2-7b'], d_model=64, n_heads=4,
                            n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sharded = shard_params(params, mesh)
        # attention wq sharded over model on its output dim
        wq = sharded['blocks']['attn']['wq']['w']
        assert wq.sharding.spec == P(None, None, 'model'), wq.sharding.spec
        # forward still works on sharded params
        batch = {'tokens': jax.numpy.zeros((4, 8), jax.numpy.int32),
                 'labels': jax.numpy.zeros((4, 8), jax.numpy.int32)}
        with use_mesh(mesh):
            loss, _ = jax.jit(model.train_loss)(sharded, batch)
        assert bool(jax.numpy.isfinite(loss))
        print('OK')
        """
    )


def test_pjit_train_step_multidevice_matches_single_device():
    run_sub(
        """
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import ARCHITECTURES, reduce_config
        from repro.models.transformer import build_model
        from repro.train import AdamWConfig, TrainConfig, init_train_state, make_train_step
        from repro.data import DataConfig, SyntheticLMDataset
        from repro.runtime import shard_params
        from repro.launch.mesh import make_local_mesh, use_mesh

        cfg = reduce_config(ARCHITECTURES['qwen2-7b'], d_model=64, n_heads=4,
                            n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        data = SyntheticLMDataset(DataConfig(seq_len=16, global_batch=8,
                                             vocab_size=cfg.vocab_size), cfg)
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=10))
        step = make_train_step(lambda p, b: model.train_loss(p, b), tcfg)
        rng = jax.random.PRNGKey(0)

        # single-device result
        st = init_train_state(params, tcfg)
        p1, o1, _, m1 = jax.jit(step)(st.params, st.opt_state, None, data.batch(0), rng)

        # sharded result on the 2×4 mesh
        mesh = make_local_mesh(data=2, model=4)
        with use_mesh(mesh):
            sp = shard_params(params, mesh)
            st2 = init_train_state(sp, tcfg)
            p2, o2, _, m2 = jax.jit(step)(st2.params, st2.opt_state, None,
                                          data.batch(0), rng)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 5e-2, \
            (float(m1['loss']), float(m2['loss']))
        # parameters agree after one update
        la = jax.tree_util.tree_leaves(p1)
        lb = jax.tree_util.tree_leaves(p2)
        worst = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                    for a, b in zip(la, lb))
        assert worst < 0.15, worst
        print('OK', float(m1['loss']), float(m2['loss']), worst)
        """
    )


def test_pipeline_apply_matches_sequential():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_apply, stack_stage_params
        from repro.launch.mesh import _mk, use_mesh

        mesh = _mk((2, 4), ('pod', 'data'))
        L, d = 8, 16
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(L, d, d)) * 0.1 + np.eye(d), jnp.float32)

        def stage_fn(p, x):
            y, _ = jax.lax.scan(lambda x, wl: (jnp.tanh(x @ wl), None), x, p['w'])
            return y

        B, S = 16, 4
        x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
        ref = x
        for i in range(L):
            ref = jnp.tanh(ref @ w[i])
        stacked = stack_stage_params({'w': w}, 2)
        with use_mesh(mesh):
            for n_micro in (1, 2, 4):
                out = pipeline_apply(stage_fn, stacked, x, mesh=mesh, n_micro=n_micro)
                err = float(jnp.abs(out - ref).max())
                assert err < 1e-6, (n_micro, err)
        print('OK')
        """
    )


def test_multipod_mesh_cross_pod_collectives():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import _mk, use_mesh

        mesh = _mk((2, 2, 2), ('pod', 'data', 'model'))
        x = jnp.arange(16.0).reshape(8, 2)
        with use_mesh(mesh):
            xs = jax.device_put(x, NamedSharding(mesh, P(('pod', 'data'), 'model')))
            total = jax.jit(lambda a: a.sum())(xs)
        assert float(total) == float(x.sum())
        print('OK')
        """
    )


def test_checkpoint_restore_onto_different_mesh():
    """Elastic resume: save from a (2,4) mesh, restore onto (4,2)."""
    run_sub(
        """
        import tempfile, jax, numpy as np, jax.numpy as jnp
        from repro.checkpoint import CheckpointStore
        from repro.configs import ARCHITECTURES, reduce_config
        from repro.models.transformer import build_model
        from repro.runtime import param_shardings, shard_params
        from repro.launch.mesh import make_local_mesh, use_mesh

        cfg = reduce_config(ARCHITECTURES['qwen2-7b'], d_model=64, n_heads=4,
                            n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

        mesh_a = make_local_mesh(data=2, model=4)
        sharded = shard_params(params, mesh_a)
        with tempfile.TemporaryDirectory() as d:
            store = CheckpointStore(d)
            store.save(1, sharded)

            mesh_b = make_local_mesh(data=4, model=2)
            shapes = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            target = param_shardings(shapes, mesh_b)
            restored, _extra = store.restore(1, params, shardings=target)
        # values identical, placement follows the NEW mesh
        for a, b, s in zip(jax.tree_util.tree_leaves(params),
                           jax.tree_util.tree_leaves(restored),
                           jax.tree_util.tree_leaves(target)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert b.sharding == s, (b.sharding, s)
        print('OK')
        """
    )
