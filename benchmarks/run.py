"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only complexity,gains,...]

Prints ``name,us_per_call,derived`` CSV.  Mapping to the paper:

    complexity      → Fig. 14 (runtime vs |V|, B&B comparator)
    gains           → Figs. 17–19 (schemes vs B and F; 3 cost models)
    optimality_gap  → beyond-paper: Theorem 1 gap quantification
    mcop_backends   → §3.1 real-time requirement (ref vs jit vs batched vs Pallas)
    roofline        → §Roofline table from the dry-run artifact

The mcop_backends rows are additionally appended to ``BENCH_mcop.json``
(a bounded trajectory of runs), so backend/batching speedups can be
tracked across commits.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from benchmarks import (
    complexity,
    compression_ablation,
    gains,
    mcop_backends,
    optimality_gap,
    roofline,
)

MODULES = {
    "complexity": complexity,
    "gains": gains,
    "optimality_gap": optimality_gap,
    "mcop_backends": mcop_backends,
    "compression_ablation": compression_ablation,
    "roofline": roofline,
}


# anchored at the repo root so the trajectory accumulates in one place
# regardless of the invoking cwd
_TRAJECTORY_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_mcop.json"
_TRAJECTORY_KEEP = 50  # bounded history of runs


def _append_trajectory(rows: list[dict], path: pathlib.Path = _TRAJECTORY_PATH) -> None:
    """Append this run's mcop_backends rows to the trajectory artifact."""
    doc = {"benchmark": "mcop_backends", "runs": []}
    if path.exists():
        try:
            loaded = json.loads(path.read_text())
            if isinstance(loaded.get("runs"), list):
                doc = loaded
        except (json.JSONDecodeError, OSError):
            pass  # corrupt artifact: start a fresh trajectory
    doc["runs"].append(
        {
            "unix_time": int(time.time()),
            "rows": [
                {
                    "name": r["name"],
                    "us_per_call": round(float(r["us_per_call"]), 2),
                    "derived": str(r["derived"]),
                }
                for r in rows
            ],
        }
    )
    doc["runs"] = doc["runs"][-_TRAJECTORY_KEEP:]
    path.write_text(json.dumps(doc, indent=2) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated subset of benchmarks")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            rows = list(MODULES[name].run())
            for row in rows:
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.2f},{derived}", flush=True)
            if name == "mcop_backends":
                _append_trajectory(rows)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0.00,{e!r}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
