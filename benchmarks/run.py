"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only complexity,gains,...]

Prints ``name,us_per_call,derived`` CSV.  Mapping to the paper:

    complexity      → Fig. 14 (runtime vs |V|, B&B comparator)
    gains           → Figs. 17–19 (schemes vs B and F; 3 cost models)
    optimality_gap  → beyond-paper: Theorem 1 gap quantification
    mcop_backends   → §3.1 real-time requirement (ref vs jit vs batched vs Pallas)
    pipeline        → fused env→placement pipeline vs the object path
    broker          → serving tier: multi-user tick throughput, warm restarts
    scale           → batched session engine: ticks/s and µs/user at
                      U ∈ {1k, 10k, 100k} vs the per-object baseline
                      (``REPRO_SCALE_U=1000`` for the CI smoke subset)
    faults          → fault-tolerance overhead: throughput/p99/degraded
                      fraction at injected fault rates {0%, 1%, 10%}
                      (``REPRO_FAULTS_STEPS=3`` for the CI smoke subset)
    ipc             → cross-process serving plane: req/s and p99 over a
                      unix-socket solver subprocess vs the in-process
                      broker (``REPRO_IPC_REQS=16`` for the CI smoke
                      subset)
    shard           → sharded solver fleet: µs/graph and tick throughput
                      at 1/2/4/8 simulated devices, plus compiled-vs-
                      interpret kernel rows (``REPRO_SHARD_K=64`` for the
                      CI smoke subset)
    roofline        → §Roofline table from the dry-run artifact

The mcop_backends rows are additionally appended to ``BENCH_mcop.json``,
the broker rows to ``BENCH_broker.json``, the pipeline rows to
``BENCH_pipeline.json``, the scale rows to ``BENCH_scale.json``, the
faults rows to ``BENCH_faults.json`` and the ipc rows to
``BENCH_ipc.json`` (bounded trajectories of runs), so
backend/batching/serving/resilience/transport numbers can be tracked
across commits; the broker, pipeline, scale, faults, shard and ipc
artifacts are smoke-checked after every append.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import pathlib
import re
import subprocess
import sys
import time

from benchmarks import (
    broker,
    complexity,
    compression_ablation,
    faults,
    gains,
    ipc,
    mcop_backends,
    optimality_gap,
    pipeline,
    roofline,
    scale,
    shard,
)

MODULES = {
    "complexity": complexity,
    "gains": gains,
    "optimality_gap": optimality_gap,
    "mcop_backends": mcop_backends,
    "pipeline": pipeline,
    "broker": broker,
    "scale": scale,
    "faults": faults,
    "shard": shard,
    "ipc": ipc,
    "compression_ablation": compression_ablation,
    "roofline": roofline,
}


# anchored at the repo root so the trajectories accumulate in one place
# regardless of the invoking cwd
_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_mcop.json"
_BROKER_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_broker.json"
_PIPELINE_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_pipeline.json"
_SCALE_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_scale.json"
_FAULTS_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_faults.json"
_SHARD_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_shard.json"
_IPC_TRAJECTORY_PATH = _REPO_ROOT / "BENCH_ipc.json"
_TRAJECTORY_KEEP = 50  # bounded history of runs


@functools.lru_cache(maxsize=1)
def _env_metadata() -> dict:
    """Execution environment stamped onto every trajectory record.

    Makes cross-commit comparisons honest: a row timed on a different
    accelerator backend, under Pallas interpret mode, or on a different
    core count is not comparable, and the artifact now says so.
    """
    import jax  # deferred: keep artifact-only code paths import-light

    from repro.kernels.ops import default_interpret

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=_REPO_ROOT,
            capture_output=True,
            text=True,
            timeout=10,
            check=False,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "jax_backend": jax.default_backend(),
        "pallas_interpret": bool(default_interpret()),
        "cpu_count": os.cpu_count(),
        "git_sha": sha,
    }


def _append_trajectory(
    rows: list[dict],
    path: pathlib.Path = _TRAJECTORY_PATH,
    benchmark: str = "mcop_backends",
    wall_s: float | None = None,
) -> None:
    """Append one run's rows to a bounded trajectory artifact."""
    doc = {"benchmark": benchmark, "runs": []}
    if path.exists():
        try:
            loaded = json.loads(path.read_text())
            # adopt only a well-formed doc for the SAME benchmark; a
            # foreign tag or non-dict payload starts a fresh trajectory
            # (isinstance guard also keeps JSON arrays on the corrupt path)
            if (
                isinstance(loaded, dict)
                and loaded.get("benchmark") == benchmark
                and isinstance(loaded.get("runs"), list)
            ):
                doc = loaded
        except (json.JSONDecodeError, OSError):
            pass  # corrupt artifact: start a fresh trajectory
    doc["runs"].append(
        {
            "unix_time": int(time.time()),
            "env": _env_metadata(),
            "wall_s": round(wall_s, 3) if wall_s is not None else None,
            "rows": [
                {
                    "name": r["name"],
                    "us_per_call": round(float(r["us_per_call"]), 2),
                    "derived": str(r["derived"]),
                }
                for r in rows
            ],
        }
    )
    doc["runs"] = doc["runs"][-_TRAJECTORY_KEEP:]
    path.write_text(json.dumps(doc, indent=2) + "\n")


def _smoke_check_trajectory(path: pathlib.Path, benchmark: str) -> None:
    """Fail loudly if the just-written artifact would not load warm.

    The broker trajectory is what dashboards (and the next session's
    diff) read; a malformed write must surface as a benchmark failure,
    not as a silently cold artifact later.
    """
    doc = json.loads(path.read_text())
    if doc.get("benchmark") != benchmark:
        raise RuntimeError(f"{path.name}: wrong benchmark tag {doc.get('benchmark')!r}")
    runs = doc.get("runs")
    if not isinstance(runs, list) or not runs:
        raise RuntimeError(f"{path.name}: no runs recorded")
    last = runs[-1]
    if not isinstance(last.get("rows"), list) or not last["rows"]:
        raise RuntimeError(f"{path.name}: last run has no rows")
    for row in last["rows"]:
        if not {"name", "us_per_call", "derived"} <= set(row):
            raise RuntimeError(f"{path.name}: malformed row {row!r}")
        float(row["us_per_call"])  # numeric or raise
    if benchmark == "pipeline":
        # the pricing fusion must keep reporting its series: a sweep's
        # telemetry speedup is an acceptance number, not a nice-to-have
        names = [row["name"] for row in last["rows"]]
        if not any(n.startswith("pipeline/pricing_fused") for n in names):
            raise RuntimeError(
                f"{path.name}: last run lacks a pipeline/pricing_fused_* row"
            )
    if benchmark == "scale":
        # the batched-session series is the PR-6 acceptance artifact:
        # every run must carry at least one batch row whose derived
        # column reports both throughput figures
        batch_rows = [
            row for row in last["rows"] if row["name"].startswith("scale/batch_u")
        ]
        if not batch_rows:
            raise RuntimeError(f"{path.name}: last run lacks a scale/batch_u* row")
        for row in batch_rows:
            if "ticks/s" not in row["derived"] or "us/user" not in row["derived"]:
                raise RuntimeError(
                    f"{path.name}: batch row missing throughput figures: {row!r}"
                )
    if benchmark == "faults":
        # PR-7 acceptance: all three rate rows present, and light chaos
        # (1% fault rate) holds throughput within 2x of the fault-free
        # pass — graceful degradation must not cost an order of magnitude
        by_name = {row["name"]: row for row in last["rows"]}
        req_s = {}
        for tag in ("rate0", "rate1pct", "rate10pct"):
            row = by_name.get(f"faults/{tag}")
            if row is None:
                raise RuntimeError(f"{path.name}: last run lacks a faults/{tag} row")
            m = re.search(r"req_s=(\d+(?:\.\d+)?)", row["derived"])
            if m is None:
                raise RuntimeError(
                    f"{path.name}: faults/{tag} derived lacks req_s=: {row!r}"
                )
            req_s[tag] = float(m.group(1))
        if req_s["rate1pct"] < 0.5 * req_s["rate0"]:
            raise RuntimeError(
                f"{path.name}: throughput at 1% faults "
                f"({req_s['rate1pct']:.0f} req/s) fell past 2x of fault-free "
                f"({req_s['rate0']:.0f} req/s)"
            )
    if benchmark == "shard":
        # PR-9 acceptance: the 8-device fleet must deliver ≥2x aggregate
        # solve throughput over 1 device for the 64-vertex bucket.  The
        # simulated fleet shares the host's physical cores, so the bar
        # scales with what the silicon can physically provide: ≥2x with
        # ≥4 cores, ≥1.3x with 2–3, and waived — loudly, in the artifact
        # — on single-core hosts (8 simulated devices on 1 core cannot
        # run in parallel at all).
        by_name = {row["name"]: row for row in last["rows"]}
        d_max = max(
            (int(m.group(1)) for n in by_name if (m := re.match(r"shard/solve_d(\d+)$", n))),
            default=0,
        )
        if "shard/solve_d1" not in by_name or d_max < 2:
            raise RuntimeError(
                f"{path.name}: last run lacks the shard/solve_d1 + "
                "shard/solve_dN sweep rows"
            )
        top = by_name[f"shard/solve_d{d_max}"]
        m = re.search(r"speedup_vs_1=(\d+(?:\.\d+)?)", top["derived"])
        if m is None:
            raise RuntimeError(
                f"{path.name}: shard/solve_d{d_max} derived lacks "
                f"speedup_vs_1=: {top!r}"
            )
        speedup = float(m.group(1))
        cores = (last.get("env") or {}).get("cpu_count") or os.cpu_count() or 1
        need = 2.0 if cores >= 4 else (1.3 if cores >= 2 else None)
        if need is None:
            if "gate=waived" not in top["derived"]:
                raise RuntimeError(
                    f"{path.name}: single-core run must carry an explicit "
                    f"gate=waived note: {top!r}"
                )
        elif speedup < need:
            raise RuntimeError(
                f"{path.name}: {speedup:.2f}x aggregate throughput at "
                f"{d_max} devices is below the {need:.1f}x bar "
                f"({cores} cores)"
            )
        if "shard/kernel_compiled" not in by_name:
            raise RuntimeError(
                f"{path.name}: last run lacks the shard/kernel_compiled row"
            )
    if benchmark == "ipc":
        # ISSUE-10 acceptance: both passes present, and cross-process
        # throughput within 3x of in-process at the K=64 bucket (the
        # gate is re-checked from the artifact so a stale row can't
        # quietly pass CI)
        by_name = {row["name"]: row for row in last["rows"]}
        cross = next(
            (r for n, r in by_name.items() if n.startswith("ipc/cross_process_k")),
            None,
        )
        local = next(
            (r for n, r in by_name.items() if n.startswith("ipc/in_process_k")),
            None,
        )
        if cross is None or local is None:
            raise RuntimeError(
                f"{path.name}: last run lacks the ipc in/cross pass rows"
            )
        m = re.search(r"slowdown_vs_local=(\d+(?:\.\d+)?)x", cross["derived"])
        if m is None:
            raise RuntimeError(
                f"{path.name}: cross-process row lacks slowdown_vs_local=: "
                f"{cross!r}"
            )
        if cross["name"].endswith("_k64") and float(m.group(1)) > 3.0:
            raise RuntimeError(
                f"{path.name}: cross-process throughput fell past 3x of "
                f"in-process at K=64 ({m.group(1)}x)"
            )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated subset of benchmarks")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            series_t0 = time.perf_counter()
            rows = list(MODULES[name].run())
            wall_s = time.perf_counter() - series_t0
            for row in rows:
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.2f},{derived}", flush=True)
            if name == "mcop_backends":
                _append_trajectory(rows, wall_s=wall_s)
            elif name == "broker":
                _append_trajectory(
                    rows, _BROKER_TRAJECTORY_PATH, "broker", wall_s=wall_s
                )
                _smoke_check_trajectory(_BROKER_TRAJECTORY_PATH, "broker")
                print("broker/smoke,0.00,BENCH_broker.json ok", flush=True)
            elif name == "pipeline":
                _append_trajectory(
                    rows, _PIPELINE_TRAJECTORY_PATH, "pipeline", wall_s=wall_s
                )
                _smoke_check_trajectory(_PIPELINE_TRAJECTORY_PATH, "pipeline")
                print("pipeline/smoke,0.00,BENCH_pipeline.json ok", flush=True)
            elif name == "scale":
                _append_trajectory(
                    rows, _SCALE_TRAJECTORY_PATH, "scale", wall_s=wall_s
                )
                _smoke_check_trajectory(_SCALE_TRAJECTORY_PATH, "scale")
                print("scale/smoke,0.00,BENCH_scale.json ok", flush=True)
            elif name == "faults":
                _append_trajectory(
                    rows, _FAULTS_TRAJECTORY_PATH, "faults", wall_s=wall_s
                )
                _smoke_check_trajectory(_FAULTS_TRAJECTORY_PATH, "faults")
                print("faults/smoke,0.00,BENCH_faults.json ok", flush=True)
            elif name == "shard":
                _append_trajectory(
                    rows, _SHARD_TRAJECTORY_PATH, "shard", wall_s=wall_s
                )
                _smoke_check_trajectory(_SHARD_TRAJECTORY_PATH, "shard")
                print("shard/smoke,0.00,BENCH_shard.json ok", flush=True)
            elif name == "ipc":
                _append_trajectory(
                    rows, _IPC_TRAJECTORY_PATH, "ipc", wall_s=wall_s
                )
                _smoke_check_trajectory(_IPC_TRAJECTORY_PATH, "ipc")
                print("ipc/smoke,0.00,BENCH_ipc.json ok", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0.00,{e!r}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
