"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only complexity,gains,...]

Prints ``name,us_per_call,derived`` CSV.  Mapping to the paper:

    complexity      → Fig. 14 (runtime vs |V|, B&B comparator)
    gains           → Figs. 17–19 (schemes vs B and F; 3 cost models)
    optimality_gap  → beyond-paper: Theorem 1 gap quantification
    mcop_backends   → §3.1 real-time requirement (ref vs jit vs Pallas)
    roofline        → §Roofline table from the dry-run artifact
"""

from __future__ import annotations

import argparse
import sys

from benchmarks import (
    complexity,
    compression_ablation,
    gains,
    mcop_backends,
    optimality_gap,
    roofline,
)

MODULES = {
    "complexity": complexity,
    "gains": gains,
    "optimality_gap": optimality_gap,
    "mcop_backends": mcop_backends,
    "compression_ablation": compression_ablation,
    "roofline": roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated subset of benchmarks")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            for row in MODULES[name].run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.2f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0.00,{e!r}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
