"""Cross-process serving overhead: wire-protocol broker vs in-process.

Drives the SAME request stream twice — once against an in-process
``OffloadBroker`` and once through a ``BrokerClient`` talking to a real
solver subprocess (``examples/serve_broker.py``) over a unix socket —
and reports req/s and p99 per-request latency for each.  The delta is
what the serving plane *costs*: framing, journaling, the snapshot loop
and a socket round-trip per submit+tick.

The workload is solve-dominated on purpose: distinct environments over a
``REPRO_IPC_K``-vertex WCG (default 64, the shard benchmark's bucket),
so the wire overhead is amortised against real min-cut work rather than
measured against a no-op.  Both passes use the reference backend — no
jit compiles land inside either timed loop, and replies are asserted
bit-identical across the wire before any number is reported.

Rows are appended to ``BENCH_ipc.json`` by ``benchmarks/run.py`` and
smoke-checked: cross-process throughput must stay within 3x of
in-process at K=64.  ``REPRO_IPC_REQS`` trims the stream for the CI
smoke run.
"""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro.core import AppProfile, ResponseTimeModel, random_wcg
from repro.service import BrokerClient, OffloadBroker, unix_address
from repro.service.workload import environment_trace

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SERVER = _REPO_ROOT / "examples" / "serve_broker.py"
_READY_TIMEOUT_S = 60.0

GATE_RATIO = 3.0  # cross-process must stay within 3x of in-process


def _profile(k: int) -> AppProfile:
    # mirrors examples/serve_broker.py demo_tenant: both processes build
    # the tenant independently from the same seed
    return AppProfile.from_wcg_times(
        random_wcg(k, rng=np.random.default_rng(0))
    )


def _start_server(tmp: pathlib.Path, k: int) -> subprocess.Popen:
    cmd = [
        sys.executable, str(_SERVER),
        "--socket", str(tmp / "solver.sock"),
        "--journal", str(tmp / "journal.jsonl"),
        "--snapshot-dir", str(tmp / "snaps"),
        "--nodes", str(k), "--seed", "0",
    ]
    env = dict(os.environ, PYTHONPATH=str(_REPO_ROOT / "src"))
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True, env=env)
    deadline = time.monotonic() + _READY_TIMEOUT_S
    for line in proc.stdout:
        if line.startswith("READY"):
            return proc
        if time.monotonic() > deadline:
            break
    proc.kill()
    raise RuntimeError("solver subprocess never became READY")


def _sig(reply) -> tuple:
    res = reply.result
    return (
        None if res is None else (
            float(res.min_cut),
            np.asarray(res.local_mask, bool).tobytes(),
        ),
        reply.cache_hit,
        reply.tick,
    )


def _measure(submit, tick, envs) -> dict:
    """submit+tick per request; per-request wall latency and signatures."""
    lat_s: list[float] = []
    sigs: list[tuple] = []
    t0 = time.perf_counter()
    for env in envs:
        r0 = time.perf_counter()
        fut = submit("app", env)
        tick()
        assert fut.done, "request unresolved after its tick"
        lat_s.append(time.perf_counter() - r0)
        sigs.append(_sig(fut.result))
    elapsed = time.perf_counter() - t0
    return {
        "elapsed": elapsed,
        "req_s": len(envs) / max(elapsed, 1e-12),
        "p99_ms": float(np.percentile(lat_s, 99)) * 1e3,
        "sigs": sigs,
    }


def run() -> list[dict]:
    k = int(os.environ.get("REPRO_IPC_K", "64"))
    n_reqs = int(os.environ.get("REPRO_IPC_REQS", "48"))
    profile = _profile(k)
    envs = environment_trace(n_reqs, seed=13)

    # --- in-process baseline ---------------------------------------------
    broker = OffloadBroker(backend="reference", clock=lambda: 0.0)
    broker.register("app", profile, ResponseTimeModel())
    local = _measure(broker.submit, broker.tick, envs)

    # --- cross-process over a unix socket --------------------------------
    import tempfile

    with tempfile.TemporaryDirectory(prefix="bench_ipc_") as tmp_s:
        tmp = pathlib.Path(tmp_s)
        proc = _start_server(tmp, k)
        try:
            client = BrokerClient(
                unix_address(tmp / "solver.sock"),
                tenants={"app": (profile, ResponseTimeModel())},
                client="bench",
            )
            client.connect()
            remote = _measure(client.submit, client.tick, envs)
            stream = client._stream
            wire_bytes = (
                (stream.bytes_in + stream.bytes_out) if stream else 0
            )
            client.close()
        finally:
            proc.kill()
            proc.wait()

    # replies across the wire must be the in-process replies, bit for bit
    if remote["sigs"] != local["sigs"]:
        raise RuntimeError("cross-process replies diverged from in-process")

    ratio = local["req_s"] / max(remote["req_s"], 1e-12)
    rows = [
        {
            "name": f"ipc/in_process_k{k}",
            "us_per_call": local["elapsed"] / n_reqs * 1e6,
            "derived": (
                f"req_s={local['req_s']:.0f}; p99_ms={local['p99_ms']:.2f};"
                f" reqs={n_reqs}"
            ),
        },
        {
            "name": f"ipc/cross_process_k{k}",
            "us_per_call": remote["elapsed"] / n_reqs * 1e6,
            "derived": (
                f"req_s={remote['req_s']:.0f}; p99_ms={remote['p99_ms']:.2f};"
                f" reqs={n_reqs}; slowdown_vs_local={ratio:.2f}x;"
                f" wire_bytes={wire_bytes}"
            ),
        },
    ]

    # acceptance: the wire must not cost an order of magnitude at the
    # 64-vertex bucket (the gate benchmarks/run.py re-checks from the
    # artifact)
    if k == 64 and ratio > GATE_RATIO:
        raise RuntimeError(
            f"cross-process throughput fell past {GATE_RATIO:.0f}x of "
            f"in-process: {remote['req_s']:.0f} vs {local['req_s']:.0f} req/s"
        )
    return rows
