"""MCOP backend runtimes: numpy reference vs jitted-JAX vs Pallas-phase.

The paper's §3.1 requires a *real-time online* partitioner.  This
benchmark times the three implementations across graph sizes — the JAX
and Pallas variants exist so the partitioner can run on-device inside a
jitted control loop (the CPU timings here are indicative only; the point
on TPU is avoiding the host round-trip entirely).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import mcop_jax, mcop_reference, random_wcg
from repro.core.mcop import _mcop_jax_impl
import jax.numpy as jnp


def _time(fn, reps=3) -> float:
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[dict]:
    rows: list[dict] = []
    for n in (16, 64, 128):
        g = random_wcg(n, edge_prob=0.2, rng=np.random.default_rng(n))
        rows.append(
            {
                "name": f"backends/reference_n{n}",
                "us_per_call": _time(lambda: mcop_reference(g)) * 1e6,
                "derived": "",
            }
        )
        # jit once, measure steady-state
        adj = jnp.asarray(g.adj, jnp.float32)
        wl = jnp.asarray(g.w_local, jnp.float32)
        wc = jnp.asarray(g.w_cloud, jnp.float32)
        pin = jnp.asarray(~g.offloadable)
        _mcop_jax_impl(adj, wl, wc, pin)[0].block_until_ready()
        rows.append(
            {
                "name": f"backends/jax_jitted_n{n}",
                "us_per_call": _time(
                    lambda: _mcop_jax_impl(adj, wl, wc, pin)[0].block_until_ready()
                )
                * 1e6,
                "derived": "steady-state (compiled)",
            }
        )
        cut_ref = mcop_reference(g).min_cut
        cut_jax = float(_mcop_jax_impl(adj, wl, wc, pin)[0])
        assert abs(cut_ref - cut_jax) / max(cut_ref, 1e-9) < 1e-4, (cut_ref, cut_jax)
    # Pallas interpret-mode is Python-speed on CPU; time one small case so
    # the number is recorded, flagged as interpret-only.
    from repro.kernels import mcop_min_cut

    g = random_wcg(16, edge_prob=0.2, rng=np.random.default_rng(16))
    rows.append(
        {
            "name": "backends/pallas_phase_n16_interpret",
            "us_per_call": _time(
                lambda: mcop_min_cut(g.adj, g.w_local, g.w_cloud, g.offloadable),
                reps=1,
            )
            * 1e6,
            "derived": "interpret=True (CPU); compiled on TPU target",
        }
    )
    return rows
