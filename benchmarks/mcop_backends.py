"""MCOP backend runtimes: reference vs jitted-JAX vs batched vs Pallas.

The paper's §3.1 requires a *real-time online* partitioner.  This
benchmark times the implementations across graph sizes — the JAX and
Pallas variants exist so the partitioner can run on-device inside a
jitted control loop (the CPU timings here are indicative only; the point
on TPU is avoiding the host round-trip entirely).

The ``jax_vmap_bucketed`` rows measure the throughput path: B graphs
padded into one static bucket and solved by a single vmapped dispatch
(`core.mcop.mcop_batch`), reported as per-graph µs with the speedup over
the serial `_mcop_jax_impl` loop — the number that decides whether an
environment sweep or a multi-user tick is dispatch-bound.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import mcop_jax, mcop_reference, random_wcg
from repro.core.mcop import _mcop_jax_impl, mcop_batch
import jax.numpy as jnp


def _time(fn, reps=3) -> float:
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[dict]:
    rows: list[dict] = []
    for n in (16, 64, 128):
        g = random_wcg(n, edge_prob=0.2, rng=np.random.default_rng(n))
        rows.append(
            {
                "name": f"backends/reference_n{n}",
                "us_per_call": _time(lambda: mcop_reference(g)) * 1e6,
                "derived": "",
            }
        )
        # jit once, measure steady-state
        adj = jnp.asarray(g.adj, jnp.float32)
        wl = jnp.asarray(g.w_local, jnp.float32)
        wc = jnp.asarray(g.w_cloud, jnp.float32)
        pin = jnp.asarray(~g.offloadable)
        _mcop_jax_impl(adj, wl, wc, pin)[0].block_until_ready()
        rows.append(
            {
                "name": f"backends/jax_jitted_n{n}",
                "us_per_call": _time(
                    lambda: _mcop_jax_impl(adj, wl, wc, pin)[0].block_until_ready()
                )
                * 1e6,
                "derived": "steady-state (compiled)",
            }
        )
        cut_ref = mcop_reference(g).min_cut
        cut_jax = float(_mcop_jax_impl(adj, wl, wc, pin)[0])
        assert abs(cut_ref - cut_jax) / max(cut_ref, 1e-9) < 1e-4, (cut_ref, cut_jax)
    # ---- batched path: one vmapped dispatch vs a serial jitted loop ----
    for n in (16, 64, 128):
        reps = {16: 9, 64: 5}.get(n, 3)  # small cases are noise-sensitive
        for batch in (8, 32):
            gs = [
                random_wcg(n, edge_prob=0.2, rng=np.random.default_rng(7000 + n + i))
                for i in range(batch)
            ]

            # end-to-end serial client: per-graph host→device conversion,
            # one dispatch per graph, per-graph result extraction — what
            # the adaptive loop did per environment point before batching.
            def serial_loop():
                out = []
                for g in gs:
                    cut, mask = _mcop_jax_impl(
                        jnp.asarray(g.adj, jnp.float32),
                        jnp.asarray(g.w_local, jnp.float32),
                        jnp.asarray(g.w_cloud, jnp.float32),
                        jnp.asarray(~g.offloadable),
                    )
                    out.append((float(cut), np.asarray(mask)))
                return out

            serial_loop()  # compile once (all graphs share one shape)
            t_serial = _time(serial_loop, reps=reps)

            def batched():
                mcop_batch(gs, buckets=(16, 64, 128))

            batched()  # compile the bucket program
            t_batched = _time(batched, reps=reps)
            speedup = t_serial / t_batched
            rows.append(
                {
                    "name": f"backends/jax_vmap_bucketed_n{n}xB{batch}",
                    "us_per_call": t_batched / batch * 1e6,
                    "derived": f"{speedup:.1f}x vs serial _mcop_jax_impl loop"
                    f" ({t_serial / batch * 1e6:.0f} us/graph serial)",
                }
            )

    # Pallas interpret-mode is Python-speed on CPU; time one small case so
    # the number is recorded, flagged as interpret-only.
    from repro.kernels import mcop_min_cut

    g = random_wcg(16, edge_prob=0.2, rng=np.random.default_rng(16))
    rows.append(
        {
            "name": "backends/pallas_phase_n16_interpret",
            "us_per_call": _time(
                lambda: mcop_min_cut(g.adj, g.w_local, g.w_cloud, g.offloadable),
                reps=1,
            )
            * 1e6,
            "derived": "interpret=True (CPU); compiled on TPU target",
        }
    )
    return rows
