"""Array-native session engine scale: ticks/sec and µs/user vs U.

The PR-6 tentpole number: one :class:`~repro.service.session.BatchSessionGroup`
holding U sessions is driven through seeded churning traffic
(:class:`~repro.service.workload.TrafficGenerator` — Poisson arrivals,
geometric churn) for a few broker ticks at U ∈ {1k, 10k, 100k, 1M}, and
the row reports ticks/sec and µs per user-observation.  Traffic
generation is pre-computed outside the timed region, and two warm-up
ticks absorb jit compilation plus the first-tick solve burst, so the
number is the steady-state tick cost.

A per-object :class:`~repro.service.session.BrokerSession` baseline runs
at U=1k; the acceptance criterion — batched µs/user at U=100k strictly
below the per-object µs/user at U=1k — is asserted here, so a regression
fails the benchmark run loudly instead of shipping a slow engine.

``REPRO_SCALE_U`` is a *ceiling*: only U values at or below it run, and
the object baseline/assertion is skipped (the comparison needs the full
sweep to be meaningful).  ``REPRO_SCALE_U=1000`` is the CI smoke
configuration — exactly the U=1k point.

Rows are appended to ``BENCH_scale.json`` by ``benchmarks/run.py`` (a
bounded trajectory, like ``BENCH_broker.json``) and schema-checked after
each append.
"""

from __future__ import annotations

import os
import time

from repro.core import AppProfile, ResponseTimeModel, face_recognition_graph
from repro.service import (
    OffloadBroker,
    TrafficGenerator,
    run_workload,
    user_traces,
)

U_VALUES = (1_000, 10_000, 100_000, 1_000_000)
OBJECT_U = 1_000
STEPS = 5
WARMUP = 2


def _profile() -> AppProfile:
    return AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )


def _time_batch(profile: AppProfile, u: int) -> dict:
    broker = OffloadBroker(backend="jax")
    broker.register("app", profile, ResponseTimeModel())
    group = broker.register_batch("app", u, threshold=0.15, min_interval=2)
    gen = TrafficGenerator(
        u,
        seed=7,
        arrival_rate=max(1.0, 0.02 * u),
        churn=0.02,
        initial=u,
    )
    # traffic outside the timed region: the benchmark measures the tick
    ticks = [gen.step() for _ in range(WARMUP + STEPS)]
    for tk in ticks[:WARMUP]:
        group.observe(tk.envs, arrived=tk.arrived, departed=tk.departed)
        broker.tick()
    t0 = time.perf_counter()
    for tk in ticks[WARMUP:]:
        group.observe(tk.envs, arrived=tk.arrived, departed=tk.departed)
        broker.tick()
    elapsed = time.perf_counter() - t0
    reports = group.drain()
    us_user = elapsed / (STEPS * u) * 1e6
    tel = broker.telemetry
    return {
        "name": f"scale/batch_u{u}",
        "us_per_call": us_user,
        "derived": f"{STEPS / elapsed:.2f} ticks/s; {us_user:.2f} us/user;"
        f" sessions={tel.batch_sessions} solved={tel.batch_solved}"
        f" hits={sum(r.hits + r.coalesced for r in reports)}",
        "_us_user": us_user,
    }


def _time_object(profile: AppProfile, u: int) -> dict:
    broker = OffloadBroker(backend="jax")
    broker.register("app", profile, ResponseTimeModel())
    traces = user_traces(u, STEPS, seed=7)  # pre-generated, untimed
    t0 = time.perf_counter()
    run_workload(
        broker,
        "app",
        n_users=u,
        steps=STEPS,
        threshold=0.15,
        min_interval=2,
        traces=traces,
    )
    elapsed = time.perf_counter() - t0
    us_user = elapsed / (STEPS * u) * 1e6
    tel = broker.telemetry
    return {
        "name": f"scale/object_u{u}",
        "us_per_call": us_user,
        "derived": f"{STEPS / elapsed:.2f} ticks/s; {us_user:.2f} us/user;"
        f" per-object BrokerSession baseline; hit={tel.hit_rate:.2f}",
        "_us_user": us_user,
    }


def run() -> list[dict]:
    profile = _profile()
    smoke_u = os.environ.get("REPRO_SCALE_U")
    if smoke_u:
        ceiling = int(smoke_u)
        u_values = tuple(u for u in U_VALUES if u <= ceiling) or (ceiling,)
    else:
        u_values = U_VALUES

    rows = [_time_batch(profile, u) for u in u_values]
    if not smoke_u:
        obj = _time_object(profile, OBJECT_U)
        rows.append(obj)
        # acceptance: amortization must beat the per-object engine by
        # two orders of user count — batched 100k under per-object 1k
        big = next(r for r in rows if r["name"] == f"scale/batch_u{U_VALUES[-1]}")
        if not big["_us_user"] < obj["_us_user"]:
            raise RuntimeError(
                f"scale regression: batch@{U_VALUES[-1]} "
                f"{big['_us_user']:.2f} us/user is not below per-object "
                f"@{OBJECT_U} {obj['_us_user']:.2f} us/user"
            )
        big["derived"] += (
            f"; {obj['_us_user'] / big['_us_user']:.1f}x vs object@{OBJECT_U}"
        )
    for r in rows:
        r.pop("_us_user", None)
    return rows
