"""Paper Fig. 14: MCOP running time vs number of tasks.

Measures wall time of the reference MCOP over growing |V| on the paper's
topology families, fits the theoretical O(|V|²log|V| + |V|·|E|) curve, and
contrasts the growth against the exponential branch-and-bound ("LP
solver") comparator of §5.4 — which must be cut off after a small |V|.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import branch_and_bound, linear_graph, mcop_reference, random_wcg, tree_graph


def _time(fn, *args, reps: int = 3) -> float:
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[dict]:
    rows: list[dict] = []
    sizes = [10, 20, 40, 80, 160, 320]
    times, theos = [], []
    for n in sizes:
        g = random_wcg(n, edge_prob=0.15, rng=np.random.default_rng(n))
        dt = _time(mcop_reference, g)
        e = g.num_edges
        theo = n * n * np.log(max(n, 2)) + n * e
        times.append(dt)
        theos.append(theo)
        rows.append(
            {
                "name": f"complexity/mcop_n{n}",
                "us_per_call": dt * 1e6,
                "derived": f"edges={e}",
            }
        )
    # fit quality: correlation of measured vs theoretical in log space
    corr = float(np.corrcoef(np.log(times), np.log(theos))[0, 1])
    rows.append(
        {
            "name": "complexity/theory_fit_corr",
            "us_per_call": 0.0,
            "derived": f"log-log corr={corr:.4f} (paper: 'good match')",
        }
    )

    # branch and bound blows up: time it on small graphs only
    for n in (8, 12, 16, 20):
        g = random_wcg(n, edge_prob=0.3, rng=np.random.default_rng(1000 + n))
        t0 = time.perf_counter()
        res = branch_and_bound(g, node_limit=2_000_000)
        dt = time.perf_counter() - t0
        rows.append(
            {
                "name": f"complexity/bnb_n{n}",
                "us_per_call": dt * 1e6,
                "derived": f"nodes_expanded={res.nodes_expanded}",
            }
        )
    # headline ratio at n=20
    g = random_wcg(20, edge_prob=0.3, rng=np.random.default_rng(1020))
    t_mcop = _time(mcop_reference, g)
    t0 = time.perf_counter()
    branch_and_bound(g, node_limit=2_000_000)
    t_bnb = time.perf_counter() - t0
    rows.append(
        {
            "name": "complexity/mcop_vs_bnb_speedup_n20",
            "us_per_call": t_mcop * 1e6,
            "derived": f"bnb/mcop={t_bnb / max(t_mcop, 1e-12):.1f}x",
        }
    )
    return rows
