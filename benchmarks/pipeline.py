"""Fused environment→placement pipeline vs the object path, and the
fused pricing/telemetry side vs the scalar ``_emit`` path.

The paper's Fig.-1 loop re-partitions whenever the environment drifts;
serving-scale sweeps (adaptive controllers, broker ticks, bandwidth
forecasts) solve K environments of ONE profiled application at a time.
Two ways to do that:

* **object path** — K per-environment Python ``cost_model.build`` calls
  producing ``WCG`` objects, packed by ``mcop_batch`` into a bucket and
  dispatched (the pre-fusion pipeline);
* **fused path** — ``core.mcop.solve_envs``: construction AND the batched
  Stoer–Wagner solver jitted into one XLA program, six scalars per
  environment crossing the host boundary.

Both produce identical placements (asserted here on every run); the
difference is pure host-side construction/packing overhead, which is
exactly what dominates once the solve itself is a single dispatch.

The **pricing** series measure the telemetry side of the same sweep:
every event needs the current placement's cost plus the §7.1
no-offload/full-offload baselines.  The scalar path (what
``AdaptiveController._emit`` did before the pricing fusion) materializes
one WCG per environment and runs three ``total_cost``-class evaluations
each; the fused path (``core.pricing.price_trace``) prices the whole
trace in one vectorized evaluation — with *bit-identical* numbers,
asserted on every run.  The fused/scalar ratio at K=64 is the acceptance
number for the pricing fusion (target ≥2×); all rows are appended to
``BENCH_pipeline.json`` by ``benchmarks/run.py``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    AppProfile,
    Environment,
    ResponseTimeModel,
    WeightedModel,
    baselines,
    face_recognition_graph,
    mcop_batch,
    offloading_gain,
    price_trace,
    solve_envs,
)


def _time(fn, reps: int) -> float:
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _env_sweep(k: int) -> list[Environment]:
    """K distinct (B, F) points spanning the paper's §7 regimes."""
    bands = np.geomspace(0.25, 16.0, k)
    speeds = 1.5 + 2.5 * (np.arange(k) % 4) / 3.0
    return [Environment.symmetric(float(b), float(f)) for b, f in zip(bands, speeds)]


def run() -> list[dict]:
    rows: list[dict] = []
    profile = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    for model, k, reps in (
        (ResponseTimeModel(), 8, 9),
        (ResponseTimeModel(), 64, 5),
        (WeightedModel(0.5), 64, 5),
    ):
        envs = _env_sweep(k)

        def object_path():
            return mcop_batch(
                [model.build(profile, e) for e in envs], backend="jax"
            )

        def fused_path():
            return solve_envs(profile, model, envs, backend="jax")

        obj = object_path()    # compile + parity reference
        fused = fused_path()
        for a, b in zip(obj, fused):
            if not (a.local_mask == b.local_mask).all():
                # construction rounds in solver precision on the fused
                # path; an exact cut tie may resolve differently, but the
                # costs must agree — anything else is a real divergence
                rel = abs(a.min_cut - b.min_cut) / max(abs(a.min_cut), 1e-30)
                assert rel < 1e-5, f"fused/object divergence: {rel}"

        t_obj = _time(object_path, reps)
        t_fused = _time(fused_path, reps)
        speedup = t_obj / t_fused
        tag = f"{model.name}_k{k}"
        rows.append(
            {
                "name": f"pipeline/object_{tag}",
                "us_per_call": t_obj / k * 1e6,
                "derived": f"{k} cost_model.build calls + packed mcop_batch",
            }
        )
        rows.append(
            {
                "name": f"pipeline/fused_{tag}",
                "us_per_call": t_fused / k * 1e6,
                "derived": f"{speedup:.1f}x vs object path"
                f" ({t_obj / k * 1e6:.0f} us/env object); placements identical",
            }
        )
    rows.extend(_pricing_rows(profile))
    return rows


def _pricing_rows(profile: AppProfile, k: int = 64, reps: int = 7) -> list[dict]:
    """Sweep telemetry: fused ``price_trace`` vs the scalar ``_emit`` path.

    The placements priced are the sweep's own solutions, so the workload
    is exactly what ``AdaptiveController.sweep`` pays per trace; the
    scalar loop reproduces the pre-fusion pass 3 (materialize one WCG
    per environment + three scalar evaluations + the gain).
    """
    model = ResponseTimeModel()
    envs = _env_sweep(k)
    placements = solve_envs(profile, model, envs, backend="jax")
    masks = [r.local_mask for r in placements]
    batch = model.build_batch(profile, envs)

    def scalar_emit():
        out = []
        for i in range(k):
            g = batch.wcg(i)
            partial = g.total_cost(masks[i])
            no_off = baselines.no_offloading(g).cost
            full = baselines.full_offloading(g).cost
            out.append((partial, no_off, full, offloading_gain(no_off, partial)))
        return out

    def fused_pricing():
        return price_trace(profile, model, list(zip(envs, masks)))

    scalar = scalar_emit()
    report = fused_pricing()
    for i, (partial, no_off, full, gain) in enumerate(scalar):
        assert report.row(i) == (partial, no_off, full, gain), (
            "fused pricing diverged from the scalar _emit path"
        )

    t_scalar = _time(scalar_emit, reps)
    t_fused = _time(fused_pricing, reps)
    speedup = t_scalar / t_fused
    return [
        {
            "name": f"pipeline/pricing_scalar_k{k}",
            "us_per_call": t_scalar / k * 1e6,
            "derived": f"{k} x (wcg materialize + 3 scalar evals + gain)",
        },
        {
            "name": f"pipeline/pricing_fused_k{k}",
            "us_per_call": t_fused / k * 1e6,
            "derived": f"{speedup:.1f}x vs scalar _emit path"
            f" ({t_scalar / k * 1e6:.0f} us/env scalar); numbers bit-identical",
        },
    ]
