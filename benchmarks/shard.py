"""Sharded solver fleet: aggregate solve throughput vs device count.

The PR-9 tentpole number: the same K-graph, 64-vertex-bucket solve batch
is dispatched through :func:`repro.core.mcop.solve_envs` at simulated
fleet sizes D ∈ {1, 2, 4, 8}.  Each fleet size runs in a fresh
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=D``
exported *before* jax is imported (device count is frozen at first
import), so the parent process stays single-device and the child sees an
honest D-device mesh.  The sharded dispatcher is exercised through its
transparent path — the child passes ``mesh=None`` and the broker/solve
plane auto-detects the fleet — which is exactly what production code
does.

Per fleet size the child reports:

* ``shard/solve_dD``  — µs per graph for one ``solve_envs`` dispatch of
  the K-graph bucket (best of ``REPS`` steady-state calls), plus
  aggregate graphs/s;
* ``shard/tick_dD``   — broker tick throughput with a K-session batch
  group forced to re-solve every tick (threshold 0, churning traffic).

The d8 solve row carries ``speedup_vs_1=…`` — aggregate throughput at 8
devices over 1 — and a gate note.  ``benchmarks/run.py`` smoke-checks
it: ≥2× on hosts with ≥4 cores, ≥1.3× with ≥2 cores, and waived (with
an explicit note in the artifact) on single-core hosts where 8 simulated
devices share one physical core and no parallel speedup is physically
available.

Two kernel rows compare the compiled and interpret Pallas tiers on a
tiny batch: ``shard/kernel_interpret`` times the blocked
``mcop_stoer_wagner_kernel`` under ``interpret=True``;
``shard/kernel_compiled`` attempts ``interpret=False`` and — on
platforms whose Pallas lowering cannot compile (CPU) — records the
refusal instead of a time, so the artifact states *why* the compiled
tier is absent rather than silently omitting it.

``REPRO_SHARD_K`` shrinks the solve batch (CI smoke);
``REPRO_SHARD_DEVICES`` (comma-separated) restricts the fleet sweep.

Rows are appended to ``BENCH_shard.json`` by ``benchmarks/run.py`` and
smoke-checked after each append.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 2, 4, 8)
DEFAULT_K = 512          # graphs per solve dispatch (the K=64-bucket batch)
N_VERTICES = 40          # pads to the 64-vertex bucket (DEFAULT_BUCKETS)
REPS = 3                 # steady-state solve repetitions (best-of)
TICK_WARMUP = 1
TICK_STEPS = 3
KERNEL_B = 4             # tiny batch for the interpret-tier kernel row
KERNEL_N = 16

_HERE = pathlib.Path(__file__).resolve()
_RESULT_TAG = "SHARD_RESULT "


def _shard_k() -> int:
    return max(8, int(os.environ.get("REPRO_SHARD_K", DEFAULT_K)))


def _device_counts() -> tuple[int, ...]:
    raw = os.environ.get("REPRO_SHARD_DEVICES")
    if not raw:
        return DEVICE_COUNTS
    return tuple(sorted({int(tok) for tok in raw.split(",") if tok.strip()}))


# ----------------------------------------------------------------------
# Child: one fleet size, measured behind a forced host device count
# ----------------------------------------------------------------------


def _worker(devices_requested: int) -> None:
    """Runs in a subprocess with XLA_FLAGS already exported."""
    import jax
    import numpy as np

    from repro.core import AppProfile, ResponseTimeModel, linear_graph
    from repro.core.cost_models import EnvArrays
    from repro.core.mcop import solve_envs
    from repro.service import OffloadBroker, TrafficGenerator

    assert jax.device_count() == devices_requested, (
        jax.device_count(),
        devices_requested,
    )
    k = _shard_k()
    rng = np.random.default_rng(11)
    profile = AppProfile.from_wcg_times(linear_graph(N_VERTICES, rng=rng))
    model = ResponseTimeModel()
    envs = EnvArrays(*(rng.uniform(0.5, 5.0, k) for _ in range(6)))

    # mesh=None everywhere: the transparent auto-detect path is the
    # production path, and it is what this benchmark certifies.
    solve_envs(profile, model, envs, backend="jax")  # compile + warm
    solve_s = min(
        _timed(lambda: solve_envs(profile, model, envs, backend="jax"))
        for _ in range(REPS)
    )

    broker = OffloadBroker(backend="jax")
    broker.register("app", profile, model)
    group = broker.register_batch("app", k, threshold=0.0, min_interval=1)
    gen = TrafficGenerator(
        k, seed=7, arrival_rate=max(1.0, 0.02 * k), churn=0.02, initial=k
    )
    ticks = [gen.step() for _ in range(TICK_WARMUP + TICK_STEPS)]
    for tk in ticks[:TICK_WARMUP]:
        group.observe(tk.envs, arrived=tk.arrived, departed=tk.departed)
        broker.tick()
    t0 = time.perf_counter()
    for tk in ticks[TICK_WARMUP:]:
        group.observe(tk.envs, arrived=tk.arrived, departed=tk.departed)
        broker.tick()
    tick_s = time.perf_counter() - t0

    print(
        _RESULT_TAG
        + json.dumps(
            {
                "devices": jax.device_count(),
                "k": k,
                "solve_s": solve_s,
                "tick_steps": TICK_STEPS,
                "tick_s": tick_s,
            }
        ),
        flush=True,
    )


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _run_child(devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    src = str(_HERE.parent.parent / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, str(_HERE), "--worker", str(devices)],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard worker d{devices} failed "
            f"(rc={proc.returncode}): {proc.stderr.strip()[-800:]}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith(_RESULT_TAG):
            return json.loads(line[len(_RESULT_TAG) :])
    raise RuntimeError(f"shard worker d{devices} emitted no result line")


# ----------------------------------------------------------------------
# Parent: the sweep + compiled-vs-interpret kernel rows
# ----------------------------------------------------------------------


def _speedup_gate_note(speedup: float) -> str:
    cores = os.cpu_count() or 1
    if cores >= 4:
        need = 2.0
    elif cores >= 2:
        need = 1.3
    else:
        return (
            f"gate=waived(single-core host: {cores} cpu for 8 simulated "
            "devices; no parallel speedup physically available)"
        )
    status = "met" if speedup >= need else "FAILED"
    return f"gate={status}(need {need:.1f}x at {cores} cores)"


def _fleet_rows() -> list[dict]:
    results = {d: _run_child(d) for d in _device_counts()}
    rows: list[dict] = []
    base = results.get(1)
    for d, r in sorted(results.items()):
        us_graph = r["solve_s"] / r["k"] * 1e6
        graphs_s = r["k"] / r["solve_s"]
        derived = f"graphs_s={graphs_s:.0f}; k={r['k']}; bucket=64"
        if base is not None and d == max(results):
            speedup = (base["solve_s"] / r["solve_s"]) if r["solve_s"] else 0.0
            derived += f"; speedup_vs_1={speedup:.2f}; {_speedup_gate_note(speedup)}"
        rows.append(
            {"name": f"shard/solve_d{d}", "us_per_call": us_graph, "derived": derived}
        )
        ticks_s = r["tick_steps"] / r["tick_s"] if r["tick_s"] else 0.0
        rows.append(
            {
                "name": f"shard/tick_d{d}",
                "us_per_call": r["tick_s"] / (r["tick_steps"] * r["k"]) * 1e6,
                "derived": f"{ticks_s:.2f} ticks/s; sessions={r['k']}",
            }
        )
    return rows


def _kernel_rows() -> list[dict]:
    import numpy as np

    from repro.kernels.mcop_phase import (
        default_block_graphs,
        mcop_stoer_wagner_kernel,
    )

    b, n = KERNEL_B, KERNEL_N
    rng = np.random.default_rng(3)
    adj = rng.uniform(0.1, 1.0, (b, n, n)).astype(np.float32)
    adj = adj + adj.transpose(0, 2, 1)
    adj[:, np.arange(n), np.arange(n)] = 0.0
    wl = rng.uniform(1.0, 2.0, (b, n)).astype(np.float32)
    wc = rng.uniform(0.1, 1.0, (b, n)).astype(np.float32)
    pin = np.zeros((b, n), dtype=bool)
    pin[:, 0] = True

    rows = []
    cuts, _ = mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=True)
    cuts.block_until_ready()  # compile + warm
    dt = _timed(
        lambda: mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=True)[
            0
        ].block_until_ready()
    )
    rows.append(
        {
            "name": "shard/kernel_interpret",
            "us_per_call": dt / b * 1e6,
            "derived": f"interpret=True; b={b} n={n}; block_graphs=1",
        }
    )
    g = default_block_graphs(n, False)
    try:
        cuts, _ = mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=False)
        cuts.block_until_ready()
        dt = _timed(
            lambda: mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=False)[
                0
            ].block_until_ready()
        )
        rows.append(
            {
                "name": "shard/kernel_compiled",
                "us_per_call": dt / b * 1e6,
                "derived": f"interpret=False; b={b} n={n}; block_graphs={g}",
            }
        )
    except Exception as e:  # noqa: BLE001 — platform refusal is the datum
        msg = str(e).splitlines()[0][:120]
        rows.append(
            {
                "name": "shard/kernel_compiled",
                "us_per_call": 0.0,
                "derived": f"unavailable on this platform: {msg}",
            }
        )
    return rows


def run() -> list[dict]:
    return _fleet_rows() + _kernel_rows()


if __name__ == "__main__":
    if len(sys.argv) == 3 and sys.argv[1] == "--worker":
        _worker(int(sys.argv[2]))
    else:
        for row in run():
            print(f"{row['name']},{row['us_per_call']:.2f},{row['derived']}")
