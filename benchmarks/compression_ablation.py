"""Gradient-compression ablation: DP all-reduce wire bytes per step.

Connects the trainer's compression modes to the roofline's collective
term: for each assigned dense arch, the bytes one replica puts on the
wire per optimizer step under no compression / int8 / top-k(1 %), and
the implied reduction of the DP all-reduce time at the target ICI rate.
(The §Perf collective terms measure the *uncompressed* baseline; these
rows quantify the available headroom — compression composes with every
§Perf win since it acts on a different collective.)
"""

from __future__ import annotations

import jax

from repro.configs import ARCHITECTURES, reduce_config
from repro.models.transformer import build_model
from repro.runtime import wire_bytes

ICI_BW = 50e9


def run() -> list[dict]:
    rows: list[dict] = []
    for arch in ("qwen2-7b", "qwen3-32b", "deepseek-v2-236b"):
        cfg = ARCHITECTURES[arch]
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        dense = wire_bytes(shapes, scheme="none")
        q8 = wire_bytes(shapes, scheme="int8")
        tk = wire_bytes(shapes, scheme="topk", frac=0.01)
        rows.append(
            {
                "name": f"compression/{arch}",
                "us_per_call": dense / ICI_BW * 1e6,  # bf16 all-reduce time
                "derived": (
                    f"dense={dense/2**30:.2f}GiB int8={q8/2**30:.2f}GiB "
                    f"(x{dense/q8:.1f}) topk1%={tk/2**30:.3f}GiB (x{dense/tk:.0f})"
                ),
            }
        )
    return rows
