"""Beyond-paper: MCOP's optimality gap, quantified against exact oracles.

The paper claims global optimality (Theorem 1 + §5.4); our reproduction
found counterexamples (see DESIGN.md §1.1 and tests/test_mcop_property).
This benchmark measures, per graph distribution, the fraction of
instances where MCOP is exact and the gap statistics — plus the runtime
of the exact max-flow alternative, which is what a deployment should use
(same asymptotic class, exact answer).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    linear_graph,
    loop_graph,
    maxflow_optimal,
    mcop_reference,
    random_wcg,
    tree_graph,
)


def _distribution(name: str, seed: int):
    rng = np.random.default_rng(seed)
    if name == "paper_linear":
        return linear_graph(int(rng.integers(4, 16)), rng=rng)
    if name == "paper_loop":
        return loop_graph(int(rng.integers(4, 16)), rng=rng)
    if name == "paper_tree":
        return tree_graph(int(rng.integers(4, 16)), rng=rng)
    if name == "adversarial":
        n = int(rng.integers(3, 14))
        return random_wcg(
            n,
            edge_prob=float(rng.choice([0.1, 0.3, 0.6, 0.9])),
            speedup=float(rng.choice([1.2, 2.0, 3.0, 10.0])),
            n_unoffloadable=int(rng.integers(1, max(2, n // 3))),
            rng=rng,
        )
    raise ValueError(name)


def run() -> list[dict]:
    rows: list[dict] = []
    n_trials = 150
    for dist in ("paper_linear", "paper_loop", "paper_tree", "adversarial"):
        gaps = []
        exact = 0
        t_mcop = t_exact = 0.0
        for seed in range(n_trials):
            g = _distribution(dist, seed)
            t0 = time.perf_counter()
            heur = mcop_reference(g).min_cut
            t_mcop += time.perf_counter() - t0
            t0 = time.perf_counter()
            opt = maxflow_optimal(g).cost
            t_exact += time.perf_counter() - t0
            gap = (heur - opt) / max(opt, 1e-12)
            gaps.append(gap)
            exact += gap < 1e-9
        rows.append(
            {
                "name": f"optgap/{dist}",
                "us_per_call": t_mcop / n_trials * 1e6,
                "derived": (
                    f"exact={exact / n_trials:.1%} mean_gap={np.mean(gaps):.3%} "
                    f"p95_gap={np.percentile(gaps, 95):.3%} max_gap={max(gaps):.2%} "
                    f"maxflow_us={t_exact / n_trials * 1e6:.0f}"
                ),
            }
        )
    return rows
