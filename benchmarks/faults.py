"""Fault-tolerance overhead: broker throughput under injected chaos.

Drives identical multi-user request streams through a resilient
``OffloadBroker`` (retry/backoff + circuit breaker + fallback
degradation) at deterministic fault rates {0%, 1%, 10%} and reports
throughput, p99 tick latency and the degraded-reply fraction — the
numbers that say what graceful degradation *costs* and what a fault
storm does to tail latency.

The injector is seeded, so every run replays the same fault schedule;
the rate-0 pass doubles as the no-overhead baseline (with injection
disabled the resilient tick is bit-identical to the plain one, asserted
by ``tests/test_faults.py``).  ``REPRO_FAULTS_STEPS`` trims the stream
for the CI smoke run.

Rows are appended to ``BENCH_faults.json`` by ``benchmarks/run.py`` and
smoke-checked: throughput at a 1% fault rate must stay within 2× of the
fault-free pass.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import AppProfile, ResponseTimeModel, face_recognition_graph
from repro.service import (
    CircuitBreaker,
    FaultInjector,
    OffloadBroker,
    ResiliencePolicy,
    RetryPolicy,
    user_traces,
)

RATES = ((0.0, "rate0"), (0.01, "rate1pct"), (0.10, "rate10pct"))


def _policy() -> ResiliencePolicy:
    # fast backoff: the benchmark measures orchestration overhead, not
    # configured sleep time
    return ResiliencePolicy(
        retry=RetryPolicy(
            max_retries=2, base_backoff_s=1e-4, max_backoff_s=1e-3
        ),
        degrade="fallback",
        breaker=CircuitBreaker(threshold=3, cooldown_ticks=4),
    )


def _pass(
    rate: float,
    profile: AppProfile,
    traces,
    n_users: int,
    steps: int,
) -> dict:
    broker = OffloadBroker(
        backend="jax",
        resilience=_policy(),
        fault_injector=FaultInjector(seed=2024, rate=rate, latency_s=1e-4),
    )
    broker.register("app", profile, ResponseTimeModel())
    futures = []
    t0 = time.perf_counter()
    for t in range(steps):
        for u in range(n_users):
            futures.append(broker.submit("app", traces[u][t]))
        broker.tick()
    guard = 0
    while broker.pending and guard < 4 * steps:
        broker.tick()
        guard += 1
    elapsed = time.perf_counter() - t0
    assert broker.pending == 0 and all(f.done for f in futures)
    tel = broker.telemetry
    degraded = sum(f.result.degraded for f in futures)
    p99_ms = (
        float(np.percentile([r.latency_s for r in tel.reports], 99)) * 1e3
        if tel.reports
        else 0.0
    )
    req_s = len(futures) / max(elapsed, 1e-12)
    return {
        "elapsed": elapsed,
        "requests": len(futures),
        "req_s": req_s,
        "p99_ms": p99_ms,
        "degraded_frac": degraded / max(len(futures), 1),
        "tel": tel,
    }


def run() -> list[dict]:
    profile = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    steps = int(os.environ.get("REPRO_FAULTS_STEPS", "12"))
    n_users = 16
    traces = user_traces(n_users, steps, seed=31)

    rows: list[dict] = []
    by_tag: dict[str, dict] = {}
    for rate, tag in RATES:
        # warm the jit'd bucket programs with an untimed replay of the
        # SAME pass: the injector is deterministic per tick, so forced
        # cache misses reshape the coalesced buckets identically in both
        # runs and no compile lands inside the timed loop
        _pass(rate, profile, traces, n_users, steps)
        m = _pass(rate, profile, traces, n_users, steps)
        by_tag[tag] = m
        tel = m["tel"]
        rows.append(
            {
                "name": f"faults/{tag}",
                "us_per_call": m["elapsed"] / max(m["requests"], 1) * 1e6,
                "derived": (
                    f"req_s={m['req_s']:.0f}; p99_tick_ms={m['p99_ms']:.2f};"
                    f" degraded={m['degraded_frac']:.3f};"
                    f" faults={tel.faults}; retries={tel.retries};"
                    f" trips={tel.breaker_trips};"
                    f" timed_out={tel.timed_out_requests}"
                ),
            }
        )

    # acceptance: light chaos must not halve throughput
    r0, r1 = by_tag["rate0"]["req_s"], by_tag["rate1pct"]["req_s"]
    if r1 < 0.5 * r0:
        raise RuntimeError(
            f"1% fault rate dropped throughput past 2x: {r1:.0f} vs {r0:.0f} req/s"
        )
    # a 10% storm must still resolve everything — degradation, not loss
    if by_tag["rate10pct"]["tel"].faults == 0:
        raise RuntimeError("10% pass injected no faults; schedule broken")
    return rows
