"""Offload broker tick throughput: the serving-tier number.

Drives the deterministic multi-user workload
(`repro.service.workload.run_workload`) through an `OffloadBroker` and
reports per-request latency along with the ratios that make the broker
worth running: coalesce ratio (requests that did not need their own
solve), cache hit rate, and solver dispatches per tick.  A second pass
replays the identical traces against a broker warm-started from the
first broker's cache snapshot — the serving-restart path, which must
reach zero dispatches.

A third pass measures the weighted-fair scheduler: two tenants with a
3:1 weight split submit identical load through budgeted ticks, and the
derived column reports the first-tick share split plus backpressure
rejections — the multi-tenant fairness numbers a deployment would watch.

A fourth pass gates observability overhead: the identical workload runs
with a Tracer + MetricsRegistry attached (best of 3 passes each way),
and the traced broker must stay within 1.15x of the untraced one —
instrumentation light enough to leave on in production.

Rows are appended to ``BENCH_broker.json`` by ``benchmarks/run.py`` (a
bounded trajectory, like ``BENCH_mcop.json`` for the solver backends)
and smoke-checked after each run.
"""

from __future__ import annotations

import time

from repro.core import AppProfile, Environment, ResponseTimeModel, face_recognition_graph
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.service import OffloadBroker, run_workload, user_traces

# traced ticks must stay within this factor of untraced (the "leave it
# on in production" budget; asserted here and in tests/test_observability)
TRACED_OVERHEAD_BUDGET = 1.15


def _drive(broker, traces, n_users: int, steps: int) -> float:
    t0 = time.perf_counter()
    run_workload(
        broker, "app", n_users=n_users, steps=steps, traces=traces
    )
    return time.perf_counter() - t0


def run() -> list[dict]:
    rows: list[dict] = []
    profile = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    steps = 10
    for n_users in (8, 32):
        traces = user_traces(n_users, steps, seed=7)

        cold = OffloadBroker(backend="jax")
        cold.register("app", profile, ResponseTimeModel())
        _drive(cold, traces, n_users, steps)  # compile the bucket program
        snapshot = cold.snapshot("app")

        cold2 = OffloadBroker(backend="jax")
        cold2.register("app", profile, ResponseTimeModel())
        t_cold = _drive(cold2, traces, n_users, steps)
        tel = cold2.telemetry
        rows.append(
            {
                "name": f"broker/cold_u{n_users}x{steps}",
                "us_per_call": t_cold / max(tel.requests, 1) * 1e6,
                "derived": f"{tel.dispatches} dispatches/{tel.ticks} ticks;"
                f" coalesce={tel.coalesce_ratio:.2f} hit={tel.hit_rate:.2f}"
                f" maxq={tel.max_queue_depth}",
            }
        )

        warm = OffloadBroker(backend="jax")
        warm.register("app", profile, ResponseTimeModel(), warm_start=snapshot)
        t_warm = _drive(warm, traces, n_users, steps)
        telw = warm.telemetry
        rows.append(
            {
                "name": f"broker/warm_u{n_users}x{steps}",
                "us_per_call": t_warm / max(telw.requests, 1) * 1e6,
                "derived": f"{telw.dispatches} dispatches (restart replay);"
                f" hit={telw.hit_rate:.2f}; {t_cold / max(t_warm, 1e-12):.1f}x"
                " vs cold",
            }
        )
    rows.append(_wfq_row(profile))
    rows.append(_traced_overhead_row(profile))
    return rows


def _traced_overhead_row(profile: AppProfile) -> dict:
    """Enabled-observability tick throughput vs the detached broker.

    Identical workload, best-of-3 wall time each way (damping scheduler
    noise); the ratio is gated at ``TRACED_OVERHEAD_BUDGET``.  The
    tracer ring is sized to retain the whole run, so the measurement
    includes span construction, ring appends, and registry updates.
    """
    n_users, steps = 32, 10
    traces = user_traces(n_users, steps, seed=7)

    def best_of(k: int, make) -> float:
        best = float("inf")
        for _ in range(k):
            broker = make()
            broker.register("app", profile, ResponseTimeModel())
            best = min(best, _drive(broker, traces, n_users, steps))
        return best

    best_of(1, lambda: OffloadBroker(backend="jax"))  # compile untimed
    t_plain = best_of(3, lambda: OffloadBroker(backend="jax"))
    t_traced = best_of(
        3,
        lambda: OffloadBroker(
            backend="jax",
            tracer=Tracer(capacity=16384),
            metrics=MetricsRegistry(),
        ),
    )
    ratio = t_traced / max(t_plain, 1e-12)
    if ratio > TRACED_OVERHEAD_BUDGET:
        raise RuntimeError(
            f"traced broker tick overhead {ratio:.3f}x exceeds the "
            f"{TRACED_OVERHEAD_BUDGET}x budget"
        )
    requests = n_users * steps
    return {
        "name": f"broker/traced_u{n_users}x{steps}",
        "us_per_call": t_traced / requests * 1e6,
        "derived": f"overhead={ratio:.3f}x vs untraced"
        f" (budget {TRACED_OVERHEAD_BUDGET}x; best of 3)",
    }


def _wfq_row(profile: AppProfile) -> dict:
    """Weighted-fair scheduling under mixed two-tenant load.

    Both tenants submit the same 24-bin sweep; budgeted ticks (8
    requests each) drain them 3:1 until the queues empty, with a
    64-bin backpressure cap armed.
    """
    broker = OffloadBroker(backend="jax", max_queued_bins=64)
    broker.register("heavy", profile, ResponseTimeModel(), weight=3.0)
    broker.register("light", profile, ResponseTimeModel(), weight=1.0)
    envs = [Environment.symmetric(0.25 * (1.3 ** i), 3.0) for i in range(24)]
    t0 = time.perf_counter()
    for env in envs:
        broker.submit("heavy", env)
        broker.submit("light", env)
    ticks = 0
    while broker.pending:
        broker.tick(budget=8)
        ticks += 1
    elapsed = time.perf_counter() - t0
    tel = broker.telemetry
    requests = max(tel.requests, 1)
    first = dict(tel.reports[0].shares) if tel.reports else {}
    return {
        "name": "broker/wfq_2tenants_b8",
        "us_per_call": elapsed / requests * 1e6,
        "derived": f"{ticks} budgeted ticks; first-tick split "
        f"heavy:light={first.get('heavy', 0)}:{first.get('light', 0)} (weights 3:1);"
        f" rejected={tel.rejected_requests}",
    }
