"""Offload broker tick throughput: the serving-tier number.

Drives the deterministic multi-user workload
(`repro.service.workload.run_workload`) through an `OffloadBroker` and
reports per-request latency along with the ratios that make the broker
worth running: coalesce ratio (requests that did not need their own
solve), cache hit rate, and solver dispatches per tick.  A second pass
replays the identical traces against a broker warm-started from the
first broker's cache snapshot — the serving-restart path, which must
reach zero dispatches.

Rows are appended to ``BENCH_broker.json`` by ``benchmarks/run.py`` (a
bounded trajectory, like ``BENCH_mcop.json`` for the solver backends)
and smoke-checked after each run.
"""

from __future__ import annotations

import time

from repro.core import AppProfile, ResponseTimeModel, face_recognition_graph
from repro.service import OffloadBroker, run_workload, user_traces


def _drive(broker, traces, n_users: int, steps: int) -> float:
    t0 = time.perf_counter()
    run_workload(
        broker, "app", n_users=n_users, steps=steps, traces=traces
    )
    return time.perf_counter() - t0


def run() -> list[dict]:
    rows: list[dict] = []
    profile = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    steps = 10
    for n_users in (8, 32):
        traces = user_traces(n_users, steps, seed=7)

        cold = OffloadBroker(backend="jax")
        cold.register("app", profile, ResponseTimeModel())
        _drive(cold, traces, n_users, steps)  # compile the bucket program
        snapshot = cold.snapshot("app")

        cold2 = OffloadBroker(backend="jax")
        cold2.register("app", profile, ResponseTimeModel())
        t_cold = _drive(cold2, traces, n_users, steps)
        tel = cold2.telemetry
        rows.append(
            {
                "name": f"broker/cold_u{n_users}x{steps}",
                "us_per_call": t_cold / max(tel.requests, 1) * 1e6,
                "derived": f"{tel.dispatches} dispatches/{tel.ticks} ticks;"
                f" coalesce={tel.coalesce_ratio:.2f} hit={tel.hit_rate:.2f}"
                f" maxq={tel.max_queue_depth}",
            }
        )

        warm = OffloadBroker(backend="jax")
        warm.register("app", profile, ResponseTimeModel(), warm_start=snapshot)
        t_warm = _drive(warm, traces, n_users, steps)
        telw = warm.telemetry
        rows.append(
            {
                "name": f"broker/warm_u{n_users}x{steps}",
                "us_per_call": t_warm / max(telw.requests, 1) * 1e6,
                "derived": f"{telw.dispatches} dispatches (restart replay);"
                f" hit={telw.hit_rate:.2f}; {t_cold / max(t_warm, 1e-12):.1f}x"
                " vs cold",
            }
        )
    return rows
