"""Roofline table from the dry-run artifact (results/dryrun_all.json).

Per (arch × shape × mesh): the three terms (compute / HBM / interconnect)
in seconds, the dominant one, MODEL_FLOPS/HLO_FLOPS (useful-compute
ratio), and the roofline fraction

    frac = compute_term / max(compute, memory, collective)

— i.e. how close the cell is to being compute-bound at the paper's-target
hardware rates (TPU v5e: 197 TF bf16, 819 GB/s HBM, ~50 GB/s ICI).

Also nominates the three hillclimb cells per the assignment: worst
roofline fraction, most collective-bound, most representative of the
paper's technique (the biggest train cell — placement operates on its
stage graph).
"""

from __future__ import annotations

import json
import os

ARTIFACT = os.environ.get("DRYRUN_JSON", "")
if not ARTIFACT:
    for cand in ("results/dryrun_corrected.json", "results/dryrun_all.json"):
        if os.path.exists(cand):
            ARTIFACT = cand
            break
    else:
        ARTIFACT = "results/dryrun_all.json"


def run() -> list[dict]:
    rows: list[dict] = []
    if not os.path.exists(ARTIFACT):
        rows.append(
            {
                "name": "roofline/missing_artifact",
                "us_per_call": 0.0,
                "derived": f"run `python -m repro.launch.dryrun --all --out {ARTIFACT}` first",
            }
        )
        return rows
    with open(ARTIFACT) as f:
        cells = json.load(f)

    ok = [c for c in cells if "roofline" in c]
    err = [c for c in cells if "error" in c]
    skipped = [c for c in cells if "skipped" in c]
    rows.append(
        {
            "name": "roofline/cells",
            "us_per_call": 0.0,
            "derived": f"ok={len(ok)} errors={len(err)} skipped={len(skipped)}",
        }
    )
    for c in ok:
        r = c["roofline"]
        mesh = "x".join(map(str, c["mesh"]))
        frac = r["compute_s"] / max(r["step_time_s"], 1e-30)
        rows.append(
            {
                "name": f"roofline/{c['arch']}/{c['shape']}/{mesh}",
                "us_per_call": r["step_time_s"] * 1e6,
                "derived": (
                    f"compute={r['compute_s']:.3e}s hbm={r['memory_s']:.3e}s "
                    f"coll={r['collective_s']:.3e}s dom={r['dominant']} "
                    f"frac={frac:.3f} useful={c.get('useful_flops_ratio') or 0:.3f}"
                ),
            }
        )

    # nominate hillclimb cells (single-pod mesh, one per criterion)
    single = [c for c in ok if not c["multi_pod"]]
    if single:
        worst = min(
            single,
            key=lambda c: c["roofline"]["compute_s"]
            / max(c["roofline"]["step_time_s"], 1e-30),
        )
        coll = max(single, key=lambda c: c["roofline"]["collective_s"])
        train = [c for c in single if c["kind"] == "train"]
        rep = max(train, key=lambda c: c["flops_per_device"]) if train else worst
        for tag, c in (("worst_frac", worst), ("most_collective", coll),
                       ("paper_representative", rep)):
            rows.append(
                {
                    "name": f"roofline/hillclimb/{tag}",
                    "us_per_call": 0.0,
                    "derived": f"{c['arch']}×{c['shape']}",
                }
            )
    return rows
