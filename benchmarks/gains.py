"""Paper Figs. 17–19: scheme comparison and offloading gains vs environment.

Reproduces, with the paper's own constants (P_m=0.9 W, P_i=0.3 W,
P_tr=1.3 W; F=3 for the bandwidth sweep; B=3 MB/s for the speedup sweep;
ω=0.5), the three curves:

  * response time / energy of no-offloading, full-offloading and partial
    (MCOP) offloading vs wireless bandwidth (Fig. 17) and speedup (Fig. 18);
  * offloading gains under the three cost models (Fig. 19).

The application is the reconstructed face-recognition call tree (Fig. 12),
the same app the paper partitions in §7.2.  Full sweep data lands in
``results/gains.json`` for EXPERIMENTS.md; the CSV rows summarise the
qualitative claims the paper makes about these figures, each asserted.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import (
    AppProfile,
    EnergyModel,
    Environment,
    ResponseTimeModel,
    WeightedModel,
    face_recognition_graph,
    full_offloading,
    mcop_reference,
    no_offloading,
    offloading_gain,
)

BANDWIDTHS = [0.1, 0.25, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 16.0, 32.0]   # MB/s
SPEEDUPS = [1.0, 1.5, 2.0, 3.0, 5.0, 8.0, 16.0, 32.0]


def _profile() -> AppProfile:
    g = face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    return AppProfile.from_wcg_times(g, bandwidth=1.0)


def _schemes(model, prof, env):
    g = model.build(prof, env)
    no = no_offloading(g).cost
    full = full_offloading(g).cost
    part = min(mcop_reference(g).min_cut, no)  # §4.3 beneficial-only clamp
    return no, full, part


def run() -> list[dict]:
    prof = _profile()
    rows: list[dict] = []
    data = {"bandwidth_sweep": [], "speedup_sweep": [], "gain_sweep": []}

    # ---- Fig. 17: vs bandwidth at F=3 --------------------------------
    for bw in BANDWIDTHS:
        env = Environment.symmetric(bandwidth=bw, speedup=3.0)
        t_no, t_full, t_part = _schemes(ResponseTimeModel(), prof, env)
        e_no, e_full, e_part = _schemes(EnergyModel(), prof, env)
        data["bandwidth_sweep"].append(
            dict(B=bw, t_no=t_no, t_full=t_full, t_part=t_part,
                 e_no=e_no, e_full=e_full, e_part=e_part)
        )
    d = data["bandwidth_sweep"]
    low, high = d[0], d[-1]
    rows.append({
        "name": "gains/fig17_low_bw_no_offloading_wins",
        "us_per_call": 0.0,
        "derived": f"ok={low['t_part'] >= low['t_no'] - 1e-9 and low['t_full'] > low['t_no']}",
    })
    rows.append({
        "name": "gains/fig17_high_bw_full_approaches_partial",
        "us_per_call": 0.0,
        "derived": f"gap={(high['t_full'] - high['t_part']) / high['t_part']:.4f}",
    })
    rows.append({
        "name": "gains/fig17_partial_never_worse",
        "us_per_call": 0.0,
        "derived": f"ok={all(r['t_part'] <= min(r['t_no'], r['t_full']) + 1e-9 for r in d)}",
    })

    # ---- Fig. 18: vs speedup at B=3 MB/s ------------------------------
    for f in SPEEDUPS:
        env = Environment.symmetric(bandwidth=3.0, speedup=f)
        t_no, t_full, t_part = _schemes(ResponseTimeModel(), prof, env)
        e_no, e_full, e_part = _schemes(EnergyModel(), prof, env)
        data["speedup_sweep"].append(
            dict(F=f, t_no=t_no, t_full=t_full, t_part=t_part,
                 e_no=e_no, e_full=e_full, e_part=e_part)
        )
    d = data["speedup_sweep"]
    rows.append({
        "name": "gains/fig18_offloading_benefits_from_high_F",
        "us_per_call": 0.0,
        "derived": f"t_part(F=1)={d[0]['t_part']:.1f} → t_part(F=32)={d[-1]['t_part']:.1f}",
    })
    rows.append({
        "name": "gains/fig18_small_F_full_offload_slower_than_local",
        "us_per_call": 0.0,
        "derived": f"ok={d[0]['t_full'] > d[0]['t_no']}",
    })

    # ---- Fig. 19: gains under the three cost models, ω=0.5 ------------
    for bw in BANDWIDTHS:
        env = Environment.symmetric(bandwidth=bw, speedup=3.0)
        point = {"B": bw}
        for name, model in (
            ("time", ResponseTimeModel()),
            ("energy", EnergyModel()),
            ("weighted", WeightedModel(0.5)),
        ):
            no, _full, part = _schemes(model, prof, env)
            point[name] = offloading_gain(no, part)
        data["gain_sweep"].append(point)
    d = data["gain_sweep"]
    mid = d[len(d) // 2]
    rows.append({
        "name": "gains/fig19_energy_gain_largest",
        "us_per_call": 0.0,
        "derived": (
            f"B={mid['B']}: energy={mid['energy']:.3f} ≥ "
            f"weighted={mid['weighted']:.3f} ≥ time={mid['time']:.3f} "
            f"ok={mid['energy'] >= mid['weighted'] - 1e-9 >= 0 and mid['weighted'] >= mid['time'] - 1e-9}"
        ),
    })
    rows.append({
        "name": "gains/fig19_gains_rise_with_bandwidth",
        "us_per_call": 0.0,
        "derived": f"time gain {d[0]['time']:.3f}→{d[-1]['time']:.3f}, "
                   f"monotone={all(b['time'] >= a['time'] - 1e-9 for a, b in zip(d, d[1:]))}",
    })

    os.makedirs("results", exist_ok=True)
    with open("results/gains.json", "w") as f:
        json.dump(data, f, indent=1)
    return rows
