#!/usr/bin/env python
"""Offline trace summarizer for ``Tracer.export_jsonl`` traces (stdlib).

    PYTHONPATH=src python tools/tracequery.py TRACE.jsonl [options]

Reads the one-span-per-line JSONL a :class:`repro.obs.trace.Tracer`
exports and answers the questions a trace exists for:

* ``--slowest N``     the N slowest ``broker.tick`` spans (tick number,
                      duration, request/degraded counts).
* ``--stages``        per-stage breakdown: span count, total and mean
                      duration per span name, sorted by total.
* ``--provenance``    degraded-reply provenance: every ``degraded``
                      event next to the fault/retry/breaker events of
                      the same tick — the "why did this user get the
                      fallback plan" view.
* ``--audit``         CI gate: exit non-zero unless EVERY ``degraded``
                      event has at least one matching ``fault`` event
                      in-trace (same tick), i.e. every degraded reply
                      is attributable to an injected fault.
* ``--json``          machine-readable summary document instead of text.

With no option flags, prints all three human-readable sections.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def load_spans(path: pathlib.Path) -> list[dict]:
    """Parse a JSONL trace; malformed lines are skipped with a warning
    (a truncated artifact should degrade the report, not crash it)."""
    spans: list[dict] = []
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
        except json.JSONDecodeError:
            print(f"warning: {path}:{lineno}: unparseable line skipped",
                  file=sys.stderr)
            continue
        if isinstance(doc, dict) and doc.get("type") == "span":
            spans.append(doc)
    return spans


def iter_events(spans: list[dict]):
    """Yield ``(event_dict, owning_span)`` over every span, including
    orphan-event spans (exported as zero-duration spans)."""
    for s in spans:
        for e in s.get("events", ()):
            yield e, s
        if s.get("attrs", {}).get("orphan_event"):
            yield {"name": s["name"], "ts": s["ts"], "attrs": s["attrs"]}, s


def _tick_of(attrs: dict):
    t = attrs.get("tick")
    return int(t) if isinstance(t, (int, float)) else None


def slowest_ticks(spans: list[dict], n: int) -> list[dict]:
    ticks = [s for s in spans if s["name"] == "broker.tick"]
    ticks.sort(key=lambda s: -float(s.get("dur", 0.0)))
    return [
        {
            "tick": _tick_of(s.get("attrs", {})),
            "dur_s": float(s.get("dur", 0.0)),
            "requests": s.get("attrs", {}).get("requests"),
            "degraded": s.get("attrs", {}).get("degraded"),
            "faults": s.get("attrs", {}).get("faults"),
        }
        for s in ticks[:n]
    ]


def stage_breakdown(spans: list[dict]) -> list[dict]:
    """Per-stage rows, split by solver-fleet placement when present.

    Spans from the sharded solve plane carry ``devices`` (fleet size)
    and — for the per-device ``*.shard`` completion spans — ``shard``
    attrs; grouping on them turns ``--stages`` into a per-device solve
    time view instead of averaging the whole fleet into one row.
    """
    agg: dict[tuple, list[float]] = {}
    for s in spans:
        attrs = s.get("attrs", {})
        key = (s["name"], attrs.get("devices"), attrs.get("shard"))
        agg.setdefault(key, []).append(float(s.get("dur", 0.0)))
    rows = []
    for (name, devices, shard), durs in agg.items():
        row = {
            "name": name,
            "count": len(durs),
            "total_s": sum(durs),
            "mean_s": sum(durs) / len(durs),
            "max_s": max(durs),
        }
        if devices is not None:
            row["devices"] = devices
        if shard is not None:
            row["shard"] = shard
        rows.append(row)
    rows.sort(key=lambda r: -r["total_s"])
    return rows


def degraded_provenance(spans: list[dict]) -> list[dict]:
    """One row per ``degraded`` event: the fault/retry/breaker_trip
    events recorded for the same tick (its causal neighborhood)."""
    by_tick: dict[int | None, list[dict]] = {}
    degraded: list[tuple[dict, dict]] = []
    for e, owner in iter_events(spans):
        tick = _tick_of(e.get("attrs", {}))
        if e["name"] in ("fault", "retry", "breaker_trip"):
            by_tick.setdefault(tick, []).append(e)
        elif e["name"] == "degraded":
            degraded.append((e, owner))
    rows = []
    for e, owner in degraded:
        tick = _tick_of(e.get("attrs", {}))
        causes = by_tick.get(tick, [])
        rows.append(
            {
                "tick": tick,
                "attrs": e.get("attrs", {}),
                "span": owner.get("name"),
                "fault_events": [
                    c["attrs"] for c in causes if c["name"] == "fault"
                ],
                "retry_events": sum(c["name"] == "retry" for c in causes),
                "breaker_trips": sum(
                    c["name"] == "breaker_trip" for c in causes
                ),
            }
        )
    return rows


def audit(spans: list[dict]) -> list[dict]:
    """Degraded events with NO matching same-tick fault event (should be
    empty: a degraded reply must be attributable to an injected fault)."""
    return [r for r in degraded_provenance(spans) if not r["fault_events"]]


def _fmt_s(x: float) -> str:
    return f"{x * 1e3:.3f}ms" if x < 1.0 else f"{x:.3f}s"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", type=pathlib.Path)
    ap.add_argument("--slowest", type=int, metavar="N", default=None)
    ap.add_argument("--stages", action="store_true")
    ap.add_argument("--provenance", action="store_true")
    ap.add_argument("--audit", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    spans = load_spans(args.trace)
    if not spans:
        print(f"error: no spans in {args.trace}", file=sys.stderr)
        return 2

    everything = not (
        args.slowest is not None
        or args.stages
        or args.provenance
        or args.audit
    )
    doc: dict = {"spans": len(spans)}
    if everything or args.slowest is not None:
        doc["slowest_ticks"] = slowest_ticks(spans, args.slowest or 5)
    if everything or args.stages:
        doc["stages"] = stage_breakdown(spans)
    if everything or args.provenance:
        doc["degraded"] = degraded_provenance(spans)
    orphans = audit(spans) if (args.audit or everything) else None

    if args.as_json:
        if orphans is not None:
            doc["unattributed_degraded"] = orphans
        print(json.dumps(doc, indent=2))
    else:
        print(f"{len(spans)} spans in {args.trace}")
        for row in doc.get("slowest_ticks", ()):
            print(
                f"  slow tick {row['tick']}: {_fmt_s(row['dur_s'])}"
                f"  requests={row['requests']} degraded={row['degraded']}"
                f" faults={row['faults']}"
            )
        if "stages" in doc:
            print("per-stage breakdown:")
            for r in doc["stages"]:
                label = r["name"]
                if "devices" in r:
                    label += f"[devices={r['devices']}]"
                if "shard" in r:
                    label += f"[shard={r['shard']}]"
                print(
                    f"  {label:<22} n={r['count']:<5}"
                    f" total={_fmt_s(r['total_s'])}"
                    f" mean={_fmt_s(r['mean_s'])}"
                    f" max={_fmt_s(r['max_s'])}"
                )
        if "degraded" in doc:
            print(f"degraded replies: {len(doc['degraded'])}")
            for r in doc["degraded"]:
                faults = ", ".join(
                    f"{a.get('site')}/{a.get('kind')}"
                    for a in r["fault_events"]
                ) or "NONE"
                print(
                    f"  tick {r['tick']} ({r['span']}): {r['attrs']}"
                    f" ← faults: {faults};"
                    f" retries={r['retry_events']}"
                    f" breaker_trips={r['breaker_trips']}"
                )
        if orphans is not None:
            if orphans:
                print(
                    f"AUDIT FAIL: {len(orphans)} degraded replies with no"
                    " same-tick fault event:"
                )
                for r in orphans:
                    print(f"  tick {r['tick']}: {r['attrs']}")
            else:
                print("audit ok: every degraded reply has a matching"
                      " fault event")
    if args.audit and orphans:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
