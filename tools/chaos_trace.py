#!/usr/bin/env python
"""Export a deterministic chaos-storm trace (the CI trace-audit artifact).

    PYTHONPATH=src python tools/chaos_trace.py --out trace.jsonl \
        [--chrome trace.json] [--rate 0.10] [--steps 12] [--users 16]

Replays the ``benchmarks/faults.py`` workload — a seeded multi-user
request stream through a resilient ``OffloadBroker`` — at the given
fault rate with a :class:`repro.obs.trace.Tracer` and
:class:`repro.obs.metrics.MetricsRegistry` attached, then exports the
span trace.  Broker and tracer share one
:class:`~repro.service.resilience.InjectedClock`, so every timestamp in
the artifact is a pure deterministic function of the fault schedule
(identical across runs and machines).

CI then runs ``tools/tracequery.py --audit`` over the JSONL: every
degraded reply must be attributable to a same-tick injected fault.
Exits non-zero if the workload itself failed to resolve every request,
or (sanity) if a chaos run with rate > 0 recorded no fault events.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.core import AppProfile, ResponseTimeModel, face_recognition_graph
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.service import (
    CircuitBreaker,
    FaultInjector,
    InjectedClock,
    OffloadBroker,
    ResiliencePolicy,
    RetryPolicy,
    user_traces,
)


def run_storm(
    *,
    rate: float,
    steps: int,
    users: int,
    seed: int,
    retries: int = 2,
    capacity: int = 65536,
) -> tuple[OffloadBroker, Tracer, MetricsRegistry, list]:
    """Drive the seeded fault-storm workload with observability attached."""
    clock = InjectedClock()
    tracer = Tracer(clock=clock, capacity=capacity)
    metrics = MetricsRegistry(clock=clock)
    broker = OffloadBroker(
        backend="jax",
        clock=clock,
        resilience=ResiliencePolicy(
            retry=RetryPolicy(
                max_retries=retries, base_backoff_s=1e-4, max_backoff_s=1e-3
            ),
            degrade="fallback",
            breaker=CircuitBreaker(threshold=3, cooldown_ticks=4),
        ),
        fault_injector=FaultInjector(seed=seed, rate=rate, latency_s=1e-4),
        tracer=tracer,
        metrics=metrics,
    )
    profile = AppProfile.from_wcg_times(
        face_recognition_graph(speedup=1.0, bandwidth_mbps=1.0)
    )
    broker.register("app", profile, ResponseTimeModel())
    traces = user_traces(users, steps, seed=31)
    futures = []
    for t in range(steps):
        for u in range(users):
            futures.append(broker.submit("app", traces[u][t]))
        broker.tick()
    guard = 0
    while broker.pending and guard < 4 * steps:
        broker.tick()
        guard += 1
    return broker, tracer, metrics, futures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", type=pathlib.Path, required=True,
                    help="JSONL span trace (tools/tracequery.py format)")
    ap.add_argument("--chrome", type=pathlib.Path, default=None,
                    help="also export Chrome trace_event JSON")
    ap.add_argument("--metrics-out", type=pathlib.Path, default=None,
                    help="also dump the metrics registry snapshot (JSON)")
    ap.add_argument("--rate", type=float, default=0.10)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--users", type=int, default=16)
    ap.add_argument("--seed", type=int, default=2024)
    ap.add_argument("--retries", type=int, default=2,
                    help="retry budget (0 makes degraded replies likely)")
    args = ap.parse_args(argv)

    broker, tracer, metrics, futures = run_storm(
        rate=args.rate,
        steps=args.steps,
        users=args.users,
        seed=args.seed,
        retries=args.retries,
    )
    if broker.pending or not all(f.done for f in futures):
        print("error: chaos workload left unresolved requests",
              file=sys.stderr)
        return 2

    n_spans = tracer.export_jsonl(args.out)
    if args.chrome is not None:
        tracer.export_chrome(args.chrome)
    if args.metrics_out is not None:
        args.metrics_out.write_text(
            json.dumps(metrics.snapshot(), indent=2, default=str) + "\n"
        )

    tel = broker.telemetry
    degraded = sum(f.result.degraded for f in futures)
    p50, p90, p99 = tel.tick_latency_quantiles()
    print(
        f"{n_spans} spans -> {args.out}; requests={len(futures)}"
        f" faults={tel.faults} retries={tel.retries}"
        f" breaker_trips={tel.breaker_trips} degraded={degraded}"
        f" tick_p50={p50 * 1e3:.3f}ms p99={p99 * 1e3:.3f}ms"
    )
    if args.rate > 0 and tel.faults == 0:
        print("error: chaos run recorded no faults (injector not wired?)",
              file=sys.stderr)
        return 3
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
