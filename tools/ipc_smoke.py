#!/usr/bin/env python
"""Cross-process smoke: one solver, N client processes, U batched users.

    PYTHONPATH=src python tools/ipc_smoke.py --users 1000 --clients 2 \
        --ticks 6 --dir /tmp/ipc_smoke

Boots ``examples/serve_broker.py`` on a unix socket, then spawns
``--clients`` REAL client processes (this script re-executed with
``--worker``), each registering a server-side
:class:`~repro.service.session.BatchSessionGroup` of ``U/N`` slots and
driving it with seeded :class:`~repro.service.workload.TrafficGenerator`
churn for ``--ticks`` ticks.  Every worker must see a ``batch_report``
for every tick it staged, and the solver must survive interleaved ticks
from concurrent clients.  On success the server is shut down gracefully
(SIGINT) so it exports its trace — the CI job feeds the JSONL to
``tools/tracequery.py --audit`` and uploads both trace files.

Exit status is the CI contract: 0 only if the server came up, every
worker resolved every staged tick, and the trace files exist.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import subprocess
import sys
import time

READY_TIMEOUT_S = 120.0


# ----------------------------------------------------------------------
# worker: one client process driving U/N batched users
# ----------------------------------------------------------------------

def worker(args) -> int:
    import numpy as np  # deferred: the coordinator stays stdlib-only

    from repro.core import AppProfile, ResponseTimeModel, random_wcg
    from repro.service import BrokerClient, unix_address
    from repro.service.workload import TrafficGenerator

    profile = AppProfile.from_wcg_times(
        random_wcg(args.nodes, rng=np.random.default_rng(args.seed))
    )
    client = BrokerClient(
        unix_address(args.socket),
        tenants={args.tenant: (profile, ResponseTimeModel())},
        client=args.name,
    )
    client.connect()
    group = client.register_batch(args.tenant, args.users)
    gen = TrafficGenerator(args.users, seed=args.traffic_seed)

    reports = []
    for _ in range(args.ticks):
        t = gen.step()
        group.observe(
            t.envs,
            arrived=np.nonzero(t.arrived)[0],
            departed=np.nonzero(t.departed)[0],
        )
        client.tick()
        reports.extend(group.drain())
    # a concurrent client's tick may resolve our stage before our own
    # tick frame lands, but every staged tick must report exactly once
    for _ in range(4):
        if len(reports) >= args.ticks:
            break
        client.tick()
        reports.extend(group.drain())
    client.close()

    if len(reports) != args.ticks:
        print(
            f"WORKER {args.name} FAIL: {len(reports)} reports for "
            f"{args.ticks} staged ticks",
            file=sys.stderr,
        )
        return 1
    solved = sum(r["solved"] for r in reports)
    active = reports[-1]["active"]
    print(
        f"WORKER {args.name} ok users={args.users} ticks={args.ticks} "
        f"solved={solved} active_last={active}",
        flush=True,
    )
    return 0


# ----------------------------------------------------------------------
# coordinator: server subprocess + N worker subprocesses
# ----------------------------------------------------------------------

def coordinator(args) -> int:
    repo = pathlib.Path(__file__).resolve().parent.parent
    out = pathlib.Path(args.dir)
    out.mkdir(parents=True, exist_ok=True)
    sock = out / "solver.sock"
    trace_chrome = out / "ipc_trace.json"
    trace_jsonl = out / "ipc_trace.jsonl"
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))

    server = subprocess.Popen(
        [
            sys.executable, str(repo / "examples" / "serve_broker.py"),
            "--socket", str(sock),
            "--journal", str(out / "journal.jsonl"),
            "--snapshot-dir", str(out / "snaps"),
            "--nodes", str(args.nodes), "--seed", str(args.seed),
            "--tenant", args.tenant,
            "--trace", str(trace_chrome),
            "--trace-jsonl", str(trace_jsonl),
        ],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    try:
        deadline = time.monotonic() + READY_TIMEOUT_S
        for line in server.stdout:
            print(line, end="", flush=True)
            if line.startswith("READY"):
                break
            if time.monotonic() > deadline:
                raise RuntimeError("server never became READY")
        else:
            raise RuntimeError("server exited before READY")

        per_client = args.users // args.clients
        workers = [
            subprocess.Popen(
                [
                    sys.executable, str(pathlib.Path(__file__).resolve()),
                    "--worker",
                    "--socket", str(sock),
                    "--users", str(per_client),
                    "--ticks", str(args.ticks),
                    "--nodes", str(args.nodes), "--seed", str(args.seed),
                    "--tenant", args.tenant,
                    "--name", f"smoke{i}",
                    "--traffic-seed", str(100 + i),
                ],
                env=env,
            )
            for i in range(args.clients)
        ]
        codes = [w.wait(timeout=READY_TIMEOUT_S) for w in workers]
        if any(codes):
            print(f"SMOKE FAIL: worker exit codes {codes}", file=sys.stderr)
            return 1

        # graceful shutdown so the tracer exports
        server.send_signal(signal.SIGINT)
        server.wait(timeout=READY_TIMEOUT_S)
    finally:
        if server.poll() is None:
            server.kill()
            server.wait()

    for path in (trace_chrome, trace_jsonl):
        if not path.exists() or not path.stat().st_size:
            print(f"SMOKE FAIL: missing trace {path}", file=sys.stderr)
            return 1
    spans = sum(
        1 for line in trace_jsonl.read_text().splitlines()
        if line.strip() and json.loads(line).get("type") == "span"
    )
    print(
        f"SMOKE ok clients={args.clients} users={args.users} "
        f"ticks={args.ticks} trace_spans={spans}",
        flush=True,
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--socket", help="unix socket (worker mode)")
    ap.add_argument("--dir", default="ipc_smoke_out",
                    help="scratch/artifact directory (coordinator mode)")
    ap.add_argument("--users", type=int, default=1000,
                    help="total batched users across all clients")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--ticks", type=int, default=6)
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenant", default="app")
    ap.add_argument("--name", default="smoke")
    ap.add_argument("--traffic-seed", type=int, default=100)
    args = ap.parse_args(argv)
    if args.worker:
        if not args.socket:
            ap.error("--worker requires --socket")
        return worker(args)
    return coordinator(args)


if __name__ == "__main__":
    sys.exit(main())
