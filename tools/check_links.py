#!/usr/bin/env python
"""Markdown link check over README/docs/ROADMAP (stdlib only).

Verifies that every relative link/image target in the repo's markdown
surface points at a file or directory that actually exists, and that
intra-document anchors (``#section``) resolve to a heading.  External
(``http(s)://``, ``mailto:``) targets are not fetched — CI must not
depend on network weather.

    python tools/check_links.py [paths...]

Defaults to README.md, ROADMAP.md, PAPER.md, PAPERS.md, CHANGES.md and
docs/*.md.  Exits non-zero listing every broken link.
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# [text](target) and ![alt](target); stop at the first ')' — markdown
# targets here never contain parens
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _anchor(heading: str) -> str:
    """GitHub's heading slug: lowercase, DELETE punctuation (each char,
    including dots/slashes/em-dashes), then map each space to a dash —
    runs of spaces become runs of dashes, exactly as GitHub renders."""
    slug = re.sub(r"[^\w\- ]", "", heading.strip().lower(), flags=re.UNICODE)
    return slug.replace(" ", "-")


def _anchors_of(path: pathlib.Path) -> set[str]:
    """All anchors a document renders, with GitHub's duplicate-heading
    deduplication: the second `## Example` becomes ``#example-1``."""
    text = path.read_text(encoding="utf-8")
    anchors: set[str] = set()
    seen: dict[str, int] = {}
    for heading in _HEADING.findall(_CODE_FENCE.sub("", text)):
        slug = _anchor(heading)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    text = _CODE_FENCE.sub("", path.read_text(encoding="utf-8"))
    for target in _LINK.findall(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:
            continue
        base, _, fragment = target.partition("#")
        dest = path if not base else (path.parent / base).resolve()
        if base and not dest.exists():
            errors.append(f"{_rel(path)}: broken link -> {target}")
            continue
        if fragment and dest.suffix == ".md" and dest.exists():
            if _anchor(fragment) not in _anchors_of(dest):
                errors.append(f"{_rel(path)}: broken anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = [
            REPO_ROOT / name
            for name in ("README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md",
                         "CHANGES.md")
            if (REPO_ROOT / name).exists()
        ] + sorted((REPO_ROOT / "docs").glob("*.md"))
    errors: list[str] = []
    for f in files:
        if not f.exists():
            errors.append(f"missing file: {f}")
            continue
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
