#!/usr/bin/env python
"""Offline inspector for the solver's write-ahead request journal.

    PYTHONPATH=src python tools/wire_journal.py JOURNAL.jsonl [options]

Reads the JSONL journal a :class:`repro.service.server.SolverServer`
appends (one ``submit``/``tick`` entry per accepted frame, write-ahead
of the ack) and answers the questions an operator asks after a crash:

* default           summary: seq range, submits per tenant/lane, ticks
                    covered, truncated-tail detection.
* ``--snapshot-dir DIR``
                    cross-check against the cache snapshots: which seq
                    each tenant's snapshot covers and how many journal
                    entries a warm restart would replay.
* ``--tail N``      the last N entries, pretty-printed.
* ``--verify``      CI gate: exit non-zero if the journal is not
                    replayable — non-monotonic seq, a submit entry
                    missing id/tenant/env, or a snapshot that claims a
                    seq newer than the journal's head.
* ``--json``        machine-readable summary document instead of text.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from collections import Counter


def load_entries(path: pathlib.Path) -> tuple[list[dict], int]:
    """Parse a journal; returns (entries, undecodable_line_count).

    Undecodable lines — the tail a SIGKILL mid-append leaves — are
    counted, not fatal: each journal line stands alone.
    """
    entries: list[dict] = []
    bad = 0
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
        except json.JSONDecodeError:
            bad += 1
            continue
        if isinstance(doc, dict) and isinstance(doc.get("seq"), int):
            entries.append(doc)
        else:
            bad += 1
    return entries, bad


def summarize(entries: list[dict], bad: int) -> dict:
    submits = [e for e in entries if e.get("op") == "submit"]
    ticks = [e for e in entries if e.get("op") == "tick"]
    seqs = [e["seq"] for e in entries if e.get("op") != "journal"]
    monotonic = all(a < b for a, b in zip(seqs, seqs[1:]))
    malformed_submits = [
        e["seq"]
        for e in submits
        if not (isinstance(e.get("id"), str) and e.get("tenant")
                and isinstance(e.get("env"), dict))
    ]
    return {
        "entries": len(entries),
        "undecodable_lines": bad,
        "seq_first": seqs[0] if seqs else 0,
        "seq_last": seqs[-1] if seqs else 0,
        "seq_monotonic": monotonic,
        "submits": len(submits),
        "submits_by_tenant": dict(Counter(e.get("tenant") for e in submits)),
        "submits_by_lane": dict(
            Counter(e.get("lane", "user") for e in submits)
        ),
        "ticks": len(ticks),
        "tick_first": ticks[0].get("tick") if ticks else None,
        "tick_last": ticks[-1].get("tick") if ticks else None,
        "malformed_submits": malformed_submits,
    }


def snapshot_coverage(snapshot_dir: pathlib.Path,
                      summary: dict) -> list[dict]:
    """Per-tenant snapshot meta vs the journal head: the replay window."""
    out = []
    for path in sorted(snapshot_dir.glob("*.snapshot.json")):
        tenant = path.name[: -len(".snapshot.json")]
        try:
            doc = json.loads(path.read_text())
            meta = doc.get("meta") or {}
            covered = int(meta.get("journal_seq", 0))
            tick = int(meta.get("tick", 0))
            entries = len(doc.get("entries", ()))
        except (OSError, json.JSONDecodeError, TypeError, ValueError):
            covered, tick, entries = 0, 0, 0
        out.append(
            {
                "tenant": tenant,
                "cache_entries": entries,
                "covered_seq": covered,
                "covered_tick": tick,
                "replay_window": max(summary["seq_last"] - covered, 0),
                "ahead_of_journal": covered > summary["seq_last"],
            }
        )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("journal", type=pathlib.Path)
    ap.add_argument("--snapshot-dir", type=pathlib.Path)
    ap.add_argument("--tail", type=int, metavar="N")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    if not args.journal.exists():
        print(f"error: no journal at {args.journal}", file=sys.stderr)
        return 2
    entries, bad = load_entries(args.journal)
    summary = summarize(entries, bad)
    snapshots = (
        snapshot_coverage(args.snapshot_dir, summary)
        if args.snapshot_dir
        else None
    )

    if args.as_json:
        doc = {"journal": str(args.journal), "summary": summary}
        if snapshots is not None:
            doc["snapshots"] = snapshots
        if args.tail:
            doc["tail"] = entries[-args.tail:]
        print(json.dumps(doc, indent=2))
    else:
        s = summary
        print(f"journal {args.journal}")
        print(
            f"  {s['entries']} entries (seq {s['seq_first']}..{s['seq_last']}"
            f", monotonic={s['seq_monotonic']}), "
            f"{s['undecodable_lines']} undecodable line(s)"
        )
        print(
            f"  {s['submits']} submits "
            f"by tenant {s['submits_by_tenant']} lanes {s['submits_by_lane']}"
        )
        print(
            f"  {s['ticks']} ticks "
            f"({s['tick_first']}..{s['tick_last']})"
        )
        for snap in snapshots or ():
            state = "AHEAD OF JOURNAL" if snap["ahead_of_journal"] else (
                f"replay window {snap['replay_window']} entr(ies)"
            )
            print(
                f"  snapshot {snap['tenant']}: {snap['cache_entries']} cache "
                f"entries, covers seq {snap['covered_seq']} "
                f"tick {snap['covered_tick']} — {state}"
            )
        for e in entries[-args.tail:] if args.tail else ():
            print(f"  {json.dumps(e, separators=(',', ':'))}")

    if args.verify:
        problems = []
        if not summary["seq_monotonic"]:
            problems.append("sequence numbers are not strictly increasing")
        if summary["malformed_submits"]:
            problems.append(
                f"malformed submit entries at seq "
                f"{summary['malformed_submits']}"
            )
        for snap in snapshots or ():
            if snap["ahead_of_journal"]:
                problems.append(
                    f"snapshot {snap['tenant']} covers seq "
                    f"{snap['covered_seq']} past journal head "
                    f"{summary['seq_last']}"
                )
        if problems:
            for p in problems:
                print(f"VERIFY FAIL: {p}", file=sys.stderr)
            return 1
        print("verify: journal replayable")
    return 0


if __name__ == "__main__":
    sys.exit(main())
