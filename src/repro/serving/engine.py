"""Batched serving engine: KV-cache slots + continuous batching scheduler.

The engine owns a fixed pool of ``max_batch`` cache slots (one decode
cache built by ``Model.init_cache``).  Requests flow through a FIFO
admission queue; each engine step either

* **prefills** newly-admitted requests (one jitted prefill per admission
  wave — right-padded to the slot's prompt capacity so there is exactly
  one prefill specialisation), or
* **decodes** every active slot one token (a single jitted decode_step
  over the whole pool — finished slots keep decoding into a scratch
  position and are masked; this keeps the decode HLO static, the standard
  serving-engine trade).

The MCOP tie-in (paper → serving): the *prefill pool vs decode pool* is a
two-tier offloading decision — prefill is compute-bound (cloud-tier-ish),
decode is bandwidth-bound (device-tier-ish).  ``examples/serve_lm.py``
feeds both pools' analytic costs to the placement engine to pick where
each phase runs; the engine itself is placement-agnostic.

Per-slot state is host-side metadata only; all token/cache state stays in
device arrays indexed by slot — no host↔device chatter inside the loop.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model

__all__ = ["Request", "RequestState", "ServingConfig", "ServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    temperature: float = 0.0      # 0 → greedy
    eos_id: int | None = None


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def uid(self) -> int:
        return self.request.uid


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    max_batch: int = 8
    max_prompt_len: int = 128
    max_len: int = 256            # prompt + generation capacity per slot
    pad_id: int = 0


class ServingEngine:
    def __init__(self, model: Model, params: Any, cfg: ServingConfig,
                 *, extras: dict | None = None, rng_seed: int = 0):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.extras = extras or {}
        self.queue: deque[Request] = deque()
        self.active: dict[int, RequestState] = {}       # slot → state
        self.finished: dict[int, RequestState] = {}     # uid → state
        self._rng = jax.random.PRNGKey(rng_seed)
        self._uid = 0

        # one pooled cache; per-slot lengths (the model cache tracks a
        # scalar length, so slots advance in lockstep — admission waves
        # prefill together; slot-level lengths mask logits instead)
        self.cache = model.init_cache(cfg.max_batch, cfg.max_len)
        self._tokens = jnp.full((cfg.max_batch, 1), cfg.pad_id, jnp.int32)
        self._active_mask = np.zeros(cfg.max_batch, bool)

        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 32,
               temperature: float = 0.0, eos_id: int | None = None) -> int:
        uid = self._uid
        self._uid += 1
        if len(prompt) > self.cfg.max_prompt_len:
            raise ValueError("prompt longer than max_prompt_len")
        self.queue.append(
            Request(uid, np.asarray(prompt, np.int32), max_new_tokens,
                    temperature, eos_id)
        )
        return uid

    # ------------------------------------------------------------------
    def _prefill_impl(self, params, cache, tokens, extras):
        batch = {"tokens": tokens, **extras}
        return self.model.prefill(params, batch, cache)

    def _decode_impl(self, params, cache, tokens):
        return self.model.decode_step(params, tokens, cache)

    # ------------------------------------------------------------------
    def _admit(self) -> list[RequestState]:
        """Move queued requests into free slots; returns admitted states."""
        free = [s for s in range(self.cfg.max_batch) if not self._active_mask[s]]
        admitted: list[RequestState] = []
        while free and self.queue:
            req = self.queue.popleft()
            slot = free.pop(0)
            st = RequestState(req, slot)
            self.active[slot] = st
            self._active_mask[slot] = True
            admitted.append(st)
        return admitted

    def _sample(self, logits: jnp.ndarray, temps: np.ndarray) -> np.ndarray:
        self._rng, sub = jax.random.split(self._rng)
        greedy = jnp.argmax(logits, axis=-1)
        temp = jnp.asarray(np.maximum(temps, 1e-6))[:, None]
        sampled = jax.random.categorical(sub, logits / temp, axis=-1)
        out = jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
        return np.asarray(out, np.int32)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration.  Returns True while work remains.

        Admission model: waves. A wave of requests is admitted only when
        the pool is empty (the shared scalar cache length advances in
        lockstep); within a wave, continuous masking retires sequences
        early.  This is the single-cache-pool trade documented above.
        """
        if not self.active and self.queue:
            # ---- new wave: reset cache, admit, batch-prefill ------------
            self.cache = self.model.init_cache(self.cfg.max_batch, self.cfg.max_len)
            admitted = self._admit()
            plen = max(len(st.request.prompt) for st in admitted)
            toks = np.full((self.cfg.max_batch, plen), self.cfg.pad_id, np.int32)
            for st in admitted:
                # left-pad so every prompt ends at position plen-1
                p = st.request.prompt
                toks[st.slot, plen - len(p):] = p
            extras = dict(self.extras)
            logits, self.cache = self._prefill(
                self.params, self.cache, jnp.asarray(toks), extras
            )
            temps = np.array(
                [
                    self.active[s].request.temperature if self._active_mask[s] else 0.0
                    for s in range(self.cfg.max_batch)
                ]
            )
            nxt = self._sample(logits, temps)
            self._push_tokens(nxt)
            return True

        if self.active:
            # ---- decode one token for the whole pool --------------------
            logits, self.cache = self._decode(self.params, self.cache, self._tokens)
            temps = np.array(
                [
                    self.active[s].request.temperature if s in self.active else 0.0
                    for s in range(self.cfg.max_batch)
                ]
            )
            nxt = self._sample(logits, temps)
            self._push_tokens(nxt)
            return True

        return bool(self.queue)

    def _push_tokens(self, nxt: np.ndarray) -> None:
        new_tok = np.full((self.cfg.max_batch, 1), self.cfg.pad_id, np.int32)
        for slot in list(self.active):
            st = self.active[slot]
            tok = int(nxt[slot])
            st.generated.append(tok)
            req = st.request
            if (req.eos_id is not None and tok == req.eos_id) or len(
                st.generated
            ) >= req.max_new_tokens:
                st.done = True
                self.finished[st.uid] = st
                del self.active[slot]
                self._active_mask[slot] = False
            else:
                new_tok[slot, 0] = tok
        self._tokens = jnp.asarray(new_tok)

    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        steps = 0
        while (self.active or self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return {uid: st.generated for uid, st in sorted(self.finished.items())}
