from repro.serving.engine import Request, RequestState, ServingConfig, ServingEngine
