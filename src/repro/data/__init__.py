from repro.data.pipeline import DataConfig, SyntheticLMDataset, make_batch_shapes
