"""Deterministic synthetic data pipeline, host-sharded.

Real clusters stream tokenized shards; this container has no corpus, so
the pipeline synthesizes *deterministic* token streams: batch ``i`` of a
run is a pure function of (seed, step, host) — restart-safe (checkpoint
resume regenerates the identical stream, tested) and host-sharded (each
data-parallel host materialises only its slice, as a real loader would).

The stream is not uniform noise: tokens follow a skewed unigram
distribution with short-range Markov structure so the training loss has
signal to descend — quickstart/train examples show a real learning curve.

``[vlm]``/``[audio]`` archs additionally get deterministic patch/frame
embedding stand-ins (the assignment treats modality frontends as stubs).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["DataConfig", "SyntheticLMDataset", "make_batch_shapes"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    ignore_id: int = -100


class SyntheticLMDataset:
    """Deterministic, indexable stream of LM batches.

    ``batch(step)`` is a pure function — calling it twice, on any host
    subset, in any order, yields identical data.  Per-host slicing takes
    ``global_batch // num_hosts`` rows.
    """

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide num_hosts")
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._zipf = self._unigram(cfg.vocab_size)

    @staticmethod
    def _unigram(v: int) -> np.ndarray:
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        return p / p.sum()

    # ------------------------------------------------------------------
    def batch(self, step: int) -> dict:
        cfg = self.cfg
        local = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index])
        )
        # skewed unigram draw + Markov smoothing: next token correlates
        # with the previous one ⇒ learnable bigram structure.
        base = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len), p=self._zipf)
        carry = rng.random((local, cfg.seq_len)) < 0.3
        tokens = base.copy()
        tokens[:, 1:] = np.where(
            carry[:, 1:],
            (tokens[:, :-1] * 31 + 17) % cfg.vocab_size,  # deterministic successor
            base[:, 1:],
        )
        tokens = tokens.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((local, 1), cfg.ignore_id, np.int32)], axis=1
        )
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        mc = self.model_cfg
        if mc is not None and mc.frontend == "vision_patches":
            n = mc.frontend_seq or 16
            out["patch_embeds"] = self._frontend_embeds(rng, local, n, mc)
        if mc is not None and mc.frontend == "audio_frames":
            n = mc.frontend_seq or 16
            out["frame_embeds"] = self._frontend_embeds(rng, local, n, mc)
        return out

    @staticmethod
    def _frontend_embeds(rng, local: int, n: int, mc: ModelConfig) -> jnp.ndarray:
        e = rng.standard_normal((local, n, mc.d_model)).astype(np.float32) * 0.02
        return jnp.asarray(e, jnp.bfloat16 if mc.dtype == "bfloat16" else jnp.float32)

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def take(self, n: int, start: int = 0) -> Iterator[dict]:
        for s in range(start, start + n):
            yield self.batch(s)


def make_batch_shapes(
    model_cfg: ModelConfig, seq_len: int, global_batch: int
) -> dict:
    """ShapeDtypeStruct stand-ins for one *training* batch (dry-run path)."""
    shapes = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    dt = jnp.bfloat16 if model_cfg.dtype == "bfloat16" else jnp.float32
    if model_cfg.frontend == "vision_patches":
        n = model_cfg.frontend_seq or 16
        shapes["patch_embeds"] = jax.ShapeDtypeStruct((global_batch, n, model_cfg.d_model), dt)
    if model_cfg.frontend == "audio_frames":
        n = model_cfg.frontend_seq or 16
        shapes["frame_embeds"] = jax.ShapeDtypeStruct((global_batch, n, model_cfg.d_model), dt)
    return shapes
