"""Environment-adaptive re-partitioning (paper §3.2, Fig. 1).

The paper's workflow: profile once, partition, then *monitor* the mobile
environment (bandwidth, cloud speed); when drift exceeds a threshold,
re-partition with the new parameters.  Here the same loop drives
re-placement across TPU tiers: the network profiler's bandwidth estimate
(ICI/DCN/PCIe) and the tier speed ratio F play the paper's roles, and
"re-partition" maps to re-running MCOP and re-emitting placement artifacts
(see `repro.core.placement`).  Elastic events (chip loss) enter the same
path: they change the tier compute capacity, i.e. F.

Hysteresis: re-partitioning is itself a cost (recompilation/resharding in
our setting; process migration in the paper's), so the controller only
acts on *relative* drift above ``threshold`` and enforces a cooldown of
``min_interval`` environment updates between repartitions.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import baselines
from repro.core.cost_models import AppProfile, CostModel, Environment, offloading_gain
from repro.core.graph import WCG
from repro.core.mcop import MCOPResult, mcop

__all__ = ["EnvironmentDrift", "AdaptiveController", "AdaptationEvent"]


@dataclasses.dataclass
class AdaptationEvent:
    step: int
    env: Environment
    result: MCOPResult
    partial_cost: float
    no_offload_cost: float
    full_offload_cost: float
    gain: float
    repartitioned: bool


class EnvironmentDrift:
    """Tracks relative drift of the (B, F) environment since last partition."""

    def __init__(self, threshold: float = 0.10):
        self.threshold = threshold
        self._anchor: Environment | None = None

    def anchor(self, env: Environment) -> None:
        self._anchor = env

    def exceeded(self, env: Environment) -> bool:
        if self._anchor is None:
            return True
        a = self._anchor

        def rel(new: float, old: float) -> float:
            return abs(new - old) / max(abs(old), 1e-30)

        return (
            rel(env.bandwidth_up, a.bandwidth_up) > self.threshold
            or rel(env.bandwidth_down, a.bandwidth_down) > self.threshold
            or rel(env.speedup, a.speedup) > self.threshold
        )


class AdaptiveController:
    """Fig. 1 loop: (re-)partition when the monitored environment drifts.

    Parameters:
      profile:     program-profiler output (environment-independent).
      cost_model:  which objective (time / energy / weighted).
      threshold:   relative drift that triggers re-partitioning.
      min_interval: cooldown in observe() calls between repartitions.
      backend:     MCOP backend ("reference" or "jax").
    """

    def __init__(
        self,
        profile: AppProfile,
        cost_model: CostModel,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
        backend: str = "reference",
    ):
        self.profile = profile
        self.cost_model = cost_model
        self.drift = EnvironmentDrift(threshold)
        self.min_interval = min_interval
        self.backend = backend
        self._steps_since = 10**9
        self._step = 0
        self._current: MCOPResult | None = None
        self.history: list[AdaptationEvent] = []

    # ------------------------------------------------------------------
    def observe(self, env: Environment) -> AdaptationEvent:
        """Feed one environment measurement; repartition if warranted."""
        self._step += 1
        self._steps_since += 1
        g = self.cost_model.build(self.profile, env)
        repartition = (
            self._current is None
            or (self.drift.exceeded(env) and self._steps_since >= self.min_interval)
        )
        if repartition:
            candidate = mcop(g, backend=self.backend)
            # paper §4.3: only partition when beneficial — compare against
            # the all-local plan (MCOP's phase cuts never return it).
            no_off = baselines.no_offloading(g)
            if no_off.cost < candidate.min_cut:
                candidate = MCOPResult(
                    min_cut=no_off.cost,
                    local_mask=no_off.local_mask,
                    phases=candidate.phases,
                )
            self._current = candidate
            self.drift.anchor(env)
            self._steps_since = 0
        assert self._current is not None
        # Cost of the *current* placement under the *new* environment: if we
        # chose not to repartition, we still pay today's prices.
        partial = g.total_cost(self._current.local_mask)
        no_off = baselines.no_offloading(g).cost
        full = baselines.full_offloading(g).cost
        event = AdaptationEvent(
            step=self._step,
            env=env,
            result=self._current,
            partial_cost=partial,
            no_offload_cost=no_off,
            full_offload_cost=full,
            gain=offloading_gain(no_off, partial),
            repartitioned=repartition,
        )
        self.history.append(event)
        return event

    # ------------------------------------------------------------------
    def sweep(
        self, envs: list[Environment]
    ) -> list[AdaptationEvent]:
        return [self.observe(e) for e in envs]

    @property
    def placement(self) -> MCOPResult:
        if self._current is None:
            raise RuntimeError("no partition computed yet; call observe()")
        return self._current
