"""Environment-adaptive re-partitioning (paper §3.2, Fig. 1).

The paper's workflow: profile once, partition, then *monitor* the mobile
environment (bandwidth, cloud speed); when drift exceeds a threshold,
re-partition with the new parameters.  Here the same loop drives
re-placement across TPU tiers: the network profiler's bandwidth estimate
(ICI/DCN/PCIe) and the tier speed ratio F play the paper's roles, and
"re-partition" maps to re-running MCOP and re-emitting placement artifacts
(see `repro.core.placement`).  Elastic events (chip loss) enter the same
path: they change the tier compute capacity, i.e. F.

Hysteresis: re-partitioning is itself a cost (recompilation/resharding in
our setting; process migration in the paper's), so the controller only
acts on *relative* drift above ``threshold`` and enforces a cooldown of
``min_interval`` environment updates between repartitions.

Throughput: :meth:`AdaptiveController.sweep` is the batched entry point.
Repartition decisions depend only on the environment trace (drift +
cooldown), never on solver output, so a sweep can decide every step up
front, solve all repartition points in ONE ``mcop_batch`` dispatch, and
serve repeats from a :class:`~repro.core.placement_cache.PlacementCache`
keyed on quantized environment bins.  With ``cache=None`` the sweep is
bit-identical to calling :meth:`observe` per environment.

Serving scale: where :meth:`AdaptiveController.sweep` batches one
controller across *time*, the :class:`repro.service.broker.OffloadBroker`
batches many controllers across *users* — per-user
:class:`repro.service.session.BrokerSession`s drive this controller's
:meth:`~AdaptiveController.begin_step` / :meth:`~AdaptiveController.commit_step`
split and route the solves through the broker's coalesced per-tick
``mcop_batch`` dispatches and shared persistent cache.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import baselines, pricing
from repro.core.cost_models import AppProfile, CostModel, Environment, offloading_gain
from repro.core.graph import WCG
from repro.core.mcop import MCOPResult, mcop, solve_envs
from repro.core.placement_cache import PlacementCache

__all__ = [
    "EnvironmentDrift",
    "AdaptiveController",
    "AdaptationEvent",
    "drift_exceeded_arrays",
]


def drift_exceeded_arrays(
    anchor_up,
    anchor_down,
    anchor_speedup,
    up,
    down,
    speedup,
    threshold: float,
):
    """Vectorized drift test over K (anchor, observation) pairs.

    The single place the relative-drift comparison lives: the scalar
    :meth:`EnvironmentDrift.exceeded_between` is literally a batch of
    one over this function, and the batched session engine
    (``repro.core.session_batch``) runs it over all active sessions at
    once — the two paths can never disagree about a drift boundary.

    Written against the array namespace of its inputs (numpy or jax), so
    it can also run inside a jitted program; the session tick keeps it on
    host numpy float64 because the decision must stay bit-identical to
    the scalar controller (jax without x64 would demote to float32).
    Returns a (k,) bool array: relative drift of bandwidth (either
    direction) or speedup strictly above ``threshold``.
    """
    import jax
    import jax.numpy as jnp

    xp = jnp if isinstance(up, jax.Array) else np

    def rel(new, old):
        return xp.abs(new - old) / xp.maximum(xp.abs(old), 1e-30)

    return (
        (rel(up, anchor_up) > threshold)
        | (rel(down, anchor_down) > threshold)
        | (rel(speedup, anchor_speedup) > threshold)
    )


@dataclasses.dataclass
class AdaptationEvent:
    step: int
    env: Environment
    result: MCOPResult
    partial_cost: float
    no_offload_cost: float
    full_offload_cost: float
    gain: float
    repartitioned: bool
    cache_hit: bool = False


class EnvironmentDrift:
    """Tracks relative drift of the (B, F) environment since last partition."""

    def __init__(self, threshold: float = 0.10):
        self.threshold = threshold
        self._anchor: Environment | None = None

    def anchor(self, env: Environment) -> None:
        self._anchor = env

    def exceeded(self, env: Environment) -> bool:
        if self._anchor is None:
            return True
        return self.exceeded_between(self._anchor, env, self.threshold)

    @staticmethod
    def exceeded_between(
        anchor: Environment, env: Environment, threshold: float
    ) -> bool:
        """Stateless drift test — also used by the batched sweep's decision
        pre-pass, which simulates anchor updates without mutating state.

        A batch of one over :func:`drift_exceeded_arrays` (IEEE-identical
        to the historical scalar expression), so the per-object and
        batched-session paths share one drift boundary."""
        return bool(
            drift_exceeded_arrays(
                np.float64(anchor.bandwidth_up),
                np.float64(anchor.bandwidth_down),
                np.float64(anchor.speedup),
                np.float64(env.bandwidth_up),
                np.float64(env.bandwidth_down),
                np.float64(env.speedup),
                threshold,
            )
        )


class AdaptiveController:
    """Fig. 1 loop: (re-)partition when the monitored environment drifts.

    Parameters:
      profile:     program-profiler output (environment-independent).
      cost_model:  which objective (time / energy / weighted).
      threshold:   relative drift that triggers re-partitioning.
      min_interval: cooldown in observe() calls between repartitions.
      backend:     MCOP backend ("reference", "jax" or "pallas").
      cache:       optional PlacementCache; repartitions whose quantized
                   environment was solved before reuse the cached mask
                   (re-priced at the exact current environment).  Share one
                   cache across controllers that partition the same profile.
    """

    def __init__(
        self,
        profile: AppProfile,
        cost_model: CostModel,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
        backend: str = "reference",
        cache: PlacementCache | None = None,
    ):
        self.profile = profile
        self.cost_model = cost_model
        self.drift = EnvironmentDrift(threshold)
        self.min_interval = min_interval
        self.backend = backend
        self.cache = cache
        self._steps_since = 10**9
        self._step = 0
        self._current: MCOPResult | None = None
        # decision-level flag: a partition exists or has been *scheduled*
        # (begin_step may run ticks before the deferred solve commits)
        self._has_partition = False
        self.history: list[AdaptationEvent] = []

    # ------------------------------------------------------------------
    def _clamp(self, g: WCG, candidate: MCOPResult) -> MCOPResult:
        """Paper §4.3: only partition when beneficial (shared clamp)."""
        return baselines.clamp_no_offloading(g, candidate)

    # -- decision-state checkpointing (shared with BrokerSession) ------
    def checkpoint_decision(self) -> tuple:
        """Snapshot the drift/cooldown decision state before a step.

        Pair with :meth:`rollback_decision` when the solve that
        :meth:`begin_step` scheduled never lands (solver failure,
        broker backpressure rejection) — used by :meth:`observe`'s own
        containment and by ``BrokerSession``.
        """
        return (self.drift._anchor, self._steps_since, self._has_partition)

    def rollback_decision(self, state: tuple) -> None:
        """Undo :meth:`begin_step`'s decision effects after a failed step.

        The step still happened (the clock advanced; the cooldown counts
        it) but no partition was installed, so the next observation
        retries instead of serving a placement that never arrived.
        """
        anchor, steps_since, had_partition = state
        self.drift._anchor = anchor
        self._steps_since = steps_since + 1
        self._has_partition = had_partition

    def _reprice(self, g: WCG, mask: np.ndarray) -> MCOPResult:
        """A cached mask is re-priced at the exact current WCG and clamped
        (shared with the broker via :func:`baselines.reprice_clamped`) —
        costs stay honest even though the placement came from a same-bin
        neighbor."""
        return baselines.reprice_clamped(g, mask)

    def _repartition_due(self, env: Environment) -> bool:
        return not self._has_partition or (
            self.drift.exceeded(env) and self._steps_since >= self.min_interval
        )

    def _emit(
        self,
        g: WCG | None,
        env: Environment,
        repartitioned: bool,
        cache_hit: bool,
        step: int | None = None,
        priced: tuple[float, float, float] | None = None,
    ) -> AdaptationEvent:
        """Record one event.

        ``priced`` is the precomputed ``(partial, no_offload,
        full_offload)`` triple when the caller already priced the whole
        trace in one batched evaluation (:meth:`sweep` passes ``g=None``
        then); the serial path evaluates the three numbers on ``g`` —
        bit-identical to one row of the batched report (see
        ``repro.core.pricing``).
        """
        assert self._current is not None
        # Cost of the *current* placement under the *new* environment: if we
        # chose not to repartition, we still pay today's prices.
        if priced is None:
            assert g is not None
            partial = g.total_cost(self._current.local_mask)
            no_off = baselines.no_offloading(g).cost
            full = baselines.full_offloading(g).cost
        else:
            partial, no_off, full = priced
        event = AdaptationEvent(
            step=self._step if step is None else step,
            env=env,
            result=self._current,
            partial_cost=partial,
            no_offload_cost=no_off,
            full_offload_cost=full,
            gain=offloading_gain(no_off, partial),
            repartitioned=repartitioned,
            cache_hit=cache_hit,
        )
        self.history.append(event)
        return event

    # ------------------------------------------------------------------
    def begin_step(self, env: Environment) -> tuple[WCG, bool]:
        """Advance the loop clock and take the repartition decision.

        The drift/cooldown decision never depends on solver output, so
        its state effects (anchor move, cooldown reset) apply
        immediately.  That split is what lets an
        :class:`~repro.service.broker.OffloadBroker` defer the solve to
        a later coalesced tick: callers pair this with
        :meth:`commit_step` once the placement is available.  Returns
        the WCG priced at ``env`` and whether a repartition is due.
        """
        self._step += 1
        self._steps_since += 1
        g = self.cost_model.build(self.profile, env)
        due = self._repartition_due(env)
        if due:
            self.drift.anchor(env)
            self._steps_since = 0
            self._has_partition = True
        return g, due

    def commit_step(
        self,
        g: WCG,
        env: Environment,
        candidate: MCOPResult | None,
        *,
        repartitioned: bool,
        cache_hit: bool = False,
        step: int | None = None,
    ) -> AdaptationEvent:
        """Install the resolved placement (if any) and emit the event.

        ``candidate`` must already be clamped (paper §4.3) and priced for
        ``g`` — :meth:`_resolve` and the broker both guarantee this.
        Deferred callers (broker sessions committing a backlog after a
        tick) pass the ``step`` number captured at :meth:`begin_step`
        time so events carry the observation's own step, not the latest
        clock value.
        """
        if repartitioned:
            assert candidate is not None
            self._current = candidate
        return self._emit(g, env, repartitioned, cache_hit, step=step)

    def _resolve(self, g: WCG, env: Environment) -> tuple[MCOPResult, bool]:
        """Cache-or-solve for one repartition point (serial path)."""
        if self.cache is not None:
            mask = self.cache.get(env, expected_n=g.n)
            if mask is not None:
                return self._reprice(g, mask), True
        candidate = self._clamp(g, mcop(g, backend=self.backend))
        if self.cache is not None:
            self.cache.put(env, candidate.local_mask)
        return candidate, False

    def observe(self, env: Environment) -> AdaptationEvent:
        """Feed one environment measurement; repartition if warranted."""
        checkpoint = self.checkpoint_decision()
        g, due = self.begin_step(env)
        if not due:
            return self.commit_step(g, env, None, repartitioned=False)
        try:
            candidate, cache_hit = self._resolve(g, env)
        except BaseException:
            # a solver failure must not corrupt the loop: undo the decision
            # effects so the next observe() retries instead of serving a
            # placement that never arrived
            self.rollback_decision(checkpoint)
            raise
        return self.commit_step(
            g, env, candidate, repartitioned=True, cache_hit=cache_hit
        )

    # ------------------------------------------------------------------
    def sweep(self, envs: Sequence[Environment]) -> list[AdaptationEvent]:
        """Batched Fig.-1 loop: one fused ``solve_envs`` dispatch per sweep.

        Semantics match calling :meth:`observe` per environment
        (bit-identical events when ``cache is None``), but all
        repartition points are solved together and the whole trace is
        priced together: pass 1 replays the drift/cooldown decision
        sequence (which never depends on solver output), pass 2 resolves
        each repartition from the cache or the fused build+solve program
        (WCG construction happens on-device, inside the same XLA program
        as the solver), pass 3 prices every step — current placement,
        no-offload and full-offload baselines, stale-placement repricing
        and the §4.3 clamps — in ONE
        :func:`repro.core.pricing.price_batch` evaluation, and pass 4
        emits events from the report.

        Exact cache-counter parity with the serial loop assumes the cache
        capacity exceeds the number of distinct environment bins in one
        sweep (all lookups happen before the batch's stores, so a cache
        small enough to evict *within* a sweep sees slightly fewer misses
        than serial observe would).  The default capacity (4096) is far
        above any realistic per-sweep bin count.
        """
        envs = list(envs)
        # ---- pass 1: decide repartition steps without solving ----------
        steps_since = self._steps_since
        anchor = self.drift._anchor
        have_current = self._has_partition
        decisions: list[bool] = []
        for env in envs:
            steps_since += 1
            exceeded = anchor is None or EnvironmentDrift.exceeded_between(
                anchor, env, self.drift.threshold
            )
            repart = (not have_current) or (
                exceeded and steps_since >= self.min_interval
            )
            decisions.append(repart)
            if repart:
                anchor = env
                steps_since = 0
                have_current = True

        # ---- pass 2: resolve each repartition (cache or fused solve) ---
        # One vectorized host build (for exact f64 pricing/repricing) in
        # place of K per-environment Python constructions; rows are
        # bit-identical to cost_model.build(profile, env).
        batch = self.cost_model.build_batch(self.profile, envs)
        # Vectorized §7.1 all-local baselines for the whole sweep.  These
        # also drive the §4.3 clamp of solved candidates, so no per-step
        # baseline evaluation remains anywhere in the sweep.
        no_off_costs = np.asarray(batch.w_local).sum(axis=-1)
        # per repartition step: ("mask", mask) — cache hit; ("solve", slot)
        # — own batched solve; ("reuse", slot) — same-bin reuse in-sweep
        source: dict[int, tuple] = {}
        solve_steps: list[int] = []
        pending: dict[tuple, int] = {}  # quantized key -> solve slot
        for i, env in enumerate(envs):
            if not decisions[i]:
                continue
            if self.cache is None:
                source[i] = ("solve", len(solve_steps))
                solve_steps.append(i)
                continue
            key = self.cache.key(env)
            mask = self.cache.lookup(key, expected_n=self.profile.n)
            if mask is not None:
                self.cache.record(True)
                source[i] = ("mask", mask)
            elif key in pending:
                # an earlier step this sweep already scheduled this bin; in
                # the serial loop its put() would have made this a hit
                self.cache.record(True)
                source[i] = ("reuse", pending[key])
            else:
                self.cache.record(False)
                slot = len(solve_steps)
                solve_steps.append(i)
                pending[key] = slot
                source[i] = ("solve", slot)
        # the misses flush through the fused build+solve program: one XLA
        # dispatch constructs their WCGs on-device and runs Stoer–Wagner
        solved = (
            solve_envs(
                self.profile,
                self.cost_model,
                [envs[i] for i in solve_steps],
                backend=self.backend,
            )
            if solve_steps
            else []
        )
        clamped_solved = [
            baselines.clamp_no_offloading_priced(r, float(no_off_costs[solve_steps[s]]))
            for s, r in enumerate(solved)
        ]
        if self.cache is not None:
            for key, slot in pending.items():
                self.cache.store(key, clamped_solved[slot].local_mask)

        # ---- pass 3: ONE fused pricing evaluation, then emit -----------
        # Simulate the mask the controller will hold at every step.  A
        # cache/reuse repartition is repriced under its exact current WCG
        # and §4.3-clamped, but the clamp depends on the repriced cost —
        # which comes out of the same batched evaluation.  So each row
        # carries the *candidate* mask plus the row index whose clamp
        # decision governs it, and the select below resolves the priced
        # cost to the no-offloading number exactly when that governing
        # step clamped (the placement is all-local from then on).
        k, n = len(envs), self.profile.n
        masks = np.ones((k, batch.m), dtype=bool)
        governs: list[int | None] = [None] * k
        cur_mask = (
            np.asarray(self._current.local_mask, dtype=bool)
            if self._current is not None
            else None
        )
        cur_govern: int | None = None
        for i in range(k):
            if decisions[i]:
                kind, payload = source[i]
                if kind == "solve":
                    cur_mask = clamped_solved[payload].local_mask
                    cur_govern = None  # already clamped in pass 2
                else:  # "mask" (cache hit) / "reuse" (in-sweep follower)
                    cur_mask = np.asarray(
                        payload
                        if kind == "mask"
                        else clamped_solved[payload].local_mask,
                        dtype=bool,
                    )
                    cur_govern = i
            assert cur_mask is not None  # decisions guarantee a partition
            governs[i] = cur_govern
            masks[i, :n] = cur_mask
        report = pricing.price_batch(batch, masks)
        clamped = report.no_offload_cost < report.partial_cost  # §4.3, strict

        # ---- pass 4: emit events, updating state exactly like observe --
        events: list[AdaptationEvent] = []
        for i, env in enumerate(envs):
            self._step += 1
            self._steps_since += 1
            cache_hit = False
            j = governs[i]
            take_no_off = j is not None and bool(clamped[j])
            partial = float(
                report.no_offload_cost[i] if take_no_off else report.partial_cost[i]
            )
            if decisions[i]:
                kind, payload = source[i]
                if kind == "solve":
                    self._current = clamped_solved[payload]
                else:
                    # reprice through the fused report (shared §4.3 clamp)
                    self._current = baselines.reprice_clamped_priced(
                        float(report.partial_cost[i]),
                        float(report.no_offload_cost[i]),
                        masks[i, :n],
                    )
                    cache_hit = True
                self.drift.anchor(env)
                self._steps_since = 0
                self._has_partition = True
            events.append(
                self._emit(
                    None,
                    env,
                    decisions[i],
                    cache_hit,
                    priced=(
                        partial,
                        float(report.no_offload_cost[i]),
                        float(report.full_offload_cost[i]),
                    ),
                )
            )
        return events

    @property
    def placement(self) -> MCOPResult:
        if self._current is None:
            raise RuntimeError("no partition computed yet; call observe()")
        return self._current
