"""Mesh-parallel MCOP solve plane — the "solver fleet".

One broker flush produces a bucket's worth of WCG instances; this module
splits that batch across every device of a 1-D ``("solve",)`` mesh (see
``repro.launch.mesh.make_solver_mesh``) with ``shard_map`` and gathers
the cuts/masks back **bit-identically** to the single-device path.  The
parity argument: the batched solvers (``_mcop_jax_batch``'s vmapped
while_loop and the Pallas grid kernel) do strictly per-graph arithmetic —
lane masking in a vmapped while_loop changes which lanes *update*, never
the update math — so regrouping rows across devices cannot perturb a
single bit.  The parity suite enforces this with ``==``, no tolerances.

Placement is round-robin with inert padding:

* the batch is zero-padded to a multiple of the shard count with graphs
  that are all-pinned with zero weights (the anchor fold absorbs them in
  zero phases; their rows are cropped after the gather), so uneven
  bucket populations keep every device busy instead of idling the tail;
* rows are dealt round-robin (row ``i`` → device ``i mod D``) and the
  inverse permutation restores input order on the host — when callers
  sort work by difficulty, consecutive hard rows land on *different*
  devices instead of serializing on one.

Input buffers are donated to the compiled program (``donate_argnums`` on
the batch pytree) except on the CPU backend, where XLA cannot reuse
donated host buffers and would warn on every dispatch.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` simulates an
N-device fleet on a CPU host — that is how the parity tests and
``benchmarks/shard.py`` exercise this module without a TPU pod.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import make_solver_mesh
from repro.runtime.sharding import solve_batch_spec, solver_axis, solver_shards

__all__ = [
    "ShardPlan",
    "shard_plan",
    "default_solver_mesh",
    "resolve_mesh",
    "sharded_dispatch_arrays",
    "sharded_fused_solver",
]


# ----------------------------------------------------------------------
# Mesh resolution
# ----------------------------------------------------------------------


def default_solver_mesh() -> Mesh | None:
    """The fleet this process can see, or ``None`` on a single device.

    ``None`` keeps single-device hosts on the exact historical dispatch
    path (no shard_map wrapper, no permutation) — multi-device hosts get
    the fleet transparently.
    """
    if jax.device_count() <= 1:
        return None
    return make_solver_mesh()


def resolve_mesh(mesh) -> Mesh | None:
    """Normalize the ``mesh=`` argument the solve entry points accept.

    * ``None``  — auto: :func:`default_solver_mesh`.
    * ``False`` — force the single-device path even on a fleet.
    * a ``Mesh`` — use it; a 1-shard mesh collapses to the plain path
      (identical results, and skipping shard_map avoids a pointless
      permutation round-trip).
    """
    if mesh is None:
        return default_solver_mesh()
    if mesh is False:
        return None
    if not isinstance(mesh, Mesh):
        raise TypeError(f"mesh must be a Mesh, None, or False; got {mesh!r}")
    return mesh if solver_shards(mesh) > 1 else None


# ----------------------------------------------------------------------
# Shard plan: padding + round-robin permutation (pure numpy, testable)
# ----------------------------------------------------------------------


class ShardPlan(NamedTuple):
    """How k rows land on a d-shard fleet.

    ``perm`` reorders the padded batch into device-major blocks (device
    s's rows are contiguous), ``inverse`` undoes it after the gather;
    both have length ``k + pad``.
    """

    shards: int
    k: int
    pad: int
    perm: np.ndarray
    inverse: np.ndarray

    @property
    def rows_per_shard(self) -> int:
        return (self.k + self.pad) // self.shards


def shard_plan(k: int, shards: int) -> ShardPlan:
    """Round-robin placement of k rows onto ``shards`` devices.

    Row ``i`` goes to device ``i mod shards``; padding rows (appended at
    the tail, indices ``k .. k+pad-1``) fill the remainder so every
    device receives exactly ``(k + pad) / shards`` rows.
    """
    if k <= 0:
        raise ValueError(f"cannot plan a shard layout for k={k} rows")
    if shards <= 0:
        raise ValueError(f"cannot shard over {shards} devices")
    pad = (-k) % shards
    kp = k + pad
    perm = np.argsort(np.arange(kp) % shards, kind="stable")
    inverse = np.empty(kp, dtype=np.int64)
    inverse[perm] = np.arange(kp)
    return ShardPlan(shards=shards, k=k, pad=pad, perm=perm, inverse=inverse)


def _donate(mesh: Mesh) -> bool:
    # XLA's CPU client can't alias donated host buffers (it warns and
    # copies anyway) — donation is a device-memory optimization.
    return next(iter(mesh.devices.flat)).platform != "cpu"


# ----------------------------------------------------------------------
# Sharded raw-array dispatch (mcop_batch / WCGBatch flush path)
# ----------------------------------------------------------------------

# Compiled sharded programs, keyed (mesh, backend, interpret, donate);
# jit specializes per input shape underneath, so bucket size and batch
# never appear in the key.  Mesh is hashable and tiny; a process holds a
# handful of meshes at most, so no LRU pressure here.
_SHARDED_DISPATCH_CACHE: dict = {}


def _sharded_dispatch(mesh: Mesh, backend: str, interpret: bool | None):
    key = (mesh, backend, interpret)
    fn = _SHARDED_DISPATCH_CACHE.get(key)
    if fn is None:
        from repro.core.mcop import _dispatch_arrays  # deferred: cycle

        spec = solve_batch_spec(mesh)

        def solve(adj, wl, wc, pin):
            return _dispatch_arrays(adj, wl, wc, pin, backend, interpret)

        # check_rep=False: the bodies contain while_loop / pallas_call,
        # which shard_map's replication checker cannot see through.
        sharded = shard_map(
            solve,
            mesh=mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec),
            check_rep=False,
        )
        donate = (0, 1, 2, 3) if _donate(mesh) else ()
        fn = _SHARDED_DISPATCH_CACHE[key] = jax.jit(
            sharded, donate_argnums=donate
        )
    return fn


def _emit_shard_spans(tracer, plan: ShardPlan, outputs, *, stage: str):
    """Per-shard completion spans: ``<stage>.shard`` with the device's
    row count; duration is the host-observed wait for that device's
    output buffer (a real measurement — on a fleet the earliest shards
    return while later ones still solve)."""
    if tracer is None:
        return
    cuts = outputs[0]
    shards = getattr(cuts, "addressable_shards", None)
    per_device = list(shards) if shards else []
    for s in range(plan.shards):
        rows = int(np.sum((np.arange(plan.k) % plan.shards) == s))
        with tracer.span(
            f"{stage}.shard", shard=s, devices=plan.shards, rows=rows
        ):
            if s < len(per_device):
                jax.block_until_ready(per_device[s].data)


def sharded_dispatch_arrays(
    adj,
    wl,
    wc,
    pin,
    *,
    mesh: Mesh,
    backend: str,
    interpret: bool | None = None,
    tracer=None,
):
    """Solve a packed ``(k, m[, m])`` bucket across the fleet.

    Drop-in for ``core.mcop._dispatch_arrays`` with a mesh: pads +
    round-robins the rows, runs one shard_map program, and returns
    ``(cuts (k,), masks (k, m))`` in input order, bit-identical to the
    single-device dispatch.  Inputs may be numpy or device arrays; the
    permutation runs on the host (exact), the solve on the mesh.
    """
    adj = np.asarray(adj)
    wl = np.asarray(wl)
    wc = np.asarray(wc)
    pin = np.asarray(pin)
    k, m = wl.shape
    plan = shard_plan(k, solver_shards(mesh))
    if plan.pad:
        # inert rows: all-pinned, zero weights/edges — the anchor fold
        # collapses them before any phase runs; cropped after the gather
        adj = np.concatenate([adj, np.zeros((plan.pad, m, m), adj.dtype)])
        wl = np.concatenate([wl, np.zeros((plan.pad, m), wl.dtype)])
        wc = np.concatenate([wc, np.zeros((plan.pad, m), wc.dtype)])
        pin = np.concatenate([pin, np.ones((plan.pad, m), pin.dtype)])
    fn = _sharded_dispatch(mesh, backend, interpret)
    cuts_sh, masks_sh = fn(
        adj[plan.perm], wl[plan.perm], wc[plan.perm], pin[plan.perm]
    )
    _emit_shard_spans(tracer, plan, (cuts_sh, masks_sh), stage="solve")
    cuts_sh, masks_sh = jax.device_get((cuts_sh, masks_sh))
    return cuts_sh[plan.inverse][: plan.k], masks_sh[plan.inverse][: plan.k]


# ----------------------------------------------------------------------
# Sharded fused build+solve (solve_envs flush path)
# ----------------------------------------------------------------------


def sharded_fused_solver(build_solve, mesh: Mesh, env_struct):
    """Wrap an *unjitted* fused build+solve closure for the fleet.

    ``build_solve(t_local, data_in, data_out, pinned, env)`` maps K
    environment rows to ``(cuts (K,), masks (K, m))``; the profile
    tensors are replicated to every device, the environment columns
    (an ``EnvArrays``-style pytree of (k,) leaves, structure given by
    ``env_struct``) are sharded along the solve axis.  Returns a jitted
    callable with the same signature.  Padding/permutation live in
    :func:`sharded_solve_envs_call`, not here — this is the cacheable
    compiled object.
    """
    spec = solve_batch_spec(mesh)
    env_specs = jax.tree_util.tree_unflatten(
        env_struct, [spec] * env_struct.num_leaves
    )
    sharded = shard_map(
        build_solve,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), env_specs),
        out_specs=(spec, spec),
        check_rep=False,
    )
    # donate the env columns (the per-tick varying buffers); the profile
    # tensors are replicated constants the caller reuses across ticks
    donate = (4,) if _donate(mesh) else ()
    return jax.jit(sharded, donate_argnums=donate)


def sharded_solve_envs_call(
    fn,
    t_local,
    data_in,
    data_out,
    pinned,
    env_arrays,
    *,
    mesh: Mesh,
    tracer=None,
):
    """Run a :func:`sharded_fused_solver` program over K environments.

    Pads the environment columns with rows of 1.0 (a benign environment:
    unit bandwidths/powers/speedup — solved and discarded), round-robins
    rows, dispatches once, and restores input order.  Returns
    ``(cuts (k,), masks (k, m))`` as host arrays, bit-identical to the
    unsharded fused program (row-wise build + per-graph solve).
    """
    cols = [np.asarray(c) for c in env_arrays]
    k = cols[0].shape[0]
    plan = shard_plan(k, solver_shards(mesh))
    if plan.pad:
        cols = [
            np.concatenate([c, np.ones(plan.pad, c.dtype)]) for c in cols
        ]
    cols = [c[plan.perm] for c in cols]
    env_sh = type(env_arrays)(*cols)
    cuts_sh, masks_sh = fn(t_local, data_in, data_out, pinned, env_sh)
    _emit_shard_spans(tracer, plan, (cuts_sh, masks_sh), stage="solve_envs")
    cuts_sh, masks_sh = jax.device_get((cuts_sh, masks_sh))
    return cuts_sh[plan.inverse][: plan.k], masks_sh[plan.inverse][: plan.k]
