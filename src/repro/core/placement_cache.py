"""Placement cache keyed on quantized environment parameters.

The adaptive loop (paper Fig. 1) re-partitions whenever the environment
drifts, but at serving scale the *same* environments recur constantly:
millions of users cycle through a handful of bandwidth/RTT/energy regimes
(WiFi, LTE, congested cell, …).  Re-running MCOP for every request wastes
the work — a placement computed at B = 8.0 MB/s is equally valid at
B = 8.2 MB/s, because the controller's own drift threshold already treats
those as "the same environment".

So the cache key is the environment *quantized* into geometric bins whose
relative width (default 10%) mirrors the drift threshold: two environments
land in the same bin exactly when re-partitioning between them would be
hysteresis noise.  The cached value is the placement *mask only* — on a
hit the caller re-prices the mask under the exact current WCG
(``g.total_cost(mask)``), so reported costs stay honest even when the
placement is reused (same contract as the controller's stale-placement
accounting).

Hit/miss counters make cache effectiveness observable; capacity is
bounded with LRU eviction so a long-lived server can't grow without
limit.  One cache instance should serve one (profile, cost-model)
pair — share it across controllers only when they partition the same
application (that is the multi-user win: N users, one profile, a handful
of environment bins).  At serving scale that sharing is done by the
:class:`repro.service.broker.OffloadBroker`, which owns one cache per
tenant and keeps it warm across process restarts via
:meth:`PlacementCache.snapshot` / :meth:`PlacementCache.load` — a JSON
document guarded by a schema version, the quantizer step, and a
:func:`profile_fingerprint` of the application profile, so a stale or
foreign snapshot degrades to a cold cache instead of serving wrong
placements.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import pathlib
import tempfile
from collections import OrderedDict
from typing import Tuple

import numpy as np

from repro.core.cost_models import Environment

__all__ = [
    "EnvQuantizer",
    "PlacementCache",
    "CacheStats",
    "profile_fingerprint",
    "SNAPSHOT_VERSION",
]

# Bump when the snapshot schema changes; load() ignores unknown versions.
SNAPSHOT_VERSION = 1


def profile_fingerprint(obj) -> str:
    """Stable content hash of an application profile (or WCG).

    Identifies *what was partitioned* so a persisted cache is only warm
    for the same application: masks are meaningless across profiles even
    when the vertex counts happen to match.  Accepts an
    :class:`~repro.core.cost_models.AppProfile` (``t_local``/``data_in``/
    ``data_out``/``offloadable``) or a :class:`~repro.core.graph.WCG`
    (``w_local``/``w_cloud``/``adj``/``offloadable``).
    """
    if hasattr(obj, "t_local"):
        arrays = (obj.t_local, obj.data_in, obj.data_out, obj.offloadable)
    elif hasattr(obj, "w_local"):
        arrays = (obj.w_local, obj.w_cloud, obj.adj, obj.offloadable)
    else:
        raise TypeError(f"cannot fingerprint {type(obj).__name__}")
    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class EnvQuantizer:
    """Maps an :class:`Environment` to a hashable bin key.

    Positive scalars are binned geometrically: ``bin(x) = round(ln x / ln
    (1 + rel_step))``, so bins are uniformly ``rel_step`` wide in relative
    terms at every scale — the natural metric for bandwidth/speedup, which
    the drift detector also compares relatively.  Powers enter the key too
    (the energy model prices transfers with them), with the same binning.
    """

    rel_step: float = 0.10

    def bins_batch(self, x: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`bin`: geometric binning of an array of scalars.

        The scalar :meth:`bin` rides this exact code path (a batch of
        one), so batched session engines and per-environment callers can
        never disagree about a bin boundary — ``np.round`` applies the
        same round-half-even rule as Python's ``round``.
        """
        x = np.asarray(x, dtype=np.float64)
        safe = np.where(x > 0.0, x, 1.0)
        b = np.round(np.log(safe) / np.log1p(self.rel_step)).astype(np.int64)
        # non-positive values: degenerate env; one shared sentinel bin
        return np.where(x > 0.0, b, np.int64(-(2**31)))

    def bin(self, x: float) -> int:
        return int(self.bins_batch(np.float64(x)))

    def key(self, env: Environment) -> Tuple[int, ...]:
        return (
            self.bin(env.bandwidth_up),
            self.bin(env.bandwidth_down),
            self.bin(env.speedup),
            self.bin(env.p_compute),
            self.bin(env.p_idle),
            self.bin(env.p_transfer),
        )

    def keys_batch(self, envs) -> np.ndarray:
        """K environments (:class:`~repro.core.cost_models.EnvArrays`) →
        ``(k, 6)`` int64 key rows, column order matching :meth:`key`.

        ``tuple(int(v) for v in row)`` of row ``i`` equals
        ``self.key(envs.env(i))`` exactly — the vectorized front door the
        batched session tick probes the cache with.
        """
        return np.stack(
            [
                self.bins_batch(envs.bandwidth_up),
                self.bins_batch(envs.bandwidth_down),
                self.bins_batch(envs.speedup),
                self.bins_batch(envs.p_compute),
                self.bins_batch(envs.p_idle),
                self.bins_batch(envs.p_transfer),
            ],
            axis=-1,
        )


@dataclasses.dataclass(frozen=True)
class CacheStats:
    hits: int
    misses: int
    size: int
    capacity: int
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class PlacementCache:
    """Quantized-environment → placement-mask cache with LRU eviction.

    ``get``/``put`` are the simple front door.  The batched sweep needs to
    separate *lookup* from *accounting* (a miss early in a sweep becomes a
    hit for later same-bin steps once the batch solve lands), so
    :meth:`lookup` and :meth:`record` are also public.
    """

    def __init__(
        self,
        quantizer: EnvQuantizer | None = None,
        *,
        capacity: int = 4096,
    ):
        if capacity <= 0:
            raise ValueError("cache capacity must be positive")
        self.quantizer = quantizer or EnvQuantizer()
        self.capacity = capacity
        self._entries: OrderedDict[Tuple[int, ...], np.ndarray] = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        # bound metrics instruments (None until bind_metrics); kept as a
        # flat tuple so the hot funnel pays one attribute read when unbound
        self._metric_instruments = None

    def bind_metrics(self, registry, **labels) -> None:
        """Mirror this cache's counters into a
        :class:`~repro.obs.metrics.MetricsRegistry`.

        ``labels`` identify the cache (the broker binds ``tenant=name``).
        Counters ``cache_hits`` / ``cache_misses`` / ``cache_evictions``
        and gauge ``cache_size`` pick up every event from bind time on;
        historical counts are seeded so the registry view equals
        :attr:`stats` at all times.
        """
        hits = registry.counter("cache_hits", **labels)
        misses = registry.counter("cache_misses", **labels)
        evictions = registry.counter("cache_evictions", **labels)
        size = registry.gauge("cache_size", **labels)
        hits.inc(self._hits)
        misses.inc(self._misses)
        evictions.inc(self._evictions)
        size.set(len(self._entries))
        self._metric_instruments = (hits, misses, evictions, size)

    # -- key/lookup/record primitives ----------------------------------
    def key(self, env: Environment) -> Tuple[int, ...]:
        return self.quantizer.key(env)

    def lookup(
        self, key: Tuple[int, ...], expected_n: int | None = None
    ) -> np.ndarray | None:
        """Return the cached local-mask for ``key`` (no counter update).

        ``expected_n`` guards against a cache (mis)shared across profiles
        of different graph sizes: a wrong-length mask is treated as
        absent, so callers never have to re-validate shapes.
        """
        mask = self._entries.get(key)
        if mask is None:
            return None
        if expected_n is not None and mask.shape != (expected_n,):
            return None
        self._entries.move_to_end(key)
        return mask.copy()

    def record(self, hit: bool) -> None:
        self.record_many(hits=int(hit), misses=1 - int(hit))

    def record_many(self, *, hits: int = 0, misses: int = 0) -> None:
        """THE stat funnel: every hit/miss count — scalar :meth:`record`,
        :meth:`get`, :meth:`get_many`, the batched session tick — lands
        here as one shared increment, so the scalar and batched paths
        cannot drift apart, and bound metrics see every event."""
        self._hits += int(hits)
        self._misses += int(misses)
        m = self._metric_instruments
        if m is not None:
            if hits:
                m[0].inc(hits)
            if misses:
                m[1].inc(misses)

    def store(self, key: Tuple[int, ...], local_mask: np.ndarray) -> None:
        self._entries[key] = np.asarray(local_mask, dtype=bool).copy()
        self._entries.move_to_end(key)
        evicted = 0
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            evicted += 1
        self._evictions += evicted
        m = self._metric_instruments
        if m is not None:
            if evicted:
                m[2].inc(evicted)
            m[3].set(len(self._entries))

    # -- convenience front door ----------------------------------------
    def get(
        self, env: Environment, expected_n: int | None = None
    ) -> np.ndarray | None:
        """Counted lookup by environment.

        Args:
          env:        the exact measured environment; quantized to a bin
                      key by the cache's :class:`EnvQuantizer`.
          expected_n: caller's graph size; a cached mask of any other
                      length is treated as a miss (guards a cache
                      mis-shared across profiles).
        Returns:
          ``(n,)`` bool local-mask *copy*, or ``None`` on miss.  Callers
          must re-price the mask under their exact current WCG
          (``g.total_cost(mask)``) — the honesty contract for every
          reused placement.
        """
        mask = self.lookup(self.key(env), expected_n)
        self.record(mask is not None)
        return mask

    def put(self, env: Environment, local_mask: np.ndarray) -> None:
        """Store ``local_mask`` ((n,) bool, copied) under ``env``'s bin."""
        self.store(self.key(env), local_mask)

    # -- batch front door (array-native session engine) ------------------
    def keys_batch(self, envs) -> list[Tuple[int, ...]]:
        """Quantize K environments (an ``EnvArrays``) to K bin keys.

        One vectorized binning pass; element ``i`` equals
        ``self.key(envs.env(i))`` exactly (see
        :meth:`EnvQuantizer.keys_batch`).
        """
        rows = self.quantizer.keys_batch(envs)
        return [tuple(int(v) for v in row) for row in rows]

    def get_many(
        self, envs, expected_n: int | None = None
    ) -> list[np.ndarray | None]:
        """Counted batch lookup: one quantization pass, K probes in order.

        Equivalent to ``[self.get(envs.env(i), expected_n) for i in
        range(envs.k)]`` — identical returned masks, identical hit/miss
        counters, identical LRU recency order (probes touch entries in
        row order) — with the per-environment Python quantization work
        hoisted into one vectorized pass.
        """
        out: list[np.ndarray | None] = []
        hits = 0
        for key in self.keys_batch(envs):
            mask = self.lookup(key, expected_n)
            hits += mask is not None
            out.append(mask)
        # one shared funnel call for the whole batch (not a record() per
        # key): same totals, and scalar/batched accounting share one
        # code path by construction
        self.record_many(hits=hits, misses=len(out) - hits)
        return out

    def put_many(self, envs, local_masks) -> None:
        """Batch store: row ``i`` of ``local_masks`` under ``envs`` row ``i``.

        Same effect as a scalar :meth:`put` loop in row order (later
        same-bin rows overwrite earlier ones, eviction order included).
        """
        masks = np.asarray(local_masks, dtype=bool)
        keys = self.keys_batch(envs)
        if masks.ndim != 2 or masks.shape[0] != len(keys):
            raise ValueError(
                f"local_masks must be ({len(keys)}, n), got {masks.shape}"
            )
        for key, mask in zip(keys, masks):
            self.store(key, mask)

    # -- observability --------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[int, ...]) -> bool:
        return key in self._entries

    @property
    def stats(self) -> CacheStats:
        return CacheStats(
            hits=self._hits,
            misses=self._misses,
            size=len(self._entries),
            capacity=self.capacity,
            evictions=self._evictions,
        )

    def clear(self) -> None:
        self._entries.clear()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        m = self._metric_instruments
        if m is not None:
            m[3].set(0)

    # -- persistence -----------------------------------------------------
    def snapshot(
        self,
        *,
        fingerprint: str | None = None,
        meta: dict | None = None,
    ) -> dict:
        """JSON-serializable snapshot of the entries (oldest → newest).

        ``fingerprint`` should be :func:`profile_fingerprint` of the
        profile the masks were computed for; :meth:`load` uses it to
        refuse snapshots taken for a different application.  Counters are
        deliberately not persisted — a warm restart starts fresh stats.

        ``meta`` is an opaque JSON-serializable dict stored alongside the
        entries and returned by :meth:`load_with_meta` — the serving
        plane stamps it with the journal sequence / broker tick the
        snapshot covers so a warm restart knows where replay begins.
        """
        doc = {
            "version": SNAPSHOT_VERSION,
            "fingerprint": fingerprint,
            "rel_step": self.quantizer.rel_step,
            "entries": [
                {"key": [int(x) for x in k], "mask": [int(b) for b in v]}
                for k, v in self._entries.items()
            ],
        }
        if meta is not None:
            doc["meta"] = dict(meta)
        return doc

    def save(
        self,
        path,
        *,
        fingerprint: str | None = None,
        meta: dict | None = None,
    ) -> None:
        """Atomically write the snapshot to ``path``.

        The document is serialized to a temporary file in the same
        directory and ``os.replace``d over the target, so a crash (or a
        concurrent reader) can never observe a truncated snapshot —
        :meth:`load`'s guards then only ever see whole files.
        """
        path = pathlib.Path(path)
        payload = (
            json.dumps(self.snapshot(fingerprint=fingerprint, meta=meta))
            + "\n"
        )
        fd, tmp = tempfile.mkstemp(
            dir=path.parent or ".", prefix=f".{path.name}.", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def load(
        self,
        source,
        *,
        fingerprint: str | None = None,
        expected_n: int | None = None,
    ) -> int:
        """Warm-start from a snapshot ``dict`` or a JSON file path.

        Forgiving by design — a serving restart must never crash on a
        stale artifact, it just cold-starts: a missing/corrupt file, an
        unknown schema version, a quantizer ``rel_step`` mismatch (bins
        are not comparable) or a profile-fingerprint mismatch loads
        nothing; individually malformed or wrong-length entries are
        skipped.  Entries land through :meth:`store`, so a snapshot
        larger than ``capacity`` is evicted down to capacity keeping the
        newest (last-written) entries.  Returns the number of entries
        loaded.
        """
        loaded, _ = self.load_with_meta(
            source, fingerprint=fingerprint, expected_n=expected_n
        )
        return loaded

    def load_with_meta(
        self,
        source,
        *,
        fingerprint: str | None = None,
        expected_n: int | None = None,
    ) -> tuple[int, dict | None]:
        """:meth:`load`, also returning the snapshot's ``meta`` dict.

        ``meta`` is ``None`` whenever the snapshot was rejected (any of
        the cold-start guards fired) or carried no metadata — the caller
        can distinguish "warm with provenance" from "cold" in one call.
        """
        if isinstance(source, (str, pathlib.Path)):
            try:
                doc = json.loads(pathlib.Path(source).read_text())
            except (OSError, json.JSONDecodeError, UnicodeDecodeError):
                return 0, None
        else:
            doc = source
        if not isinstance(doc, dict) or doc.get("version") != SNAPSHOT_VERSION:
            return 0, None
        if fingerprint is not None and doc.get("fingerprint") != fingerprint:
            return 0, None
        try:
            rel = float(doc.get("rel_step"))
        except (TypeError, ValueError):
            return 0, None
        if not math.isclose(rel, self.quantizer.rel_step, rel_tol=1e-9):
            return 0, None
        entries = doc.get("entries")
        if not isinstance(entries, list):
            return 0, None
        loaded = 0
        for e in entries:
            try:
                key = tuple(int(x) for x in e["key"])
                mask = np.asarray(e["mask"], dtype=bool)
            except (TypeError, ValueError, KeyError):
                continue
            if mask.ndim != 1 or mask.size == 0:
                continue
            if expected_n is not None and mask.shape != (expected_n,):
                continue
            self.store(key, mask)
            loaded += 1
        meta = doc.get("meta")
        return loaded, (dict(meta) if isinstance(meta, dict) else None)

    @classmethod
    def from_snapshot(
        cls,
        source,
        *,
        fingerprint: str | None = None,
        quantizer: EnvQuantizer | None = None,
        capacity: int = 4096,
    ) -> "PlacementCache":
        """Construct and warm-start in one step (serving-restart path)."""
        cache = cls(quantizer, capacity=capacity)
        cache.load(source, fingerprint=fingerprint)
        return cache
