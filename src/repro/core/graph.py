"""Weighted consumption graphs (WCGs) — the paper's §4.2 data structure.

A WCG annotates every vertex with a 2-tuple ``<w_local(v), w_cloud(v)>``
(cost of executing the task on the weak tier vs. the strong tier) and every
edge with the communication cost paid only when the edge is *cut*, i.e. its
endpoints are placed on different tiers (Eq. 1 of the paper).

The canonical representation here is dense: a symmetric ``(n, n)`` adjacency
matrix of edge weights (0 == no edge) plus per-vertex cost vectors.  Dense
is the right layout for this framework because (i) the paper's graphs are
small-to-medium task graphs (|V| in the tens-to-thousands), (ii) the JAX
implementation of MCOP (``mcop.mcop_jax``) wants MXU/VPU-friendly matrix
ops, and (iii) merging vertices is a row/column add — O(n) — instead of
pointer surgery.

Builders are provided for every topology in the paper's Fig. 2 (linear,
loop, tree, mesh) plus random connected graphs for property tests, the
reconstructed 6-node worked example of §5.5, and the face-recognition call
tree of Fig. 12.

:class:`WCGBatch` is the array-native sibling: K environments' worth of
WCGs stacked into ``(k, m[, m])`` tensors sharing one static topology
(vertex count, labels, padding layout).  It is a registered JAX pytree, so
cost models can *build* it inside a jitted program and the batched solver
(`mcop.mcop_batch` / `mcop.solve_envs`) can consume it without any
per-environment Python graph objects on the hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import numpy as np

__all__ = [
    "WCG",
    "WCGBatch",
    "NonFiniteWeightError",
    "linear_graph",
    "loop_graph",
    "tree_graph",
    "mesh_graph",
    "random_wcg",
    "paper_example_graph",
    "face_recognition_graph",
    "TOPOLOGY_BUILDERS",
]


class NonFiniteWeightError(ValueError):
    """NaN/Inf detected in WCG weights or environment inputs.

    Corruption used to propagate silently into the solver (Stoer–Wagner
    happily partitions a NaN graph into garbage); now it is rejected at
    the first host boundary with the offending rows named, so the
    resilience layer can treat it as a transient failure and retry on
    clean inputs.  ``rows`` carries the offending batch-row indices.
    """

    def __init__(self, message: str, *, rows=()):
        super().__init__(message)
        self.rows = tuple(int(r) for r in rows)


@dataclasses.dataclass
class WCG:
    """Weighted consumption graph (paper §4.2).

    Attributes:
      w_local:  (n,) float64 — cost of executing vertex i on the local tier.
      w_cloud:  (n,) float64 — cost of executing vertex i on the remote tier.
      adj:      (n, n) float64 symmetric, zero diagonal — communication cost
                charged iff the edge is cut.
      offloadable: (n,) bool — False marks the paper's *unoffloadable* tasks
                (camera/GPS/UI-pinned; here: ingest/sampler/host-pinned
                stages).  At least one vertex must be unoffloadable to act
                as the local anchor; builders default vertex 0.
      names:    optional vertex labels for reporting.
    """

    w_local: np.ndarray
    w_cloud: np.ndarray
    adj: np.ndarray
    offloadable: np.ndarray
    names: list[str] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        self.w_local = np.asarray(self.w_local, dtype=np.float64)
        self.w_cloud = np.asarray(self.w_cloud, dtype=np.float64)
        self.adj = np.asarray(self.adj, dtype=np.float64)
        self.offloadable = np.asarray(self.offloadable, dtype=bool)
        n = self.n
        if self.adj.shape != (n, n):
            raise ValueError(f"adj must be ({n},{n}), got {self.adj.shape}")
        if self.w_cloud.shape != (n,) or self.offloadable.shape != (n,):
            raise ValueError("vertex attribute shape mismatch")
        if not np.allclose(self.adj, self.adj.T):
            raise ValueError("adj must be symmetric (undirected comm costs)")
        if np.any(np.diag(self.adj) != 0):
            raise ValueError("adj diagonal must be zero")
        if np.any(self.adj < 0):
            raise ValueError("communication costs must be non-negative")
        if not self.names:
            self.names = [f"v{i}" for i in range(n)]

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.w_local.shape[0])

    @property
    def num_edges(self) -> int:
        return int(np.count_nonzero(np.triu(self.adj)))

    @property
    def local_cost_total(self) -> float:
        """C_local = Σ_v w_local(v) — the paper's no-offloading cost."""
        return float(self.w_local.sum())

    @property
    def gains(self) -> np.ndarray:
        """Per-vertex offloading gain w_local − w_cloud (paper Eq. 10 term)."""
        return self.w_local - self.w_cloud

    # ------------------------------------------------------------------
    def total_cost(self, local_mask: np.ndarray) -> float:
        """Eq. 2: total cost of the placement ``I`` (True == run locally).

        Args:
          local_mask: (n,) bool — True places the vertex on the local tier.
        Returns:
          float — Σ node costs + Σ cut-edge costs (cut edges are those with
          exactly one endpoint local).

        The comm term reduces row-by-row (``sum(axis=-1)`` then ``sum()``)
        so this scalar evaluation is bit-identical to one row of the
        vectorized :meth:`WCGBatch.total_cost` / :meth:`WCGBatch.price_batch`
        on an unpadded batch — the parity contract the fused pricing
        pipeline (``repro.core.pricing``) asserts against.
        """
        local_mask = np.asarray(local_mask, dtype=bool)
        if local_mask.shape != (self.n,):
            raise ValueError("placement mask shape mismatch")
        node_cost = np.where(local_mask, self.w_local, self.w_cloud).sum()
        cut = local_mask[:, None] != local_mask[None, :]
        # each edge counted twice (symmetric adj), hence /2
        comm_cost = float((self.adj * cut).sum(axis=-1).sum()) / 2.0
        return float(node_cost) + comm_cost

    def validate_placement(self, local_mask: np.ndarray) -> None:
        local_mask = np.asarray(local_mask, dtype=bool)
        if np.any(~local_mask & ~self.offloadable):
            bad = [self.names[i] for i in np.nonzero(~local_mask & ~self.offloadable)[0]]
            raise ValueError(f"unoffloadable vertices placed on cloud tier: {bad}")

    def with_bandwidth_scale(self, scale: float) -> "WCG":
        """Return a WCG whose comm costs are scaled by 1/scale.

        Edge weights are ``bytes / B`` (Eq. 1), so a bandwidth change
        B → scale·B rescales every edge by 1/scale.  Used by the adaptive
        re-partitioning loop (paper Fig. 1) without re-profiling.
        """
        if scale <= 0:
            raise ValueError("bandwidth scale must be positive")
        return WCG(
            w_local=self.w_local.copy(),
            w_cloud=self.w_cloud.copy(),
            adj=self.adj / scale,
            offloadable=self.offloadable.copy(),
            names=list(self.names),
        )

    def with_speedup(self, new_f: float, old_f: float = 1.0) -> "WCG":
        """Rescale cloud costs for a new speedup factor F (T_cloud = T_local/F)."""
        if new_f <= 0:
            raise ValueError("speedup factor must be positive")
        return WCG(
            w_local=self.w_local.copy(),
            w_cloud=self.w_cloud * (old_f / new_f),
            offloadable=self.offloadable.copy(),
            adj=self.adj.copy(),
            names=list(self.names),
        )

    def copy(self) -> "WCG":
        return WCG(
            w_local=self.w_local.copy(),
            w_cloud=self.w_cloud.copy(),
            adj=self.adj.copy(),
            offloadable=self.offloadable.copy(),
            names=list(self.names),
        )


# ----------------------------------------------------------------------
# WCGBatch — K environments of one topology as stacked tensors.
# ----------------------------------------------------------------------


@dataclasses.dataclass
class WCGBatch:
    """K stacked WCGs over one static topology (the array-native WCG).

    Attributes:
      w_local:  (k, m) per-graph local execution costs.
      w_cloud:  (k, m) per-graph remote execution costs.
      adj:      (k, m, m) symmetric per-graph communication costs.
      pinned:   (k, m) bool — True marks unoffloadable vertices AND
                padding (padded vertices carry zero weights/edges, so the
                solver's anchor fold absorbs them for free).
      n_valid:  static per-graph true vertex counts (≤ m); padding lives
                in columns [n_valid[i], m).
      names:    shared vertex labels of the topology ('' == anonymous).

    Arrays may be numpy (host construction / pricing, float64) or JAX
    (inside a jitted build+solve program).  The class is a registered
    pytree whose static leaves are ``(n_valid, names)``, so it crosses
    ``jax.jit`` boundaries; validation is skipped for traced leaves.
    """

    w_local: Any
    w_cloud: Any
    adj: Any
    pinned: Any
    n_valid: tuple[int, ...] = ()
    names: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        self.n_valid = tuple(int(n) for n in self.n_valid)
        self.names = tuple(self.names)
        if not all(hasattr(a, "shape") for a in
                   (self.w_local, self.w_cloud, self.adj, self.pinned)):
            return  # pytree unflatten with placeholder leaves
        k, m = self.w_local.shape
        if not self.n_valid:
            self.n_valid = (m,) * k
        if len(self.n_valid) != k or any(not 0 < n <= m for n in self.n_valid):
            raise ValueError(f"n_valid {self.n_valid} inconsistent with (k={k}, m={m})")
        if self.adj.shape != (k, m, m):
            raise ValueError(f"adj must be ({k},{m},{m}), got {self.adj.shape}")
        if self.w_cloud.shape != (k, m) or self.pinned.shape != (k, m):
            raise ValueError("batch attribute shape mismatch")

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.n_valid)

    @property
    def k(self) -> int:
        return len(self.n_valid)

    @property
    def m(self) -> int:
        return int(self.w_local.shape[1])

    def validate_finite(self) -> None:
        """Reject NaN/Inf weights, naming the offending batch rows.

        Host-only (a no-op for traced/device leaves): the cheap aggregate
        probe runs on every call, the per-row scan only on failure.
        Raises :class:`NonFiniteWeightError`.
        """
        arrays = (self.w_local, self.w_cloud, self.adj)
        if not all(isinstance(a, np.ndarray) for a in arrays):
            return
        probe = (
            float(self.w_local.sum())
            + float(self.w_cloud.sum())
            + float(self.adj.sum())
        )
        if np.isfinite(probe):
            return
        k = int(self.w_local.shape[0])
        bad = ~(
            np.isfinite(self.w_local).all(axis=-1)
            & np.isfinite(self.w_cloud).all(axis=-1)
            & np.isfinite(self.adj.reshape(k, -1)).all(axis=-1)
        )
        rows = np.nonzero(bad)[0]
        shown = ", ".join(str(int(r)) for r in rows[:8])
        more = "" if rows.size <= 8 else f" (+{rows.size - 8} more)"
        raise NonFiniteWeightError(
            f"non-finite WCG weights in batch row(s) {shown}{more}; "
            "rejecting before the solver partitions garbage",
            rows=rows,
        )

    # ------------------------------------------------------------------
    @classmethod
    def pack(
        cls,
        w_local: np.ndarray,
        w_cloud: np.ndarray,
        adj: np.ndarray,
        offloadable: np.ndarray,
        *,
        m: int | None = None,
        names: Sequence[str] = (),
        dtype=np.float64,
    ) -> "WCGBatch":
        """Stack already-batched ``(k, n[, n])`` arrays, zero-padding to
        ``m`` vertices (padding is pinned with zero weights/edges).

        Rejects NaN/Inf weights (:class:`NonFiniteWeightError`) — the
        host pack is the first boundary corruption can be named at.
        """
        w_local = np.asarray(w_local, dtype)
        k, n = w_local.shape
        m = n if m is None else int(m)
        if m < n:
            raise ValueError(f"pad target m={m} smaller than n={n}")
        wl = np.zeros((k, m), dtype)
        wc = np.zeros((k, m), dtype)
        a = np.zeros((k, m, m), dtype)
        pin = np.ones((k, m), dtype=bool)
        wl[:, :n] = w_local
        wc[:, :n] = w_cloud
        a[:, :n, :n] = adj
        pin[:, :n] = ~np.asarray(offloadable, dtype=bool)
        batch = cls(wl, wc, a, pin, n_valid=(n,) * k, names=tuple(names))
        batch.validate_finite()
        return batch

    @classmethod
    def from_wcgs(
        cls,
        graphs: Sequence[WCG],
        *,
        m: int | None = None,
        dtype=np.float64,
    ) -> "WCGBatch":
        """Pad a list of WCGs into one batch (generalized bucket packing).

        Graphs may differ in size and pinned sets; ``names`` are kept only
        when every graph shares one labelled topology.  Round-trips with
        :meth:`to_wcgs` exactly (offloadability included).
        """
        graphs = list(graphs)
        if not graphs:
            raise ValueError("cannot batch zero graphs")
        sizes = [g.n for g in graphs]
        m = max(sizes) if m is None else int(m)
        if m < max(sizes):
            raise ValueError(f"pad target m={m} smaller than largest graph {max(sizes)}")
        k = len(graphs)
        wl = np.zeros((k, m), dtype)
        wc = np.zeros((k, m), dtype)
        a = np.zeros((k, m, m), dtype)
        pin = np.ones((k, m), dtype=bool)
        for i, g in enumerate(graphs):
            n = g.n
            wl[i, :n] = g.w_local
            wc[i, :n] = g.w_cloud
            a[i, :n, :n] = g.adj
            pin[i, :n] = ~g.offloadable
        names = tuple(graphs[0].names)
        if any(tuple(g.names) != names for g in graphs[1:]):
            names = ()
        return cls(wl, wc, a, pin, n_valid=tuple(sizes), names=names)

    # ------------------------------------------------------------------
    def wcg(self, i: int) -> WCG:
        """Materialize graph ``i`` as a plain :class:`WCG` (crops padding)."""
        n = self.n_valid[i]
        names = list(self.names[:n]) if len(self.names) >= n else []
        return WCG(
            w_local=np.array(self.w_local[i, :n], dtype=np.float64),
            w_cloud=np.array(self.w_cloud[i, :n], dtype=np.float64),
            adj=np.array(self.adj[i, :n, :n], dtype=np.float64),
            offloadable=~np.asarray(self.pinned[i, :n], dtype=bool),
            names=names,
        )

    def to_wcgs(self) -> list[WCG]:
        return [self.wcg(i) for i in range(self.k)]

    def anchored_pinned(self) -> np.ndarray:
        """Solver-facing pinned mask: a graph with no unoffloadable vertex
        is anchored at its vertex 0, matching ``mcop_reference`` (padding
        alone must not steal the anchor)."""
        pin = np.asarray(self.pinned, dtype=bool).copy()
        for i, n in enumerate(self.n_valid):
            if not pin[i, :n].any():
                pin[i, 0] = True
        return pin

    def total_cost(self, local_masks: np.ndarray) -> np.ndarray:
        """Vectorized Eq. 2 over the batch.

        Args:
          local_masks: (k, m) bool — one placement per graph; padding
            columns must be masked local (True).  Padded vertices carry
            zero weights and edges, so they contribute exactly 0.0.
        Returns:
          (k,) float — row ``i`` equals ``self.wcg(i).total_cost(mask_i)``;
          *bit*-identical when the batch is unpadded (``m == n_valid[i]``),
          because both paths reduce the comm term row-by-row in the same
          order (see :meth:`WCG.total_cost`).
        """
        masks = np.asarray(local_masks, dtype=bool)
        if masks.shape != self.w_local.shape:
            raise ValueError("placement mask batch shape mismatch")
        node = np.where(masks, self.w_local, self.w_cloud).sum(axis=-1)
        cut = masks[:, :, None] != masks[:, None, :]
        comm = (np.asarray(self.adj) * cut).sum(axis=-1).sum(axis=-1) / 2.0
        return node + comm

    def price_batch(
        self, local_masks: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized pricing of K placements: Eq. 2 plus the §7.1 baselines.

        One call replaces the three per-graph evaluations the adaptive
        loop's telemetry used to make per event (``total_cost`` of the
        current placement, the no-offloading cost, the full-offloading
        cost) — the array-native ``_emit``.

        Args:
          local_masks: (k, m) bool — the placement to price per graph
            (padding columns True).
        Returns:
          ``(partial, no_offload, full_offload)`` — three (k,) float
          arrays:

          * ``partial[i]``      = ``wcg(i).total_cost(local_masks[i])``
          * ``no_offload[i]``   = cost of running everything locally
            (Σ w_local; the all-True placement has zero cut edges)
          * ``full_offload[i]`` = cost of offloading every offloadable
            vertex (the placement mask is exactly ``pinned``)

          On an unpadded batch every number is bit-identical to the
          scalar path (``g.total_cost`` / ``baselines.no_offloading`` /
          ``baselines.full_offloading``) — asserted by the pricing
          parity suite.
        """
        partial = self.total_cost(local_masks)
        # all-local: np.where over an all-True mask sums w_local verbatim
        # and the cut matrix is empty, so Σ w_local IS the scalar number
        no_offload = np.asarray(self.w_local).sum(axis=-1)
        full_offload = self.total_cost(np.asarray(self.pinned, dtype=bool))
        return partial, no_offload, full_offload


jax.tree_util.register_pytree_node(
    WCGBatch,
    lambda b: ((b.w_local, b.w_cloud, b.adj, b.pinned), (b.n_valid, b.names)),
    lambda aux, ch: WCGBatch(*ch, n_valid=aux[0], names=aux[1]),
)


# ----------------------------------------------------------------------
# Topology builders (paper Fig. 2)
# ----------------------------------------------------------------------


def _costs_from_times(
    t_local: np.ndarray, speedup: float
) -> tuple[np.ndarray, np.ndarray]:
    t_local = np.asarray(t_local, dtype=np.float64)
    return t_local, t_local / speedup


def linear_graph(
    n: int,
    *,
    t_local: Sequence[float] | None = None,
    edge_data: Sequence[float] | None = None,
    speedup: float = 2.0,
    bandwidth: float = 1.0,
    rng: np.random.Generator | None = None,
) -> WCG:
    """Fig. 2(b): a sequential chain v0 → v1 → … → v{n-1}."""
    rng = rng or np.random.default_rng(0)
    if t_local is None:
        t_local = rng.uniform(1.0, 10.0, size=n)
    if edge_data is None:
        edge_data = rng.uniform(0.5, 5.0, size=n - 1)
    w_local, w_cloud = _costs_from_times(np.asarray(t_local), speedup)
    adj = np.zeros((n, n))
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = edge_data[i] / bandwidth
    offloadable = np.ones(n, dtype=bool)
    offloadable[0] = False  # entry task pinned to the device
    return WCG(w_local, w_cloud, adj, offloadable)


def loop_graph(
    n: int,
    *,
    speedup: float = 2.0,
    bandwidth: float = 1.0,
    rng: np.random.Generator | None = None,
) -> WCG:
    """Fig. 2(c): a cycle — iterative/online-social style applications."""
    rng = rng or np.random.default_rng(0)
    g = linear_graph(n, speedup=speedup, bandwidth=bandwidth, rng=rng)
    back = rng.uniform(0.5, 5.0) / bandwidth
    g.adj[0, n - 1] = g.adj[n - 1, 0] = back
    return g


def tree_graph(
    n: int,
    *,
    branching: int = 2,
    speedup: float = 2.0,
    bandwidth: float = 1.0,
    rng: np.random.Generator | None = None,
) -> WCG:
    """Fig. 2(d): tree-rooted task hierarchy; root = application entry."""
    rng = rng or np.random.default_rng(0)
    t_local = rng.uniform(1.0, 10.0, size=n)
    w_local, w_cloud = _costs_from_times(t_local, speedup)
    adj = np.zeros((n, n))
    for child in range(1, n):
        parent = (child - 1) // branching
        w = rng.uniform(0.5, 5.0) / bandwidth
        adj[parent, child] = adj[child, parent] = w
    offloadable = np.ones(n, dtype=bool)
    offloadable[0] = False
    return WCG(w_local, w_cloud, adj, offloadable)


def mesh_graph(
    rows: int,
    cols: int,
    *,
    speedup: float = 2.0,
    bandwidth: float = 1.0,
    rng: np.random.Generator | None = None,
) -> WCG:
    """Fig. 2(e): lattice topology (e.g. the Java face-recognition mesh)."""
    rng = rng or np.random.default_rng(0)
    n = rows * cols
    t_local = rng.uniform(1.0, 10.0, size=n)
    w_local, w_cloud = _costs_from_times(t_local, speedup)
    adj = np.zeros((n, n))

    def idx(r: int, c: int) -> int:
        return r * cols + c

    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                w = rng.uniform(0.5, 5.0) / bandwidth
                adj[idx(r, c), idx(r, c + 1)] = adj[idx(r, c + 1), idx(r, c)] = w
            if r + 1 < rows:
                w = rng.uniform(0.5, 5.0) / bandwidth
                adj[idx(r, c), idx(r + 1, c)] = adj[idx(r + 1, c), idx(r, c)] = w
    offloadable = np.ones(n, dtype=bool)
    offloadable[0] = False
    return WCG(w_local, w_cloud, adj, offloadable)


def random_wcg(
    n: int,
    *,
    edge_prob: float = 0.4,
    speedup: float = 2.0,
    n_unoffloadable: int = 1,
    rng: np.random.Generator | None = None,
    integer_weights: bool = False,
) -> WCG:
    """Random connected WCG for property tests (arbitrary topology)."""
    rng = rng or np.random.default_rng(0)
    if integer_weights:
        t_local = rng.integers(0, 20, size=n).astype(np.float64)
    else:
        t_local = rng.uniform(0.0, 20.0, size=n)
    w_local, w_cloud = _costs_from_times(t_local, speedup)
    adj = np.zeros((n, n))
    # spanning chain through a random permutation keeps the graph connected
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        adj[a, b] = adj[b, a] = (
            float(rng.integers(0, 10)) if integer_weights else rng.uniform(0.0, 10.0)
        )
    extra = rng.random((n, n)) < edge_prob
    for i in range(n):
        for j in range(i + 1, n):
            if extra[i, j] and adj[i, j] == 0:
                adj[i, j] = adj[j, i] = (
                    float(rng.integers(0, 10))
                    if integer_weights
                    else rng.uniform(0.0, 10.0)
                )
    offloadable = np.ones(n, dtype=bool)
    pinned = rng.choice(n, size=max(1, min(n_unoffloadable, n - 1)), replace=False)
    offloadable[pinned] = False
    return WCG(w_local, w_cloud, adj, offloadable)


# ----------------------------------------------------------------------
# The paper's worked example (§5.5, Figs. 6–11) — reconstructed.
# ----------------------------------------------------------------------


def paper_example_graph() -> WCG:
    """The 6-vertex WCG of the paper's case study, reconstructed.

    The paper prints every phase's cut value, induced vertex ordering and
    itemized cut-edge sums (Figs. 6–10) but not the raw figure data.  The
    graph below is reconstructed from those constraints and reproduces the
    published run *exactly*:

      phase 1: order a,c,b,e,d,f;  t=f       cut = 45 − (15−5)  + 5        = 40
      phase 2: order a,c,b,e,{df}; t={df}    cut = 45 − (27−9)  + (1+3+4)  = 35
      phase 3: order a,c,b,{def};  t={def}   cut = 45 − (33−11) + (1+5)    = 29
      phase 4: order a,c,{bdef};   t={bdef}  cut = 45 − (42−14) + (1+4)    = 22  ← min
      phase 5: order a,{bcdef};    t={bcdef} cut = 45 − (45−15) + 12       = 27

    and the optimal partition {a,c} local / {b,d,e,f} cloud at cost 22
    (Fig. 11).  ``tests/test_paper_example.py`` asserts all of the above.
    """
    names = ["a", "b", "c", "d", "e", "f"]
    w_local = np.array([0.0, 9.0, 3.0, 12.0, 6.0, 15.0])
    w_cloud = np.array([0.0, 3.0, 1.0, 4.0, 2.0, 5.0])
    adj = np.zeros((6, 6))
    edges = {
        ("a", "b"): 3.0,
        ("a", "c"): 8.0,
        ("a", "f"): 1.0,
        ("b", "c"): 1.0,
        ("b", "d"): 3.0,
        ("b", "e"): 2.0,
        ("e", "f"): 4.0,
    }
    idx = {s: i for i, s in enumerate(names)}
    for (u, v), w in edges.items():
        adj[idx[u], idx[v]] = adj[idx[v], idx[u]] = w
    offloadable = np.array([False, True, True, True, True, True])
    return WCG(w_local, w_cloud, adj, offloadable, names=names)


def face_recognition_graph(
    *, speedup: float = 2.0, bandwidth_mbps: float = 1.0
) -> WCG:
    """Fig. 12: call tree of the Eigenface face-recognition app.

    Node times (ms, local) and edge transfer sizes (KB) follow the shape of
    the paper's profiled call graph: a main entry invoking image loading,
    training-set preparation, eigenface projection, and a checkAgainst
    matcher fan-out.  ``main`` and ``checkAgainst`` are unoffloadable, as
    in the paper's §7.2 experiment.
    """
    names = [
        "main",          # 0 (pinned)
        "loadImage",     # 1
        "buildMatrix",   # 2
        "computeEigen",  # 3
        "project",       # 4
        "checkAgainst",  # 5 (pinned)
        "distance",      # 6
        "rankMatches",   # 7
        "annotate",      # 8
    ]
    t_local = np.array([5.0, 40.0, 120.0, 400.0, 150.0, 20.0, 90.0, 30.0, 10.0])
    w_local = t_local
    w_cloud = t_local / speedup
    kb = {
        (0, 1): 60.0,
        (0, 5): 8.0,
        (1, 2): 900.0,
        (2, 3): 700.0,
        (3, 4): 120.0,
        (4, 5): 30.0,
        (5, 6): 25.0,
        (6, 7): 12.0,
        (7, 8): 6.0,
    }
    n = len(names)
    adj = np.zeros((n, n))
    for (u, v), size_kb in kb.items():
        # ms = KB / (MB/s) ≈ size_kb / (bandwidth_mbps * 1024) * 1000
        w = size_kb / (bandwidth_mbps * 1024.0) * 1000.0
        adj[u, v] = adj[v, u] = w
    offloadable = np.ones(n, dtype=bool)
    offloadable[0] = False
    offloadable[5] = False
    return WCG(w_local, w_cloud, adj, offloadable, names=names)


TOPOLOGY_BUILDERS: dict[str, Callable[..., WCG]] = {
    "linear": linear_graph,
    "loop": loop_graph,
    "tree": tree_graph,
    "mesh": lambda n, **kw: mesh_graph(max(2, int(np.sqrt(n))), max(2, int(np.ceil(n / max(2, int(np.sqrt(n)))))), **kw),
}
