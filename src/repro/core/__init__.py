"""Core: the paper's contribution — WCGs, MCOP, cost models, baselines."""

from repro.core.graph import (
    WCG,
    face_recognition_graph,
    linear_graph,
    loop_graph,
    mesh_graph,
    paper_example_graph,
    random_wcg,
    tree_graph,
)
from repro.core.mcop import (
    MCOPResult,
    PhaseRecord,
    mcop,
    mcop_batch,
    mcop_jax,
    mcop_reference,
)
from repro.core.placement_cache import (
    CacheStats,
    EnvQuantizer,
    PlacementCache,
    profile_fingerprint,
)
from repro.core.baselines import (
    PartitionResult,
    branch_and_bound,
    brute_force,
    chain_dp,
    full_offloading,
    maxflow_optimal,
    no_offloading,
)
from repro.core.cost_models import (
    AppProfile,
    CostModel,
    EnergyModel,
    Environment,
    ResponseTimeModel,
    WeightedModel,
    offloading_gain,
)
from repro.core.adaptive import AdaptationEvent, AdaptiveController, EnvironmentDrift

__all__ = [
    "WCG",
    "face_recognition_graph",
    "linear_graph",
    "loop_graph",
    "mesh_graph",
    "paper_example_graph",
    "random_wcg",
    "tree_graph",
    "MCOPResult",
    "PhaseRecord",
    "mcop",
    "mcop_batch",
    "mcop_jax",
    "mcop_reference",
    "CacheStats",
    "EnvQuantizer",
    "PlacementCache",
    "profile_fingerprint",
    "PartitionResult",
    "branch_and_bound",
    "brute_force",
    "chain_dp",
    "full_offloading",
    "maxflow_optimal",
    "no_offloading",
    "AppProfile",
    "CostModel",
    "EnergyModel",
    "Environment",
    "ResponseTimeModel",
    "WeightedModel",
    "offloading_gain",
    "AdaptationEvent",
    "AdaptiveController",
    "EnvironmentDrift",
]
