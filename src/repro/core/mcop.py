"""MCOP — the paper's Min-Cost Offloading Partitioning algorithm (§5).

Two implementations, one contract:

* :func:`mcop_reference` — a line-by-line transcription of the paper's
  Algorithms 1–3 (Merge / MinCut / MinCutPhase) in pure numpy.  It keeps a
  full per-phase trace (induced vertex orderings, cut-of-the-phase values,
  merged memberships) so tests can check the paper's §5.5 case study
  *exactly*, phase by phase.

* :func:`mcop_jax` — a dense, fully jittable JAX implementation built on
  ``lax.fori_loop``.  Vertices are never physically removed; merging is a
  masked row/column fold, membership is a boolean matrix, and the inner
  most-tightly-connected-vertex scan is a masked argmax.  Complexity is
  O(|V|³) dense work, which on the target hardware is VPU/MXU-friendly and
  lets the partitioner run *inside* a jitted training/serving loop — the
  paper's "real-time online algorithm" requirement (§3.1) without host
  round-trips.  For the graph sizes the paper studies (tens to a few
  thousand vertices) dense O(V³) easily beats the constant factors of
  pointer-chasing implementations.

Both return the minimum over phases of the paper's Eq. 10 cut value

    C_cut(A−t, t) = C_local − [w_local(t) − w_cloud(t)] + Σ_{v∈A∖t} w(e(t,v))

together with the induced placement (True = execute locally).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import WCG

__all__ = [
    "PhaseRecord",
    "MCOPResult",
    "mcop_reference",
    "mcop_jax",
    "mcop",
]

_NEG_INF = -1e30
_POS_INF = 1e30


@dataclasses.dataclass
class PhaseRecord:
    """Trace of one MinCutPhase run (paper Algorithm 3)."""

    order: list[str]          # induced ordering of current-graph nodes, by label
    s: str                    # second-to-last added
    t: str                    # last added
    cut_value: float          # Eq. 10 cut-of-the-phase
    cloud_members: frozenset  # original vertex indices inside t


@dataclasses.dataclass
class MCOPResult:
    min_cut: float
    local_mask: np.ndarray          # (n,) bool over original vertices
    phases: list[PhaseRecord]
    local_indices: tuple[int, ...] = ()
    cloud_indices: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        mask = np.asarray(self.local_mask, dtype=bool)
        self.local_indices = tuple(int(i) for i in np.nonzero(mask)[0])
        self.cloud_indices = tuple(int(i) for i in np.nonzero(~mask)[0])


# ======================================================================
# Reference implementation — Algorithms 1, 2, 3 verbatim.
# ======================================================================


class _MutableGraph:
    """Dense mutable view used by the reference implementation.

    ``members[i]`` is the set of *original* vertex indices coalesced into
    current vertex ``i``; Algorithm 1's Merge adds edge weights and node
    weight tuples.
    """

    def __init__(self, g: WCG):
        self.adj = g.adj.copy()
        self.w_local = g.w_local.copy()
        self.w_cloud = g.w_cloud.copy()
        self.alive = np.ones(g.n, dtype=bool)
        self.members: list[set[int]] = [{i} for i in range(g.n)]
        self.names = list(g.names)

    @property
    def alive_indices(self) -> np.ndarray:
        return np.nonzero(self.alive)[0]

    def label(self, i: int) -> str:
        return "{" + "".join(sorted(self.names[j] for j in self.members[i])) + "}" \
            if len(self.members[i]) > 1 else self.names[next(iter(self.members[i]))]

    def merge(self, s: int, t: int) -> int:
        """Algorithm 1: fold t into s.  Returns the surviving index (s)."""
        if s == t or not (self.alive[s] and self.alive[t]):
            raise ValueError("merge requires two distinct alive vertices")
        # multiple edges resolved by adding edge weights (Alg. 1, line 4)
        self.adj[s, :] += self.adj[t, :]
        self.adj[:, s] += self.adj[:, t]
        self.adj[s, s] = 0.0
        self.adj[t, :] = 0.0
        self.adj[:, t] = 0.0
        # node weights resolved by adding tuples (Alg. 1, lines 5–7)
        self.w_local[s] += self.w_local[t]
        self.w_cloud[s] += self.w_cloud[t]
        self.w_local[t] = self.w_cloud[t] = 0.0
        self.members[s] |= self.members[t]
        self.members[t] = set()
        self.alive[t] = False
        return s


def _min_cut_phase(
    g: _MutableGraph, start: int, c_local_total: float
) -> tuple[float, int, int, list[str]]:
    """Algorithm 3: one phase.  Returns (cut value, s, t, induced order).

    Grows A from ``start``; at every step absorbs the most tightly
    connected vertex, where tightness is the paper's
    Δ(v) = w(e(A, v)) − [w_local(v) − w_cloud(v)].
    """
    alive = g.alive_indices
    in_a = np.zeros(g.adj.shape[0], dtype=bool)
    in_a[start] = True
    conn = g.adj[start].copy()  # w(e(A, v)) maintained incrementally
    order = [g.label(start)]
    added: list[int] = [start]
    gains = g.w_local - g.w_cloud

    for _ in range(len(alive) - 1):
        # strict '<' in Algorithm 3 line 11 → first maximum wins ties,
        # which reproduces the paper's induced orderings.
        best, best_v = _NEG_INF, -1
        for v in alive:
            if not in_a[v]:
                delta = conn[v] - gains[v]
                if best < delta:
                    best, best_v = delta, v
        in_a[best_v] = True
        conn += g.adj[best_v]
        order.append(g.label(best_v))
        added.append(best_v)

    t = added[-1]
    s = added[-2] if len(added) >= 2 else added[-1]
    # Eq. 10: Σ_{v∈A∖t} w(e(t, v)) is exactly conn over the full graph row.
    comm = float(g.adj[t, g.alive].sum())
    cut = c_local_total - float(gains[t]) + comm
    return cut, s, t, order


def mcop_reference(g: WCG, *, start: int | None = None) -> MCOPResult:
    """Algorithm 2 (MinCut): merge unoffloadables, run |V|−1 phases."""
    work = _MutableGraph(g)
    c_local_total = float(g.w_local.sum())  # invariant under merging

    # Step 1 (§5.1): merge all unoffloadable vertices into the source.
    pinned = np.nonzero(~g.offloadable)[0]
    if pinned.size == 0:
        source = 0 if start is None else start
    else:
        source = int(pinned[0])
        for other in pinned[1:]:
            work.merge(source, int(other))
    if start is not None:
        source = start  # test hook: explicit anchor

    best_cut = _POS_INF
    best_members: frozenset = frozenset()
    phases: list[PhaseRecord] = []

    # Step 2: coarse partitioning, |V|−1 phases (Algorithm 2 lines 6–13).
    while work.alive.sum() > 1:
        cut, s, t, order = _min_cut_phase(work, source, c_local_total)
        phases.append(
            PhaseRecord(
                order=order,
                s=work.label(s),
                t=work.label(t),
                cut_value=cut,
                cloud_members=frozenset(work.members[t]),
            )
        )
        if cut < best_cut:
            best_cut = cut
            best_members = frozenset(work.members[t])
        survivor = work.merge(s, t)
        if t == source:   # keep the anchor alive under merging
            source = survivor

    local_mask = np.ones(g.n, dtype=bool)
    for i in best_members:
        local_mask[i] = False
    return MCOPResult(min_cut=float(best_cut), local_mask=local_mask, phases=phases)


# ======================================================================
# JAX implementation — dense masked Stoer–Wagner with node-cost tuples.
# ======================================================================


def _fold_pinned(adj, w_local, w_cloud, pinned):
    """Merge every pinned vertex into the first pinned one (masked fold)."""
    n = adj.shape[0]
    any_pinned = jnp.any(pinned)
    src = jnp.where(any_pinned, jnp.argmax(pinned), 0)
    others = pinned & (jnp.arange(n) != src)

    fold_row = (adj * others[:, None]).sum(axis=0)        # Σ rows being folded
    keep = ~others
    adj2 = adj * keep[:, None] * keep[None, :]            # drop folded rows/cols
    add = fold_row * keep
    adj2 = adj2.at[src, :].add(add)
    adj2 = adj2.at[:, src].add(add)
    adj2 = adj2.at[src, src].set(0.0)

    wl = jnp.where(others, 0.0, w_local).at[src].set((w_local * pinned).sum()
                                                     + w_local[src] * (~pinned[src]))
    wc = jnp.where(others, 0.0, w_cloud).at[src].set((w_cloud * pinned).sum()
                                                     + w_cloud[src] * (~pinned[src]))

    alive = ~others
    members = jnp.eye(n, dtype=bool)
    members = members.at[src, :].set(members[src] | pinned)
    return adj2, wl, wc, alive, members, src


@functools.partial(jax.jit, static_argnames=())
def _mcop_jax_impl(adj, w_local, w_cloud, pinned):
    n = adj.shape[0]
    c_local_total = w_local.sum()
    adj, w_local, w_cloud, alive, members, src = _fold_pinned(
        adj, w_local, w_cloud, pinned
    )

    def phase_body(_, carry):
        adj, wl, wc, alive, members, src, best_cut, best_cloud = carry
        n_alive = alive.sum()
        valid_phase = n_alive >= 2
        gains = wl - wc

        # ---- inner MTCV scan (Algorithm 3) ---------------------------
        def add_body(_, inner):
            in_a, conn, s_reg, t_reg = inner
            cand = alive & ~in_a
            scores = jnp.where(cand, conn - gains, _NEG_INF)
            v = jnp.argmax(scores)
            do = cand.any()
            in_a = jnp.where(do, in_a | (jnp.arange(n) == v), in_a)
            conn = jnp.where(do, conn + adj[v], conn)
            s_reg = jnp.where(do, t_reg, s_reg)
            t_reg = jnp.where(do, v, t_reg)
            return in_a, conn, s_reg, t_reg

        in_a0 = alive & (jnp.arange(n) == src)
        inner0 = (in_a0, adj[src], src, src)
        _, _, s_reg, t_reg = jax.lax.fori_loop(0, n - 1, add_body, inner0)

        # ---- Eq. 10 cut-of-the-phase ---------------------------------
        comm = (adj[t_reg] * alive).sum()
        cut = c_local_total - gains[t_reg] + comm
        cut = jnp.where(valid_phase, cut, _POS_INF)

        improved = cut < best_cut
        best_cut = jnp.where(improved, cut, best_cut)
        best_cloud = jnp.where(improved, members[t_reg], best_cloud)

        # ---- Algorithm 1 merge of (s, t), masked ---------------------
        do_merge = valid_phase & (s_reg != t_reg)

        def merged(args):
            adj, wl, wc, alive, members = args
            t_row = adj[t_reg]
            adj2 = adj.at[s_reg, :].add(t_row)
            adj2 = adj2.at[:, s_reg].add(t_row)
            adj2 = adj2.at[s_reg, s_reg].set(0.0)
            tmask = jnp.arange(n) == t_reg
            adj2 = adj2 * (~tmask[:, None]) * (~tmask[None, :])
            wl2 = wl.at[s_reg].add(wl[t_reg]).at[t_reg].set(0.0)
            wc2 = wc.at[s_reg].add(wc[t_reg]).at[t_reg].set(0.0)
            alive2 = alive & ~tmask
            members2 = members.at[s_reg, :].set(members[s_reg] | members[t_reg])
            members2 = members2.at[t_reg, :].set(False)
            return adj2, wl2, wc2, alive2, members2

        adj, wl, wc, alive, members = jax.lax.cond(
            do_merge, merged, lambda a: a, (adj, wl, wc, alive, members)
        )
        # anchor survives: if t was the source, s is the survivor
        src = jnp.where(do_merge & (t_reg == src), s_reg, src)
        return adj, wl, wc, alive, members, src, best_cut, best_cloud

    best0 = jnp.asarray(_POS_INF, adj.dtype)
    cloud0 = jnp.zeros(n, dtype=bool)
    carry0 = (adj, w_local, w_cloud, alive, members, src, best0, cloud0)
    out = jax.lax.fori_loop(0, n - 1, phase_body, carry0)
    best_cut, best_cloud = out[6], out[7]
    return best_cut, ~best_cloud  # local mask


def mcop_jax(g: WCG) -> MCOPResult:
    """Jittable MCOP.  Semantics match :func:`mcop_reference`."""
    cut, local = _mcop_jax_impl(
        jnp.asarray(g.adj, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32),
        jnp.asarray(g.w_local),
        jnp.asarray(g.w_cloud),
        jnp.asarray(~g.offloadable),
    )
    return MCOPResult(
        min_cut=float(cut), local_mask=np.asarray(local), phases=[]
    )


def mcop(g: WCG, *, backend: str = "reference") -> MCOPResult:
    """Front door used by the rest of the framework."""
    if backend == "reference":
        return mcop_reference(g)
    if backend == "jax":
        return mcop_jax(g)
    raise ValueError(f"unknown MCOP backend: {backend!r}")
