"""MCOP — the paper's Min-Cost Offloading Partitioning algorithm (§5).

All implementations share one contract: the minimum over phases of the
paper's Eq. 10 cut value

    C_cut(A−t, t) = C_local − [w_local(t) − w_cloud(t)] + Σ_{v∈A∖t} w(e(t,v))

together with the induced placement (True = execute locally).

Backend-selection story — when each wins:

* :func:`mcop_reference` (``backend="reference"``) — a line-by-line numpy
  transcription of Algorithms 1–3 (Merge / MinCut / MinCutPhase).  It keeps
  a full per-phase trace (induced vertex orderings, cut-of-the-phase
  values, merged memberships) so tests can check the paper's §5.5 case
  study *exactly*, phase by phase.  Use it for a single graph when you
  want the trace, f64 arithmetic, or are debugging; it is the semantic
  oracle everything else is tested against.

* :func:`mcop_jax` (``backend="jax"``) — a dense, fully jittable JAX
  implementation built on ``lax.fori_loop``.  Vertices are never
  physically removed; merging is a masked row/column fold, membership is a
  boolean matrix, and the inner most-tightly-connected-vertex scan is a
  masked argmax.  O(|V|³) dense work is VPU/MXU-friendly and lets the
  partitioner run *inside* a jitted training/serving loop — the paper's
  "real-time online algorithm" requirement (§3.1) without host
  round-trips.  Use it for one graph per call on-device.

* :func:`mcop_batch` — the throughput path.  Pads a heterogeneous list of
  graphs into static shape *buckets* (default 16/64/256 vertices) and
  ``vmap``s the jitted solver per bucket, so N environment points or N
  concurrent requests compile to ONE XLA program per bucket rather than N
  traces, and execute as one dispatch.  Amortizes dispatch overhead and
  keeps the batch resident on-device; this is what
  ``AdaptiveController.sweep`` and the placement tier sweep call.

* ``mcop_batch(..., backend="pallas")`` — same bucketing, but each bucket
  runs ``repro.kernels.mcop_phase.mcop_stoer_wagner_kernel``: the full
  |V|−1-phase solve (merges included) inside one Pallas kernel with a
  grid dimension over the batch, so the adjacency is loaded HBM→VMEM once
  per solve.  Wins on TPU where the phase loop is bandwidth-bound on
  adjacency row reads; on CPU it falls back to interpret mode (correct
  but slow — benchmark numbers there are indicative only).

* :func:`mcop_batch` also accepts a :class:`~repro.core.graph.WCGBatch`
  directly — consumers that already hold stacked tensors (the cost
  models' ``build_batch``, the placement tier sweep, the broker's
  per-bucket flush) skip the per-graph Python packing entirely.

* :func:`solve_envs` — the fully fused environment→placement pipeline.
  Builds the K WCGs *and* runs Stoer–Wagner inside ONE jitted program per
  (cost model, shape bucket): the paper's Fig.-1 re-partitioning loop
  under a drifting environment becomes a single device dispatch with six
  scalars per environment crossing the host boundary, instead of K
  Python graph constructions followed by a packed solve.

Padding semantics: padded vertices carry zero weights, zero edges, and
are marked *pinned*, so the anchor fold absorbs them with no effect on
any phase cut; graphs with no unoffloadable vertex are anchored at vertex
0, matching :func:`mcop_reference`.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import WCG, WCGBatch

__all__ = [
    "PhaseRecord",
    "MCOPResult",
    "mcop_reference",
    "mcop_jax",
    "mcop_batch",
    "solve_envs",
    "mcop",
    "DEFAULT_BUCKETS",
]

_NEG_INF = -1e30
_POS_INF = 1e30


@dataclasses.dataclass
class PhaseRecord:
    """Trace of one MinCutPhase run (paper Algorithm 3)."""

    order: list[str]          # induced ordering of current-graph nodes, by label
    s: str                    # second-to-last added
    t: str                    # last added
    cut_value: float          # Eq. 10 cut-of-the-phase
    cloud_members: frozenset  # original vertex indices inside t


@dataclasses.dataclass
class MCOPResult:
    min_cut: float
    local_mask: np.ndarray          # (n,) bool over original vertices
    phases: list[PhaseRecord]
    local_indices: tuple[int, ...] = ()
    cloud_indices: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        mask = np.asarray(self.local_mask, dtype=bool)
        self.local_indices = tuple(int(i) for i in np.nonzero(mask)[0])
        self.cloud_indices = tuple(int(i) for i in np.nonzero(~mask)[0])


# ======================================================================
# Reference implementation — Algorithms 1, 2, 3 verbatim.
# ======================================================================


class _MutableGraph:
    """Dense mutable view used by the reference implementation.

    ``members[i]`` is the set of *original* vertex indices coalesced into
    current vertex ``i``; Algorithm 1's Merge adds edge weights and node
    weight tuples.
    """

    def __init__(self, g: WCG):
        self.adj = g.adj.copy()
        self.w_local = g.w_local.copy()
        self.w_cloud = g.w_cloud.copy()
        self.alive = np.ones(g.n, dtype=bool)
        self.members: list[set[int]] = [{i} for i in range(g.n)]
        self.names = list(g.names)

    @property
    def alive_indices(self) -> np.ndarray:
        return np.nonzero(self.alive)[0]

    def label(self, i: int) -> str:
        return "{" + "".join(sorted(self.names[j] for j in self.members[i])) + "}" \
            if len(self.members[i]) > 1 else self.names[next(iter(self.members[i]))]

    def merge(self, s: int, t: int) -> int:
        """Algorithm 1: fold t into s.  Returns the surviving index (s)."""
        if s == t or not (self.alive[s] and self.alive[t]):
            raise ValueError("merge requires two distinct alive vertices")
        # multiple edges resolved by adding edge weights (Alg. 1, line 4)
        self.adj[s, :] += self.adj[t, :]
        self.adj[:, s] += self.adj[:, t]
        self.adj[s, s] = 0.0
        self.adj[t, :] = 0.0
        self.adj[:, t] = 0.0
        # node weights resolved by adding tuples (Alg. 1, lines 5–7)
        self.w_local[s] += self.w_local[t]
        self.w_cloud[s] += self.w_cloud[t]
        self.w_local[t] = self.w_cloud[t] = 0.0
        self.members[s] |= self.members[t]
        self.members[t] = set()
        self.alive[t] = False
        return s


def _min_cut_phase(
    g: _MutableGraph, start: int, c_local_total: float
) -> tuple[float, int, int, list[str]]:
    """Algorithm 3: one phase.  Returns (cut value, s, t, induced order).

    Grows A from ``start``; at every step absorbs the most tightly
    connected vertex, where tightness is the paper's
    Δ(v) = w(e(A, v)) − [w_local(v) − w_cloud(v)].
    """
    alive = g.alive_indices
    in_a = np.zeros(g.adj.shape[0], dtype=bool)
    in_a[start] = True
    conn = g.adj[start].copy()  # w(e(A, v)) maintained incrementally
    order = [g.label(start)]
    added: list[int] = [start]
    gains = g.w_local - g.w_cloud

    for _ in range(len(alive) - 1):
        # strict '<' in Algorithm 3 line 11 → first maximum wins ties,
        # which reproduces the paper's induced orderings.
        best, best_v = _NEG_INF, -1
        for v in alive:
            if not in_a[v]:
                delta = conn[v] - gains[v]
                if best < delta:
                    best, best_v = delta, v
        in_a[best_v] = True
        conn += g.adj[best_v]
        order.append(g.label(best_v))
        added.append(best_v)

    t = added[-1]
    s = added[-2] if len(added) >= 2 else added[-1]
    # Eq. 10: Σ_{v∈A∖t} w(e(t, v)) is exactly conn over the full graph row.
    comm = float(g.adj[t, g.alive].sum())
    cut = c_local_total - float(gains[t]) + comm
    return cut, s, t, order


def mcop_reference(g: WCG, *, start: int | None = None) -> MCOPResult:
    """Algorithm 2 (MinCut): merge unoffloadables, run |V|−1 phases."""
    work = _MutableGraph(g)
    c_local_total = float(g.w_local.sum())  # invariant under merging

    # Step 1 (§5.1): merge all unoffloadable vertices into the source.
    pinned = np.nonzero(~g.offloadable)[0]
    if pinned.size == 0:
        source = 0 if start is None else start
    else:
        source = int(pinned[0])
        for other in pinned[1:]:
            work.merge(source, int(other))
    if start is not None:
        source = start  # test hook: explicit anchor

    best_cut = _POS_INF
    best_members: frozenset = frozenset()
    phases: list[PhaseRecord] = []

    # Step 2: coarse partitioning, |V|−1 phases (Algorithm 2 lines 6–13).
    while work.alive.sum() > 1:
        cut, s, t, order = _min_cut_phase(work, source, c_local_total)
        phases.append(
            PhaseRecord(
                order=order,
                s=work.label(s),
                t=work.label(t),
                cut_value=cut,
                cloud_members=frozenset(work.members[t]),
            )
        )
        if cut < best_cut:
            best_cut = cut
            best_members = frozenset(work.members[t])
        survivor = work.merge(s, t)
        if t == source:   # keep the anchor alive under merging
            source = survivor

    local_mask = np.ones(g.n, dtype=bool)
    for i in best_members:
        local_mask[i] = False
    return MCOPResult(min_cut=float(best_cut), local_mask=local_mask, phases=phases)


# ======================================================================
# JAX implementation — dense masked Stoer–Wagner with node-cost tuples.
# ======================================================================


def _fold_pinned(adj, w_local, w_cloud, pinned):
    """Merge every pinned vertex into the first pinned one (masked fold)."""
    n = adj.shape[0]
    any_pinned = jnp.any(pinned)
    src = jnp.where(any_pinned, jnp.argmax(pinned), 0)
    others = pinned & (jnp.arange(n) != src)

    fold_row = (adj * others[:, None]).sum(axis=0)        # Σ rows being folded
    keep = ~others
    adj2 = adj * keep[:, None] * keep[None, :]            # drop folded rows/cols
    add = fold_row * keep
    adj2 = adj2.at[src, :].add(add)
    adj2 = adj2.at[:, src].add(add)
    adj2 = adj2.at[src, src].set(0.0)

    wl = jnp.where(others, 0.0, w_local).at[src].set((w_local * pinned).sum()
                                                     + w_local[src] * (~pinned[src]))
    wc = jnp.where(others, 0.0, w_cloud).at[src].set((w_cloud * pinned).sum()
                                                     + w_cloud[src] * (~pinned[src]))

    alive = ~others
    members = jnp.eye(n, dtype=bool)
    members = members.at[src, :].set(members[src] | pinned)
    return adj2, wl, wc, alive, members, src


@functools.partial(jax.jit, static_argnames=())
def _mcop_jax_impl(adj, w_local, w_cloud, pinned):
    n = adj.shape[0]
    c_local_total = w_local.sum()
    adj, w_local, w_cloud, alive, members, src = _fold_pinned(
        adj, w_local, w_cloud, pinned
    )

    def phase_body(_, carry):
        adj, wl, wc, alive, members, src, best_cut, best_cloud = carry
        n_alive = alive.sum()
        valid_phase = n_alive >= 2
        gains = wl - wc

        # ---- inner MTCV scan (Algorithm 3) ---------------------------
        def add_body(_, inner):
            in_a, conn, s_reg, t_reg = inner
            cand = alive & ~in_a
            scores = jnp.where(cand, conn - gains, _NEG_INF)
            v = jnp.argmax(scores)
            do = cand.any()
            in_a = jnp.where(do, in_a | (jnp.arange(n) == v), in_a)
            conn = jnp.where(do, conn + adj[v], conn)
            s_reg = jnp.where(do, t_reg, s_reg)
            t_reg = jnp.where(do, v, t_reg)
            return in_a, conn, s_reg, t_reg

        in_a0 = alive & (jnp.arange(n) == src)
        inner0 = (in_a0, adj[src], src, src)
        _, _, s_reg, t_reg = jax.lax.fori_loop(0, n - 1, add_body, inner0)

        # ---- Eq. 10 cut-of-the-phase ---------------------------------
        comm = (adj[t_reg] * alive).sum()
        cut = c_local_total - gains[t_reg] + comm
        cut = jnp.where(valid_phase, cut, _POS_INF)

        improved = cut < best_cut
        best_cut = jnp.where(improved, cut, best_cut)
        best_cloud = jnp.where(improved, members[t_reg], best_cloud)

        # ---- Algorithm 1 merge of (s, t), masked ---------------------
        do_merge = valid_phase & (s_reg != t_reg)

        def merged(args):
            adj, wl, wc, alive, members = args
            t_row = adj[t_reg]
            adj2 = adj.at[s_reg, :].add(t_row)
            adj2 = adj2.at[:, s_reg].add(t_row)
            adj2 = adj2.at[s_reg, s_reg].set(0.0)
            tmask = jnp.arange(n) == t_reg
            adj2 = adj2 * (~tmask[:, None]) * (~tmask[None, :])
            wl2 = wl.at[s_reg].add(wl[t_reg]).at[t_reg].set(0.0)
            wc2 = wc.at[s_reg].add(wc[t_reg]).at[t_reg].set(0.0)
            alive2 = alive & ~tmask
            members2 = members.at[s_reg, :].set(members[s_reg] | members[t_reg])
            members2 = members2.at[t_reg, :].set(False)
            return adj2, wl2, wc2, alive2, members2

        adj, wl, wc, alive, members = jax.lax.cond(
            do_merge, merged, lambda a: a, (adj, wl, wc, alive, members)
        )
        # anchor survives: if t was the source, s is the survivor
        src = jnp.where(do_merge & (t_reg == src), s_reg, src)
        return adj, wl, wc, alive, members, src, best_cut, best_cloud

    best0 = jnp.asarray(_POS_INF, adj.dtype)
    cloud0 = jnp.zeros(n, dtype=bool)
    carry0 = (adj, w_local, w_cloud, alive, members, src, best0, cloud0)
    out = jax.lax.fori_loop(0, n - 1, phase_body, carry0)
    best_cut, best_cloud = out[6], out[7]
    return best_cut, ~best_cloud  # local mask


def mcop_jax(g: WCG) -> MCOPResult:
    """Jittable MCOP.  Semantics match :func:`mcop_reference`."""
    cut, local = _mcop_jax_impl(
        jnp.asarray(g.adj, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32),
        jnp.asarray(g.w_local),
        jnp.asarray(g.w_cloud),
        jnp.asarray(~g.offloadable),
    )
    return MCOPResult(
        min_cut=float(cut), local_mask=np.asarray(local), phases=[]
    )


# ======================================================================
# Batched solver — static shape buckets, one XLA program per bucket.
# ======================================================================

DEFAULT_BUCKETS = (16, 64, 256)


@jax.jit
def _mcop_batch_impl(adj, w_local, w_cloud, pinned):
    """Batch-optimized single-graph solver (vmapped below).

    Same algorithm as :func:`_mcop_jax_impl`, restructured for throughput:

    * ``lax.while_loop`` instead of fixed-bound ``fori_loop`` for both the
      phase loop and the inner MTCV scan — JAX's while batching rule masks
      finished lanes automatically, so each graph does exactly
      Σ(n_alive−1) absorptions instead of (n−1)² and padded vertices cost
      nothing (they are folded into the anchor before the first phase).
    * merged-group membership is a per-vertex representative *label*
      (union-find with full path compression: every merge relabels in
      O(n)) instead of the O(n²) boolean membership matrix, which would
      otherwise dominate the while-loop carry at n ≳ 128.
    """
    n = adj.shape[0]
    c_local_total = w_local.sum()
    adj, w_local, w_cloud, alive, _, src = _fold_pinned(
        adj, w_local, w_cloud, pinned
    )
    idx = jnp.arange(n)
    label = jnp.where(pinned | ~alive, src, idx)

    def phase_body(carry):
        adj, wl, wc, alive, label, src, best_cut, best_cloud = carry
        n_alive = alive.sum()
        gains = wl - wc

        # ---- inner MTCV scan (Algorithm 3), exactly n_alive−1 steps ----
        def acond(inner):
            return inner[0] < n_alive - 1

        def abody(inner):
            i, in_a, conn, s_reg, t_reg = inner
            cand = alive & ~in_a
            scores = jnp.where(cand, conn - gains, _NEG_INF)
            v = jnp.argmax(scores)
            return (i + 1, in_a | (idx == v), conn + adj[v], t_reg, v)

        in_a0 = alive & (idx == src)
        _, _, _, s_reg, t_reg = jax.lax.while_loop(
            acond, abody, (jnp.int32(0), in_a0, adj[src], src, src)
        )

        # ---- Eq. 10 cut-of-the-phase (outer cond guarantees validity) --
        comm = (adj[t_reg] * alive).sum()
        cut = c_local_total - gains[t_reg] + comm
        cloud_t = label == t_reg
        improved = cut < best_cut
        best_cut = jnp.where(improved, cut, best_cut)
        best_cloud = jnp.where(improved, cloud_t, best_cloud)

        # ---- Algorithm 1 merge of (s, t) -------------------------------
        t_row = adj[t_reg]
        adj2 = adj.at[s_reg, :].add(t_row)
        adj2 = adj2.at[:, s_reg].add(t_row)
        adj2 = adj2.at[s_reg, s_reg].set(0.0)
        tmask = idx == t_reg
        adj2 = adj2 * (~tmask[:, None]) * (~tmask[None, :])
        wl2 = wl.at[s_reg].add(wl[t_reg]).at[t_reg].set(0.0)
        wc2 = wc.at[s_reg].add(wc[t_reg]).at[t_reg].set(0.0)
        alive2 = alive & ~tmask
        label2 = jnp.where(cloud_t, s_reg, label)
        src = jnp.where(t_reg == src, s_reg, src)
        return adj2, wl2, wc2, alive2, label2, src, best_cut, best_cloud

    def pcond(carry):
        return carry[3].sum() > 1  # alive count

    carry0 = (
        adj, w_local, w_cloud, alive, label, src,
        jnp.asarray(_POS_INF, adj.dtype), jnp.zeros(n, dtype=bool),
    )
    out = jax.lax.while_loop(pcond, phase_body, carry0)
    best_cut, best_cloud = out[6], out[7]
    return best_cut, ~best_cloud  # local mask


# vmap over the batch-optimized solver; jit caches one executable per
# (bucket_n, batch) shape pair.
_mcop_jax_batch = jax.jit(jax.vmap(_mcop_batch_impl))


def _bucket_size(n: int, buckets: Sequence[int]) -> int:
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    # beyond the largest bucket: 64-align so stragglers still share programs
    return int(-(-n // 64) * 64)


def _pack_bucket(
    graphs: Sequence[WCG], m: int, dtype
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Zero-pad a bucket of WCGs to m vertices in preallocated batch
    buffers; padding is pinned so the anchor fold absorbs it without
    touching any cut value (see module docstring)."""
    b = len(graphs)
    adj = np.zeros((b, m, m), dtype)
    wl = np.zeros((b, m), dtype)
    wc = np.zeros((b, m), dtype)
    pinned = np.ones((b, m), dtype=bool)
    for i, g in enumerate(graphs):
        n = g.n
        adj[i, :n, :n] = g.adj
        wl[i, :n] = g.w_local
        wc[i, :n] = g.w_cloud
        pinned[i, :n] = ~g.offloadable
        if not pinned[i, :n].any():
            pinned[i, 0] = True  # anchor at vertex 0, matching mcop_reference
    return adj, wl, wc, pinned


def _solver_dtype(backend: str):
    return (
        np.float64
        if backend == "jax" and jax.config.jax_enable_x64
        else np.float32
    )


def _dispatch_arrays(adj, wl, wc, pin, backend: str, interpret: bool | None):
    """One device dispatch over pre-packed (b, m[, m]) tensors."""
    if backend == "jax":
        return _mcop_jax_batch(adj, wl, wc, pin)
    # deferred: keep core importable without pulling kernel deps
    from repro.kernels.mcop_phase import mcop_stoer_wagner_kernel

    return mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=interpret)


def _solve_wcg_batch(
    batch: WCGBatch,
    *,
    backend: str,
    interpret: bool | None,
    mesh=None,
    tracer=None,
) -> list[MCOPResult]:
    """Array-native entry: a WCGBatch is already one packed bucket."""
    if backend == "reference":
        return [mcop_reference(g) for g in batch.to_wcgs()]
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown MCOP batch backend: {backend!r}")
    dtype = _solver_dtype(backend)
    from repro.core.mcop_shard import resolve_mesh  # deferred: cycle

    use_mesh = resolve_mesh(mesh)
    if use_mesh is not None:
        from repro.core.mcop_shard import sharded_dispatch_arrays

        cuts, masks = sharded_dispatch_arrays(
            np.asarray(batch.adj, dtype),
            np.asarray(batch.w_local, dtype),
            np.asarray(batch.w_cloud, dtype),
            batch.anchored_pinned(),
            mesh=use_mesh,
            backend=backend,
            interpret=interpret,
            tracer=tracer,
        )
    else:
        cuts, masks = _dispatch_arrays(
            jnp.asarray(np.asarray(batch.adj, dtype)),
            jnp.asarray(np.asarray(batch.w_local, dtype)),
            jnp.asarray(np.asarray(batch.w_cloud, dtype)),
            jnp.asarray(batch.anchored_pinned()),
            backend,
            interpret,
        )
        cuts, masks = jax.device_get((cuts, masks))  # one host sync
    return [
        MCOPResult(
            min_cut=float(cuts[i]),
            local_mask=masks[i, : batch.n_valid[i]].copy(),
            phases=[],
        )
        for i in range(batch.k)
    ]


def mcop_batch(
    graphs: Sequence[WCG] | WCGBatch,
    *,
    backend: str = "jax",
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    interpret: bool | None = None,
    mesh=None,
    tracer=None,
) -> list[MCOPResult]:
    """Solve many MCOP instances at once; results in input order.

    Args:
      graphs:   a sequence of :class:`~repro.core.graph.WCG` (arbitrary,
        heterogeneous sizes), or a single
        :class:`~repro.core.graph.WCGBatch` of K graphs padded to one
        static shape ``(k, m[, m])``.
      backend:  ``"jax"`` (bucketed ``vmap`` of the jitted solver),
        ``"pallas"`` (one grid-over-batch kernel call per bucket), or
        ``"reference"`` (loops the numpy oracle — testing/parity).
      buckets:  static shape buckets; each graph is zero-padded to the
        smallest bucket ≥ its vertex count and each bucket is ONE device
        dispatch.  Ignored for a ``WCGBatch`` (its padded shape *is* the
        bucket).
      interpret: Pallas-only — force interpret (True) / compiled (False)
        mode; ``None`` auto-detects (see ``kernels.ops.default_interpret``
        and the ``REPRO_PALLAS_INTERPRET`` env override).
      mesh:     solver-fleet routing (see ``repro.core.mcop_shard``):
        ``None`` auto-shards each bucket across the devices the process
        sees when there is more than one, ``False`` forces the
        single-device dispatch, a ``Mesh`` shards over exactly that
        fleet.  Results are bit-identical either way.
      tracer:   optional :class:`~repro.obs.trace.Tracer` — the sharded
        path records one ``solve.shard`` span per device (shard index,
        device count, row count).
    Returns:
      ``list[MCOPResult]`` in input order; ``result[i].local_mask`` is
      ``(n_i,)`` bool over graph ``i``'s ORIGINAL vertices (padding
      cropped), True = execute locally.  ``min_cut`` is the Eq.-10
      optimum in solver precision (f64 when x64 is enabled on the jax
      backend, f32 otherwise).

    The WCGBatch form is the array-native path for callers that hold
    stacked tensors already (cost-model ``build_batch`` output, the
    placement tier sweep, the broker's bucket flush): the per-graph
    packing pass (``_pack_bucket``) is skipped entirely.
    """
    if isinstance(graphs, WCGBatch):
        return _solve_wcg_batch(
            graphs, backend=backend, interpret=interpret, mesh=mesh,
            tracer=tracer,
        )
    graphs = list(graphs)
    if backend == "reference":
        return [mcop_reference(g) for g in graphs]
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown MCOP batch backend: {backend!r}")
    dtype = _solver_dtype(backend)

    by_bucket: dict[int, list[int]] = {}
    for i, g in enumerate(graphs):
        by_bucket.setdefault(_bucket_size(g.n, buckets), []).append(i)

    from repro.core.mcop_shard import resolve_mesh  # deferred: cycle

    use_mesh = resolve_mesh(mesh)
    results: list[MCOPResult | None] = [None] * len(graphs)
    for m, idxs in sorted(by_bucket.items()):
        packed = _pack_bucket([graphs[i] for i in idxs], m, dtype)
        if use_mesh is not None:
            from repro.core.mcop_shard import sharded_dispatch_arrays

            cuts, masks = sharded_dispatch_arrays(
                *packed,
                mesh=use_mesh,
                backend=backend,
                interpret=interpret,
                tracer=tracer,
            )
        else:
            adj, wl, wc, pin = (jnp.asarray(a) for a in packed)
            cuts, masks = _dispatch_arrays(adj, wl, wc, pin, backend, interpret)
            cuts, masks = jax.device_get((cuts, masks))  # one host sync
        for row, i in enumerate(idxs):
            results[i] = MCOPResult(
                min_cut=float(cuts[row]),
                local_mask=masks[row, : graphs[i].n].copy(),
                phases=[],
            )
    return results  # type: ignore[return-value]


# ======================================================================
# Fused environment→placement pipeline: build + solve, one XLA program.
# ======================================================================

# Compiled build+solve programs, keyed on (model class, model fingerprint,
# backend, interpret).  The fingerprint contract (see CostModel.fingerprint)
# guarantees equal-fingerprint models price identically, so reusing the
# first instance's closure is sound; jit itself re-specializes per input
# shape/dtype, so the bucket size never needs to appear in the key.  LRU
# bounded: a parametric-model sweep (e.g. many WeightedModel omegas) must
# not accumulate compiled executables for the process lifetime.
_FUSED_SOLVERS: OrderedDict = OrderedDict()
_FUSED_SOLVERS_CAP = 64


def _fused_solver(model, backend: str, interpret: bool | None, mesh=None):
    key = (type(model), model.fingerprint, backend, interpret, mesh)
    fn = _FUSED_SOLVERS.get(key)
    if fn is not None:
        _FUSED_SOLVERS.move_to_end(key)
    if fn is None:
        if backend == "pallas_fused":
            # VMEM-resident build+solve: the kernel constructs each
            # environment's WCG weights right before its phase loop runs
            # (no HBM round-trip for the (K, n, n) adjacency batch).
            from repro.kernels.mcop_phase import (
                FUSED_MODEL_KINDS,
                mcop_fused_solve_kernel,
            )

            kind = getattr(model, "name", None)
            if kind not in FUSED_MODEL_KINDS:
                raise ValueError(
                    f"backend='pallas_fused' implements the in-kernel weight "
                    f"build only for cost-model kinds {FUSED_MODEL_KINDS}; "
                    f"got model {model!r} (name={kind!r}) — use "
                    f"backend='pallas' for custom models"
                )
            omega = float(getattr(model, "omega", 0.5))

            def fused(t_local, data_in, data_out, pinned, env):
                env_mat = jnp.stack(list(env), axis=-1)  # EnvArrays → (k, 6)
                return mcop_fused_solve_kernel(
                    t_local, data_in, data_out, pinned, env_mat,
                    kind=kind, omega=omega, interpret=interpret,
                )

        else:

            def fused(t_local, data_in, data_out, pinned, env):
                wl, wc, adj = model.batch_weights(t_local, data_in, data_out, env)
                pin = jnp.broadcast_to(pinned[None, :], wl.shape)
                if backend == "jax":
                    return jax.vmap(_mcop_batch_impl)(adj, wl, wc, pin)
                from repro.kernels.mcop_phase import mcop_stoer_wagner_kernel

                return mcop_stoer_wagner_kernel(adj, wl, wc, pin, interpret=interpret)

        if mesh is None:
            fn = jax.jit(fused)
        else:
            from repro.core.cost_models import EnvArrays
            from repro.core.mcop_shard import sharded_fused_solver

            env_struct = jax.tree_util.tree_structure(EnvArrays(*(0,) * 6))
            fn = sharded_fused_solver(fused, mesh, env_struct)
        _FUSED_SOLVERS[key] = fn
        while len(_FUSED_SOLVERS) > _FUSED_SOLVERS_CAP:
            _FUSED_SOLVERS.popitem(last=False)
    return fn


def solve_envs(
    profile,
    model,
    envs: Sequence,
    *,
    backend: str = "jax",
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    interpret: bool | None = None,
    metrics=None,
    mesh=None,
    tracer=None,
) -> list[MCOPResult]:
    """Fused Fig.-1 pipeline: K environments → K placements, one dispatch.

    Args:
      profile: :class:`~repro.core.cost_models.AppProfile` — the
        environment-independent application description; its ``(n,)`` /
        ``(n, n)`` tensors are zero-padded once to the shape bucket.
      model:   :class:`~repro.core.cost_models.CostModel`; its
        ``batch_weights`` runs INSIDE the jitted program.  Compiled
        programs are cached per ``model.fingerprint`` (equal-fingerprint
        models must price identically).
      envs:    K :class:`~repro.core.cost_models.Environment` points, or
        an :class:`~repro.core.cost_models.EnvArrays` holding them as six
        (k,) columns (the batched session engine's form); six scalars per
        environment are all that crosses the host boundary.
      backend: ``"jax"`` / ``"pallas"`` for the fused program,
        ``"pallas_fused"`` for the VMEM-resident kernel that builds each
        environment's WCG weights in-kernel immediately before its solve
        (built-in cost-model kinds only), or ``"reference"`` to route
        the vectorized host build through the numpy oracle
        (exact-parity testing).
      buckets: static shape buckets for the padded vertex count.
      interpret: Pallas-only interpret/compiled override.
      metrics: optional :class:`~repro.obs.metrics.MetricsRegistry` —
        when given, each call counts one ``solve_envs_dispatches`` and
        times the dispatch into ``solve_envs_duration_s``, both labeled
        ``(backend, bucket, devices)``.  ``None`` (default) adds no work
        and no clock reads.
      mesh:    solver-fleet routing (``repro.core.mcop_shard``):
        ``None`` auto-shards the K environments across every device the
        process sees when there is more than one, ``False`` forces the
        single-device program, a ``Mesh`` shards over exactly that
        fleet.  Sharded results are bit-identical to unsharded.
      tracer:  optional :class:`~repro.obs.trace.Tracer` — the sharded
        path records one ``solve_envs.shard`` span per device.
    Returns:
      ``list[MCOPResult]``, one per environment in input order, masks
      ``(n,)`` bool over the profile's vertices.

    ``model.batch_weights`` (WCG construction) and the batched
    Stoer–Wagner solver are jitted into ONE XLA program per (cost model,
    shape bucket) — no per-environment Python ``WCG`` objects, no
    separate packing pass.  Placements match the object path
    ``mcop_batch([model.build(profile, e) for e in envs])`` (asserted by
    the parity suite; note construction happens in the solver dtype
    here, so an *exact* tie between two cuts could in principle resolve
    differently than the build-f64-then-cast object path — equal-cost
    placements either way).
    """
    from repro.core.cost_models import (  # deferred: no import cycle
        EnvArrays,
        validate_env_finite,
    )

    if not isinstance(envs, EnvArrays):
        envs = EnvArrays.from_envs(list(envs))
    k = envs.k
    if k == 0:
        return []
    # corrupted environments must be named here, not silently solved
    # (NaN weights partition into garbage) — see NonFiniteWeightError
    validate_env_finite(envs)
    from repro.core.mcop_shard import resolve_mesh, solver_shards  # deferred

    use_mesh = None if backend == "reference" else resolve_mesh(mesh)
    devices = 1 if use_mesh is None else solver_shards(use_mesh)
    if metrics is not None:
        bucket = _bucket_size(profile.n, buckets)
        metrics.counter(
            "solve_envs_dispatches",
            backend=backend, bucket=bucket, devices=devices,
        ).inc()
        timer = metrics.timer(
            "solve_envs_duration_s",
            backend=backend, bucket=bucket, devices=devices,
        )
    else:
        from repro.obs.trace import NULL_SPAN as timer
    if backend == "reference":
        with timer:
            return [
                mcop_reference(g)
                for g in model.build_batch(profile, envs).to_wcgs()
            ]
    if backend not in ("jax", "pallas", "pallas_fused"):
        raise ValueError(f"unknown MCOP batch backend: {backend!r}")
    dtype = _solver_dtype(backend)
    n = profile.n
    m = _bucket_size(n, buckets)

    # Environment-independent profile tensors, zero-padded to the bucket;
    # padding is pinned and a pin-free profile anchors at vertex 0 (the
    # same convention _pack_bucket applies per graph).
    t_local = np.zeros(m, dtype)
    data_in = np.zeros((m, m), dtype)
    data_out = np.zeros((m, m), dtype)
    pinned = np.ones(m, dtype=bool)
    t_local[:n] = profile.t_local
    data_in[:n, :n] = profile.data_in
    data_out[:n, :n] = profile.data_out
    pinned[:n] = ~profile.offloadable
    if not pinned[:n].any():
        pinned[0] = True

    fn = _fused_solver(model, backend, interpret, use_mesh)
    env_cols = (
        envs.astype(dtype)
        if isinstance(envs, EnvArrays)
        else EnvArrays.from_envs(envs, dtype)
    )
    with timer:
        if use_mesh is not None:
            from repro.core.mcop_shard import sharded_solve_envs_call

            cuts, masks = sharded_solve_envs_call(
                fn,
                jnp.asarray(t_local),
                jnp.asarray(data_in),
                jnp.asarray(data_out),
                jnp.asarray(pinned),
                env_cols,
                mesh=use_mesh,
                tracer=tracer,
            )
        else:
            cuts, masks = fn(
                jnp.asarray(t_local),
                jnp.asarray(data_in),
                jnp.asarray(data_out),
                jnp.asarray(pinned),
                env_cols,
            )
            cuts, masks = jax.device_get((cuts, masks))  # one host sync
    return [
        MCOPResult(min_cut=float(cuts[i]), local_mask=masks[i, :n].copy(), phases=[])
        for i in range(k)
    ]


def mcop(g: WCG, *, backend: str = "reference") -> MCOPResult:
    """Front door used by the rest of the framework.

    Backends: ``"reference"`` (numpy oracle with per-phase trace),
    ``"jax"`` (jitted dense solver), ``"pallas"`` (single-graph batch
    through the full Stoer–Wagner kernel).  For many graphs per call use
    :func:`mcop_batch`.
    """
    if backend == "reference":
        return mcop_reference(g)
    if backend == "jax":
        return mcop_jax(g)
    if backend == "pallas":
        return mcop_batch([g], backend="pallas")[0]
    raise ValueError(f"unknown MCOP backend: {backend!r}")
