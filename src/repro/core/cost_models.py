"""Partitioning cost models (paper §4.3).

An :class:`AppProfile` is the raw, environment-independent description of
an application that the profilers produce: per-task local execution times
and per-invocation transfer sizes.  A cost model turns a profile plus the
current *environment* (bandwidth B, speedup F, device powers) into a
:class:`~repro.core.graph.WCG` whose total cost under a placement equals
the paper's objective:

* :class:`ResponseTimeModel`   — Eq. 4  (T_total)
* :class:`EnergyModel`         — Eq. 6  (E_total)
* :class:`WeightedModel`       — Eq. 8  (ω-blend, normalised by the
  all-local costs so time and energy are dimensionless and comparable)

Offloading gains (Eqs. 5/7/9) are provided as
:func:`offloading_gain`: ``1 − partial/no-offloading``.

Hardware constants default to the paper's HP iPAQ measurements
(P_m≈0.9 W, P_i≈0.3 W, P_tr≈1.3 W, §7.1) so the reproduction figures are
directly comparable to Figs. 17–19.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import WCG

__all__ = [
    "Environment",
    "AppProfile",
    "CostModel",
    "ResponseTimeModel",
    "EnergyModel",
    "WeightedModel",
    "offloading_gain",
    "PAPER_POWERS",
]

# Paper §7.1 fixed values (HP iPAQ PDA, 400 MHz XScale).
PAPER_POWERS = dict(p_compute=0.9, p_idle=0.3, p_transfer=1.3)


@dataclasses.dataclass(frozen=True)
class Environment:
    """Mutable mobile environment (paper Fig. 1): what the profilers track.

    bandwidth_up/down are in data-units per time-unit (the paper assumes
    B_up == B_down for convenience; we keep both).  ``speedup`` is F.
    """

    bandwidth_up: float
    bandwidth_down: float
    speedup: float
    p_compute: float = PAPER_POWERS["p_compute"]
    p_idle: float = PAPER_POWERS["p_idle"]
    p_transfer: float = PAPER_POWERS["p_transfer"]

    @classmethod
    def symmetric(cls, bandwidth: float, speedup: float, **kw) -> "Environment":
        return cls(bandwidth, bandwidth, speedup, **kw)

    def replace(self, **kw) -> "Environment":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class AppProfile:
    """Environment-independent application profile (program profiler output).

    Attributes:
      t_local:   (n,) local execution time of each task.
      data_in:   (n, n) — data_in[i, j] = bytes sent i→j on invocation
                 (paper's in_ij); asymmetric in general.
      data_out:  (n, n) — data_out[i, j] = bytes returned j→i (out_ji).
      offloadable: (n,) bool.
      names:     labels.
    """

    t_local: np.ndarray
    data_in: np.ndarray
    data_out: np.ndarray
    offloadable: np.ndarray
    names: list[str] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        self.t_local = np.asarray(self.t_local, dtype=np.float64)
        self.data_in = np.asarray(self.data_in, dtype=np.float64)
        self.data_out = np.asarray(self.data_out, dtype=np.float64)
        self.offloadable = np.asarray(self.offloadable, dtype=bool)
        if not self.names:
            self.names = [f"v{i}" for i in range(self.n)]

    @property
    def n(self) -> int:
        return int(self.t_local.shape[0])

    @classmethod
    def from_wcg_times(cls, g: WCG, *, bandwidth: float = 1.0) -> "AppProfile":
        """Invert Eq. 1 assuming symmetric bandwidth: recover transfer sizes."""
        data = g.adj * bandwidth / 2.0
        return cls(
            t_local=g.w_local.copy(),
            data_in=data,
            data_out=data.T.copy(),
            offloadable=g.offloadable.copy(),
            names=list(g.names),
        )


def _edge_time(profile: AppProfile, env: Environment) -> np.ndarray:
    """Eq. 1: w(e(v_i, v_j)) = in_ij/B_up + out_ij/B_down, symmetrised.

    The communication charge is paid once per cut edge regardless of
    direction, so the WCG edge weight is the *total* transfer time across
    the (i, j) boundary.
    """
    per_dir = profile.data_in / env.bandwidth_up + profile.data_out / env.bandwidth_down
    return per_dir + per_dir.T


class CostModel:
    """Base: maps (profile, environment) → WCG.  Subclasses fill weights."""

    name = "abstract"

    @property
    def fingerprint(self) -> str:
        """Identity of the *objective* for cache-persistence guards: a
        placement cached under one cost model must not warm-start a
        tenant optimizing another.  Parametric models must fold their
        parameters in (see :class:`WeightedModel`)."""
        return self.name

    def build(self, profile: AppProfile, env: Environment) -> WCG:
        raise NotImplementedError

    def local_total(self, profile: AppProfile, env: Environment) -> float:
        """Cost of the no-offloading scheme (denominator of the gains)."""
        return float(self.build(profile, env).total_cost(np.ones(profile.n, bool)))


class ResponseTimeModel(CostModel):
    """Eq. 4: node = execution time on the given side; edge = transfer time."""

    name = "time"

    def build(self, profile: AppProfile, env: Environment) -> WCG:
        t_l = profile.t_local
        t_c = t_l / env.speedup  # T_v^l = F · T_v^c  (F > 1)
        return WCG(
            w_local=t_l,
            w_cloud=t_c,
            adj=_edge_time(profile, env),
            offloadable=profile.offloadable,
            names=list(profile.names),
        )


class EnergyModel(CostModel):
    """Eq. 6: mobile-side energy.

    Local run: P_m · T_l.  Remote run: the device idles while the cloud
    computes — P_i · T_c.  Cut edge: P_tr · transfer time.
    """

    name = "energy"

    def build(self, profile: AppProfile, env: Environment) -> WCG:
        t_l = profile.t_local
        t_c = t_l / env.speedup
        return WCG(
            w_local=env.p_compute * t_l,
            w_cloud=env.p_idle * t_c,
            adj=env.p_transfer * _edge_time(profile, env),
            offloadable=profile.offloadable,
            names=list(profile.names),
        )


class WeightedModel(CostModel):
    """Eq. 8: ω·T/T_local + (1−ω)·E/E_local.

    Linearity makes the blend itself a WCG: every node/edge weight is the
    ω-combination of the normalised time and energy weights, so MCOP (or
    any partitioner) applies unchanged — this is why the paper can reuse
    one algorithm across all three objectives.
    """

    name = "weighted"

    def __init__(self, omega: float = 0.5):
        if not 0.0 <= omega <= 1.0:
            raise ValueError("omega must be in [0, 1]")
        self.omega = omega
        self._time = ResponseTimeModel()
        self._energy = EnergyModel()

    @property
    def fingerprint(self) -> str:
        return f"{self.name}:{self.omega!r}"

    def build(self, profile: AppProfile, env: Environment) -> WCG:
        gt = self._time.build(profile, env)
        ge = self._energy.build(profile, env)
        t_norm = max(float(gt.w_local.sum()), 1e-30)  # T_local
        e_norm = max(float(ge.w_local.sum()), 1e-30)  # E_local
        w = self.omega
        return WCG(
            w_local=w * gt.w_local / t_norm + (1 - w) * ge.w_local / e_norm,
            w_cloud=w * gt.w_cloud / t_norm + (1 - w) * ge.w_cloud / e_norm,
            adj=w * gt.adj / t_norm + (1 - w) * ge.adj / e_norm,
            offloadable=profile.offloadable,
            names=list(profile.names),
        )


def offloading_gain(no_offload_cost: float, partial_cost: float) -> float:
    """§7.1: Offloading Gain = 1 − partial/no-offloading (as a fraction)."""
    if no_offload_cost <= 0:
        return 0.0
    return 1.0 - partial_cost / no_offload_cost
