"""Partitioning cost models (paper §4.3).

An :class:`AppProfile` is the raw, environment-independent description of
an application that the profilers produce: per-task local execution times
and per-invocation transfer sizes.  A cost model turns a profile plus the
current *environment* (bandwidth B, speedup F, device powers) into a
:class:`~repro.core.graph.WCG` whose total cost under a placement equals
the paper's objective:

* :class:`ResponseTimeModel`   — Eq. 4  (T_total)
* :class:`EnergyModel`         — Eq. 6  (E_total)
* :class:`WeightedModel`       — Eq. 8  (ω-blend, normalised by the
  all-local costs so time and energy are dimensionless and comparable)

Offloading gains (Eqs. 5/7/9) are provided as
:func:`offloading_gain`: ``1 − partial/no-offloading``.

Hardware constants default to the paper's HP iPAQ measurements
(P_m≈0.9 W, P_i≈0.3 W, P_tr≈1.3 W, §7.1) so the reproduction figures are
directly comparable to Figs. 17–19.

The models are *batch-first*: each implements
:meth:`CostModel.batch_weights` — pure array arithmetic mapping a profile
plus K stacked environments (:class:`EnvArrays`) to K graphs' weight
tensors.  The math is written polymorphically, so the same code path
serves two callers:

* host construction (numpy float64): :meth:`CostModel.build_batch`
  returns a :class:`~repro.core.graph.WCGBatch`, and the scalar
  :meth:`CostModel.build` is literally a batch of one — bit-identical to
  the historical per-environment builders;
* device construction (jax, traced): ``repro.core.mcop.solve_envs`` jits
  ``batch_weights`` *together with* the Stoer–Wagner solver, so an
  environment sweep compiles to one XLA program with no per-environment
  host work at all.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import numpy as np

from repro.core.graph import WCG, WCGBatch, NonFiniteWeightError

__all__ = [
    "Environment",
    "EnvArrays",
    "validate_env_finite",
    "AppProfile",
    "CostModel",
    "ResponseTimeModel",
    "EnergyModel",
    "WeightedModel",
    "offloading_gain",
    "PAPER_POWERS",
]

# Paper §7.1 fixed values (HP iPAQ PDA, 400 MHz XScale).
PAPER_POWERS = dict(p_compute=0.9, p_idle=0.3, p_transfer=1.3)


@dataclasses.dataclass(frozen=True)
class Environment:
    """Mutable mobile environment (paper Fig. 1): what the profilers track.

    bandwidth_up/down are in data-units per time-unit (the paper assumes
    B_up == B_down for convenience; we keep both).  ``speedup`` is F.
    """

    bandwidth_up: float
    bandwidth_down: float
    speedup: float
    p_compute: float = PAPER_POWERS["p_compute"]
    p_idle: float = PAPER_POWERS["p_idle"]
    p_transfer: float = PAPER_POWERS["p_transfer"]

    @classmethod
    def symmetric(cls, bandwidth: float, speedup: float, **kw) -> "Environment":
        return cls(bandwidth, bandwidth, speedup, **kw)

    def replace(self, **kw) -> "Environment":
        return dataclasses.replace(self, **kw)


class EnvArrays(NamedTuple):
    """K environments as six (k,) arrays — the batched Environment.

    A NamedTuple is automatically a JAX pytree, so an ``EnvArrays`` can be
    passed straight into a jitted build+solve program.
    """

    bandwidth_up: np.ndarray
    bandwidth_down: np.ndarray
    speedup: np.ndarray
    p_compute: np.ndarray
    p_idle: np.ndarray
    p_transfer: np.ndarray

    @classmethod
    def from_envs(cls, envs: Sequence[Environment], dtype=np.float64) -> "EnvArrays":
        return cls(
            np.array([e.bandwidth_up for e in envs], dtype),
            np.array([e.bandwidth_down for e in envs], dtype),
            np.array([e.speedup for e in envs], dtype),
            np.array([e.p_compute for e in envs], dtype),
            np.array([e.p_idle for e in envs], dtype),
            np.array([e.p_transfer for e in envs], dtype),
        )

    @property
    def k(self) -> int:
        return int(self.speedup.shape[0])

    def astype(self, dtype) -> "EnvArrays":
        return EnvArrays(*(np.asarray(f, dtype) for f in self))

    def env(self, i: int) -> Environment:
        """Materialize row ``i`` as a scalar :class:`Environment`.

        ``float()`` of a float64 array element is exact, so round-tripping
        ``from_envs`` → ``env`` preserves every value bitwise — the batched
        session engine relies on this when it emits per-session events.
        """
        return Environment(
            float(self.bandwidth_up[i]),
            float(self.bandwidth_down[i]),
            float(self.speedup[i]),
            float(self.p_compute[i]),
            float(self.p_idle[i]),
            float(self.p_transfer[i]),
        )

    def take(self, indices) -> "EnvArrays":
        """Row subset (fancy indexing) — e.g. the cache-miss sessions a
        batched tick flushes through ``solve_envs``."""
        idx = np.asarray(indices)
        return EnvArrays(*(np.asarray(f)[idx] for f in self))


def validate_env_finite(envs: EnvArrays) -> None:
    """Reject NaN/Inf environment inputs, naming the offending row.

    Host-only (a no-op when any column is a traced/device array):
    corrupted measurements used to flow silently into the weight math
    and poison every graph of the batch; now the first host boundary
    (``CostModel.build_batch``, ``solve_envs``) raises
    :class:`~repro.core.graph.NonFiniteWeightError` instead.  The cheap
    aggregate probe runs every call; the per-row scan only on failure.
    """
    if not all(isinstance(col, np.ndarray) for col in envs):
        return
    probe = sum(float(col.sum()) for col in envs)
    if np.isfinite(probe):
        return
    finite = np.ones(envs.k, dtype=bool)
    for col in envs:
        finite &= np.isfinite(col)
    rows = np.nonzero(~finite)[0]
    first = int(rows[0])
    fields = [
        name
        for name, col in zip(envs._fields, envs)
        if not np.isfinite(col[first])
    ]
    more = "" if rows.size <= 1 else f" (+{rows.size - 1} more row(s))"
    raise NonFiniteWeightError(
        f"non-finite environment input: row {first} "
        f"({', '.join(f'{f}={float(getattr(envs, f)[first])!r}' for f in fields)})"
        f"{more}; rejecting before it corrupts the weight math",
        rows=rows,
    )


@dataclasses.dataclass
class AppProfile:
    """Environment-independent application profile (program profiler output).

    Attributes:
      t_local:   (n,) local execution time of each task.
      data_in:   (n, n) — data_in[i, j] = bytes sent i→j on invocation
                 (paper's in_ij); asymmetric in general.
      data_out:  (n, n) — data_out[i, j] = bytes returned j→i (out_ji).
      offloadable: (n,) bool.
      names:     labels.
    """

    t_local: np.ndarray
    data_in: np.ndarray
    data_out: np.ndarray
    offloadable: np.ndarray
    names: list[str] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        self.t_local = np.asarray(self.t_local, dtype=np.float64)
        self.data_in = np.asarray(self.data_in, dtype=np.float64)
        self.data_out = np.asarray(self.data_out, dtype=np.float64)
        self.offloadable = np.asarray(self.offloadable, dtype=bool)
        if not self.names:
            self.names = [f"v{i}" for i in range(self.n)]

    @property
    def n(self) -> int:
        return int(self.t_local.shape[0])

    @classmethod
    def from_wcg_times(cls, g: WCG, *, bandwidth: float = 1.0) -> "AppProfile":
        """Invert Eq. 1 assuming symmetric bandwidth: recover transfer sizes."""
        data = g.adj * bandwidth / 2.0
        return cls(
            t_local=g.w_local.copy(),
            data_in=data,
            data_out=data.T.copy(),
            offloadable=g.offloadable.copy(),
            names=list(g.names),
        )


def _ns(x):
    """numpy or jax.numpy namespace matching ``x``.

    The batched weight math below is written once and dispatched here:
    host callers pass numpy float64 (bit-identical to the historical
    scalar builders), the fused device path passes traced jax arrays.
    """
    import jax.numpy as jnp

    return jnp if isinstance(x, jax.Array) else np


def _edge_time_batch(data_in, data_out, env: EnvArrays):
    """Eq. 1, batched: w(e(v_i, v_j)) = in_ij/B_up + out_ij/B_down, symmetrised.

    The communication charge is paid once per cut edge regardless of
    direction, so the WCG edge weight is the *total* transfer time across
    the (i, j) boundary.  ``data_in``/``data_out`` are (n, n); the result
    is (k, n, n).
    """
    xp = _ns(env.bandwidth_up)
    per_dir = (
        data_in[None] / env.bandwidth_up[:, None, None]
        + data_out[None] / env.bandwidth_down[:, None, None]
    )
    return per_dir + xp.swapaxes(per_dir, -1, -2)


class CostModel:
    """Base: maps (profile, environments) → WCG / WCGBatch weights.

    Subclasses implement :meth:`batch_weights` only; the scalar
    :meth:`build` and the host :meth:`build_batch` both ride on it.
    """

    name = "abstract"

    @property
    def fingerprint(self) -> str:
        """Identity of the *objective* for cache-persistence guards: a
        placement cached under one cost model must not warm-start a
        tenant optimizing another.  Parametric models must fold their
        parameters in (see :class:`WeightedModel`).  Two instances with
        equal fingerprints must price identically — ``solve_envs`` keys
        its compiled build+solve programs on the fingerprint."""
        return self.name

    def batch_weights(self, t_local, data_in, data_out, env: EnvArrays):
        """Pure array math: profile tensors + K environments → weights.

        Inputs may be numpy or traced jax arrays; returns
        ``(w_local (k, n), w_cloud (k, n), adj (k, n, n))``.  Zero-padded
        profile columns stay zero, so callers may pass padded tensors.
        """
        raise NotImplementedError

    def build_batch(
        self,
        profile: AppProfile,
        envs: "Sequence[Environment] | EnvArrays",
        *,
        m: int | None = None,
        dtype=np.float64,
    ) -> WCGBatch:
        """K environments → one :class:`WCGBatch` (vectorized host build).

        Row ``i`` is bit-identical to ``self.build(profile, envs[i])``;
        ``m`` optionally zero-pads to a solver bucket size.  ``envs`` may
        be an :class:`EnvArrays` already — the batched session engine
        never materializes per-environment Python objects.
        """
        env_arrays = (
            envs.astype(dtype)
            if isinstance(envs, EnvArrays)
            else EnvArrays.from_envs(envs, dtype)
        )
        validate_env_finite(env_arrays)
        wl, wc, adj = self.batch_weights(
            np.asarray(profile.t_local, dtype),
            np.asarray(profile.data_in, dtype),
            np.asarray(profile.data_out, dtype),
            env_arrays,
        )
        return WCGBatch.pack(
            wl, wc, adj, np.broadcast_to(profile.offloadable, wl.shape),
            m=m, names=profile.names, dtype=dtype,
        )

    def build(self, profile: AppProfile, env: Environment) -> WCG:
        """Scalar front door — a batch of one over the same code path."""
        return self.build_batch(profile, [env]).wcg(0)

    def local_total(self, profile: AppProfile, env: Environment) -> float:
        """Cost of the no-offloading scheme (denominator of the gains)."""
        return float(self.build(profile, env).total_cost(np.ones(profile.n, bool)))


class ResponseTimeModel(CostModel):
    """Eq. 4: node = execution time on the given side; edge = transfer time."""

    name = "time"

    def batch_weights(self, t_local, data_in, data_out, env: EnvArrays):
        xp = _ns(env.speedup)
        t_c = t_local[None, :] / env.speedup[:, None]  # T_v^l = F · T_v^c  (F > 1)
        t_l = xp.broadcast_to(t_local[None, :], t_c.shape)
        return t_l, t_c, _edge_time_batch(data_in, data_out, env)


class EnergyModel(CostModel):
    """Eq. 6: mobile-side energy.

    Local run: P_m · T_l.  Remote run: the device idles while the cloud
    computes — P_i · T_c.  Cut edge: P_tr · transfer time.
    """

    name = "energy"

    def batch_weights(self, t_local, data_in, data_out, env: EnvArrays):
        t_c = t_local[None, :] / env.speedup[:, None]
        return (
            env.p_compute[:, None] * t_local[None, :],
            env.p_idle[:, None] * t_c,
            env.p_transfer[:, None, None] * _edge_time_batch(data_in, data_out, env),
        )


class WeightedModel(CostModel):
    """Eq. 8: ω·T/T_local + (1−ω)·E/E_local.

    Linearity makes the blend itself a WCG: every node/edge weight is the
    ω-combination of the normalised time and energy weights, so MCOP (or
    any partitioner) applies unchanged — this is why the paper can reuse
    one algorithm across all three objectives.
    """

    name = "weighted"

    def __init__(self, omega: float = 0.5):
        if not 0.0 <= omega <= 1.0:
            raise ValueError("omega must be in [0, 1]")
        self.omega = omega
        self._time = ResponseTimeModel()
        self._energy = EnergyModel()

    @property
    def fingerprint(self) -> str:
        return f"{self.name}:{self.omega!r}"

    def batch_weights(self, t_local, data_in, data_out, env: EnvArrays):
        xp = _ns(env.speedup)
        wl_t, wc_t, adj_t = self._time.batch_weights(t_local, data_in, data_out, env)
        wl_e, wc_e, adj_e = self._energy.batch_weights(t_local, data_in, data_out, env)
        t_norm = xp.maximum(wl_t.sum(axis=-1), 1e-30)[:, None]  # T_local per graph
        e_norm = xp.maximum(wl_e.sum(axis=-1), 1e-30)[:, None]  # E_local per graph
        w = self.omega
        return (
            w * wl_t / t_norm + (1 - w) * wl_e / e_norm,
            w * wc_t / t_norm + (1 - w) * wc_e / e_norm,
            w * adj_t / t_norm[..., None] + (1 - w) * adj_e / e_norm[..., None],
        )


def offloading_gain(no_offload_cost: float, partial_cost: float) -> float:
    """§7.1: Offloading Gain = 1 − partial/no-offloading (as a fraction)."""
    if no_offload_cost <= 0:
        return 0.0
    return 1.0 - partial_cost / no_offload_cost
