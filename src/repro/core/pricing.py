"""Array-native pricing/telemetry: trace of placements → one fused report.

The paper frames partition quality as a cost/energy *trade-off report*
(§7, Figs. 17–19): for every environment the interesting numbers are the
cost of the chosen placement, the no-offloading baseline, the
full-offloading baseline, and the offloading gain between them.  The
adaptive loop's ``_emit`` used to produce those numbers with three
scalar graph evaluations per event — after PR 4 fused construction and
solving, that per-event host pricing was what dominated a sweep.

This module is the batched sibling: a whole trace of
``(environment, placement)`` pairs is priced in ONE vectorized
evaluation — one ``cost_model.build_batch`` (a single pass of array
arithmetic over the profile tensors) followed by one
:meth:`~repro.core.graph.WCGBatch.price_batch` call.  Results are
collected in a :class:`PriceReport`, a registered JAX pytree of (k,)
arrays, so downstream telemetry/dashboards can consume it without
touching Python objects.

Bit-identity contract: every number in the report equals the scalar
path (``g.total_cost`` + ``baselines.no_offloading`` /
``baselines.full_offloading`` + ``offloading_gain``) *bitwise*, because
host pricing batches are unpadded and both paths reduce in the same
order (see :meth:`repro.core.graph.WCG.total_cost`).  The parity suite
asserts ``==``, not ``approx``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.graph import WCGBatch

__all__ = [
    "PriceReport",
    "price_batch",
    "price_trace",
    "vector_gain",
    "device_price_summary",
]


def vector_gain(no_offload: np.ndarray, partial: np.ndarray) -> np.ndarray:
    """Vectorized §7.1 offloading gain: ``1 − partial/no_offload``.

    Matches :func:`repro.core.cost_models.offloading_gain` elementwise
    (a non-positive no-offloading cost yields 0.0, same guard).
    """
    no_offload = np.asarray(no_offload, dtype=np.float64)
    partial = np.asarray(partial, dtype=np.float64)
    out = np.zeros_like(no_offload)
    ok = no_offload > 0
    np.divide(partial, no_offload, out=out, where=ok)
    return np.where(ok, 1.0 - out, 0.0)


@dataclasses.dataclass
class PriceReport:
    """K priced placements as stacked (k,) arrays — the batched event.

    Attributes:
      partial_cost:      (k,) Eq.-2 cost of each placement at its own
                         environment's prices.
      no_offload_cost:   (k,) all-local baseline (paper §7.1).
      full_offload_cost: (k,) everything-offloadable-remote baseline.
      gain:              (k,) offloading gain ``1 − partial/no_offload``.

    A registered pytree (all leaves are arrays), so a report can cross
    ``jax.jit`` boundaries or be device_put for dashboard reduction.
    """

    partial_cost: Any
    no_offload_cost: Any
    full_offload_cost: Any
    gain: Any

    def __len__(self) -> int:
        return int(np.asarray(self.partial_cost).shape[0])

    def row(self, i: int) -> tuple[float, float, float, float]:
        """Scalar view of one trace step: (partial, no_off, full, gain)."""
        return (
            float(self.partial_cost[i]),
            float(self.no_offload_cost[i]),
            float(self.full_offload_cost[i]),
            float(self.gain[i]),
        )


jax.tree_util.register_pytree_node(
    PriceReport,
    lambda r: (
        (r.partial_cost, r.no_offload_cost, r.full_offload_cost, r.gain),
        None,
    ),
    lambda _, ch: PriceReport(*ch),
)


def price_batch(batch: WCGBatch, local_masks: np.ndarray) -> PriceReport:
    """Price K placements against an already-built :class:`WCGBatch`.

    Args:
      batch:       K stacked WCGs (one pricing evaluation regardless of K).
        For bit-identity with the scalar path the batch must be unpadded
        (``m == n``); padded batches are still numerically correct
        (padding contributes exactly 0.0) but may differ from the scalar
        path in the last ulp because numpy's pairwise summation groups
        by row length.
      local_masks: (k, m) bool placements (padding columns True).
    Returns:
      :class:`PriceReport` with (k,) rows in batch order.
    """
    partial, no_off, full = batch.price_batch(local_masks)
    return PriceReport(
        partial_cost=np.asarray(partial, dtype=np.float64),
        no_offload_cost=np.asarray(no_off, dtype=np.float64),
        full_offload_cost=np.asarray(full, dtype=np.float64),
        gain=vector_gain(no_off, partial),
    )


def price_trace(
    profile,
    model,
    trace: Sequence[tuple],
) -> PriceReport:
    """Price a trace of ``(environment, placement-mask)`` pairs in one pass.

    The array-native replacement for looping ``_emit``-style telemetry:
    the K WCGs are constructed by ONE vectorized
    ``model.build_batch`` call (rows bit-identical to the scalar
    ``model.build``) and all 3·K cost numbers come from ONE
    :meth:`~repro.core.graph.WCGBatch.price_batch` evaluation.

    Args:
      profile: :class:`~repro.core.cost_models.AppProfile` shared by the
        whole trace (one application, K environment points).
      model:   :class:`~repro.core.cost_models.CostModel` pricing the
        objective (time / energy / weighted).
      trace:   sequence of ``(Environment, local_mask)`` pairs; each
        mask is (n,) bool over the profile's vertices.
    Returns:
      :class:`PriceReport` with row ``i`` bit-identical to pricing
      ``trace[i]`` through the scalar path.
    """
    trace = list(trace)
    if not trace:
        empty = np.zeros(0, dtype=np.float64)
        return PriceReport(empty, empty.copy(), empty.copy(), empty.copy())
    envs = [env for env, _ in trace]
    masks = np.stack([np.asarray(m, dtype=bool) for _, m in trace])
    if masks.shape != (len(trace), profile.n):
        raise ValueError(
            f"trace masks must be (k, {profile.n}), got {masks.shape}"
        )
    batch = model.build_batch(profile, envs)  # unpadded: m == profile.n
    return price_batch(batch, masks)


# ----------------------------------------------------------------------
# Device-resident reduction: build → price → reduce inside ONE jitted
# program, so telemetry over K sessions syncs a handful of scalars to
# the host instead of K-sized report arrays.
# ----------------------------------------------------------------------

# Compiled build+price+reduce programs, keyed like mcop._FUSED_SOLVERS:
# equal-fingerprint models price identically (CostModel.fingerprint
# contract), and jit re-specializes per input shape, so (type,
# fingerprint) suffices.  LRU-bounded for parametric-model sweeps.
_DEVICE_PRICERS: OrderedDict = OrderedDict()
_DEVICE_PRICERS_CAP = 64

_SUMMARY_FIELDS = (
    "partial_mean",
    "partial_min",
    "partial_max",
    "no_offload_mean",
    "full_offload_mean",
    "gain_mean",
    "gain_min",
    "gain_max",
)


def _device_pricer(model):
    import jax.numpy as jnp

    key = (type(model), model.fingerprint)
    fn = _DEVICE_PRICERS.get(key)
    if fn is not None:
        _DEVICE_PRICERS.move_to_end(key)
        return fn

    def fused(t_local, data_in, data_out, offloadable, env, masks, weights):
        wl, wc, adj = model.batch_weights(t_local, data_in, data_out, env)

        def price(m):
            node = jnp.where(m, wl, wc).sum(axis=-1)
            cut = m[:, :, None] != m[:, None, :]
            return node + (adj * cut).sum(axis=(-1, -2)) / 2.0

        partial = price(masks)
        no_off = wl.sum(axis=-1)
        full = price(jnp.broadcast_to(~offloadable[None, :], masks.shape))
        gain = jnp.where(no_off > 0, 1.0 - partial / no_off, 0.0)
        # weighted (active-session) reductions; `weights` is 0/1 so idle
        # slots of a fixed-capacity session batch never skew the means
        w_sum = jnp.maximum(weights.sum(), 1.0)

        def masked_min(x):
            return jnp.where(weights > 0, x, jnp.inf).min()

        def masked_max(x):
            return jnp.where(weights > 0, x, -jnp.inf).max()

        return {
            "partial_mean": (partial * weights).sum() / w_sum,
            "partial_min": masked_min(partial),
            "partial_max": masked_max(partial),
            "no_offload_mean": (no_off * weights).sum() / w_sum,
            "full_offload_mean": (full * weights).sum() / w_sum,
            "gain_mean": (gain * weights).sum() / w_sum,
            "gain_min": masked_min(gain),
            "gain_max": masked_max(gain),
        }

    fn = _DEVICE_PRICERS[key] = jax.jit(fused)
    while len(_DEVICE_PRICERS) > _DEVICE_PRICERS_CAP:
        _DEVICE_PRICERS.popitem(last=False)
    return fn


def device_price_summary(profile, model, envs, masks, active=None) -> dict:
    """Fused device-side pricing telemetry: K sessions → ~8 scalars.

    The whole chain — ``model.batch_weights`` WCG construction, Eq.-2
    pricing of the placements, both §7.1 baselines, the offloading gains
    *and the reductions over sessions* — runs inside one jitted XLA
    program; only the reduced scalars cross the host boundary.  This is
    the dashboard path for batched session ticks at 10⁵–10⁶ users, where
    syncing K-sized :class:`PriceReport` arrays per tick would dominate.

    Args:
      profile: shared :class:`~repro.core.cost_models.AppProfile`.
      model:   :class:`~repro.core.cost_models.CostModel` objective.
      envs:    :class:`~repro.core.cost_models.EnvArrays` (k rows) or a
               sequence of Environments.
      masks:   (k, n) bool placements to price.
      active:  optional (k,) bool — sessions to include in the
               reductions (idle slots of a fixed-capacity batch are
               priced but excluded).
    Returns:
      dict of Python floats (mean/min/max partial cost, mean baselines,
      mean/min/max gain) in device precision — f32 unless jax x64 is
      enabled, so this is telemetry, NOT the bit-exact host pricing path
      that placement/clamp decisions ride.
    """
    import jax.numpy as jnp

    from repro.core.cost_models import EnvArrays

    if not isinstance(envs, EnvArrays):
        envs = EnvArrays.from_envs(envs)
    masks = np.asarray(masks, dtype=bool)
    weights = (
        np.ones(masks.shape[0])
        if active is None
        else np.asarray(active, dtype=np.float64)
    )
    fn = _device_pricer(model)
    out = fn(
        jnp.asarray(np.asarray(profile.t_local)),
        jnp.asarray(np.asarray(profile.data_in)),
        jnp.asarray(np.asarray(profile.data_out)),
        jnp.asarray(profile.offloadable),
        jax.tree_util.tree_map(jnp.asarray, envs),
        jnp.asarray(masks),
        jnp.asarray(weights),
    )
    out = jax.device_get(out)  # ONE host sync for the whole summary
    return {k: float(out[k]) for k in _SUMMARY_FIELDS}
