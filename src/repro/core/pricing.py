"""Array-native pricing/telemetry: trace of placements → one fused report.

The paper frames partition quality as a cost/energy *trade-off report*
(§7, Figs. 17–19): for every environment the interesting numbers are the
cost of the chosen placement, the no-offloading baseline, the
full-offloading baseline, and the offloading gain between them.  The
adaptive loop's ``_emit`` used to produce those numbers with three
scalar graph evaluations per event — after PR 4 fused construction and
solving, that per-event host pricing was what dominated a sweep.

This module is the batched sibling: a whole trace of
``(environment, placement)`` pairs is priced in ONE vectorized
evaluation — one ``cost_model.build_batch`` (a single pass of array
arithmetic over the profile tensors) followed by one
:meth:`~repro.core.graph.WCGBatch.price_batch` call.  Results are
collected in a :class:`PriceReport`, a registered JAX pytree of (k,)
arrays, so downstream telemetry/dashboards can consume it without
touching Python objects.

Bit-identity contract: every number in the report equals the scalar
path (``g.total_cost`` + ``baselines.no_offloading`` /
``baselines.full_offloading`` + ``offloading_gain``) *bitwise*, because
host pricing batches are unpadded and both paths reduce in the same
order (see :meth:`repro.core.graph.WCG.total_cost`).  The parity suite
asserts ``==``, not ``approx``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.graph import WCGBatch

__all__ = ["PriceReport", "price_batch", "price_trace", "vector_gain"]


def vector_gain(no_offload: np.ndarray, partial: np.ndarray) -> np.ndarray:
    """Vectorized §7.1 offloading gain: ``1 − partial/no_offload``.

    Matches :func:`repro.core.cost_models.offloading_gain` elementwise
    (a non-positive no-offloading cost yields 0.0, same guard).
    """
    no_offload = np.asarray(no_offload, dtype=np.float64)
    partial = np.asarray(partial, dtype=np.float64)
    out = np.zeros_like(no_offload)
    ok = no_offload > 0
    np.divide(partial, no_offload, out=out, where=ok)
    return np.where(ok, 1.0 - out, 0.0)


@dataclasses.dataclass
class PriceReport:
    """K priced placements as stacked (k,) arrays — the batched event.

    Attributes:
      partial_cost:      (k,) Eq.-2 cost of each placement at its own
                         environment's prices.
      no_offload_cost:   (k,) all-local baseline (paper §7.1).
      full_offload_cost: (k,) everything-offloadable-remote baseline.
      gain:              (k,) offloading gain ``1 − partial/no_offload``.

    A registered pytree (all leaves are arrays), so a report can cross
    ``jax.jit`` boundaries or be device_put for dashboard reduction.
    """

    partial_cost: Any
    no_offload_cost: Any
    full_offload_cost: Any
    gain: Any

    def __len__(self) -> int:
        return int(np.asarray(self.partial_cost).shape[0])

    def row(self, i: int) -> tuple[float, float, float, float]:
        """Scalar view of one trace step: (partial, no_off, full, gain)."""
        return (
            float(self.partial_cost[i]),
            float(self.no_offload_cost[i]),
            float(self.full_offload_cost[i]),
            float(self.gain[i]),
        )


jax.tree_util.register_pytree_node(
    PriceReport,
    lambda r: (
        (r.partial_cost, r.no_offload_cost, r.full_offload_cost, r.gain),
        None,
    ),
    lambda _, ch: PriceReport(*ch),
)


def price_batch(batch: WCGBatch, local_masks: np.ndarray) -> PriceReport:
    """Price K placements against an already-built :class:`WCGBatch`.

    Args:
      batch:       K stacked WCGs (one pricing evaluation regardless of K).
        For bit-identity with the scalar path the batch must be unpadded
        (``m == n``); padded batches are still numerically correct
        (padding contributes exactly 0.0) but may differ from the scalar
        path in the last ulp because numpy's pairwise summation groups
        by row length.
      local_masks: (k, m) bool placements (padding columns True).
    Returns:
      :class:`PriceReport` with (k,) rows in batch order.
    """
    partial, no_off, full = batch.price_batch(local_masks)
    return PriceReport(
        partial_cost=np.asarray(partial, dtype=np.float64),
        no_offload_cost=np.asarray(no_off, dtype=np.float64),
        full_offload_cost=np.asarray(full, dtype=np.float64),
        gain=vector_gain(no_off, partial),
    )


def price_trace(
    profile,
    model,
    trace: Sequence[tuple],
) -> PriceReport:
    """Price a trace of ``(environment, placement-mask)`` pairs in one pass.

    The array-native replacement for looping ``_emit``-style telemetry:
    the K WCGs are constructed by ONE vectorized
    ``model.build_batch`` call (rows bit-identical to the scalar
    ``model.build``) and all 3·K cost numbers come from ONE
    :meth:`~repro.core.graph.WCGBatch.price_batch` evaluation.

    Args:
      profile: :class:`~repro.core.cost_models.AppProfile` shared by the
        whole trace (one application, K environment points).
      model:   :class:`~repro.core.cost_models.CostModel` pricing the
        objective (time / energy / weighted).
      trace:   sequence of ``(Environment, local_mask)`` pairs; each
        mask is (n,) bool over the profile's vertices.
    Returns:
      :class:`PriceReport` with row ``i`` bit-identical to pricing
      ``trace[i]`` through the scalar path.
    """
    trace = list(trace)
    if not trace:
        empty = np.zeros(0, dtype=np.float64)
        return PriceReport(empty, empty.copy(), empty.copy(), empty.copy())
    envs = [env for env, _ in trace]
    masks = np.stack([np.asarray(m, dtype=bool) for _, m in trace])
    if masks.shape != (len(trace), profile.n):
        raise ValueError(
            f"trace masks must be (k, {profile.n}), got {masks.shape}"
        )
    batch = model.build_batch(profile, envs)  # unpadded: m == profile.n
    return price_batch(batch, masks)
