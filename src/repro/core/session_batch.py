"""Array-native session engine: K adaptive sessions as one pytree.

PRs 3–5 made the *solver* side of the serving tier array-native — one
``solve_envs`` flush per tick, one ``price_batch`` for telemetry — but
every user was still a Python :class:`~repro.service.session.BrokerSession`
wrapping an :class:`~repro.core.adaptive.AdaptiveController`, so a broker
tick remained O(users) interpreted work above the solver.  This module
refactors the session *state itself* into arrays:

* :class:`SessionBatch` — a registered JAX pytree holding, for K
  sessions: the drift anchors (the environment at the last repartition),
  current placement masks, installed cut values, the per-session step
  clock and repartition-cooldown counters, and activity flags (a fixed
  capacity of slots; Poisson arrivals / geometric churn activate and
  reset them — see ``repro.service.workload.TrafficGenerator``).
* :meth:`SessionBatch.begin_step` — the vectorized Fig.-1 decision: one
  pass of array arithmetic advances every session's clock, runs the
  shared drift test (:func:`repro.core.adaptive.drift_exceeded_arrays`
  — literally the same function the scalar controller calls) and moves
  the anchors of every session whose repartition is due.
* :func:`tick_sessions` / :meth:`SessionBatch.commit_step` — one tick
  over all K sessions: (a) one vectorized cache probe on quantized keys
  (:meth:`~repro.core.placement_cache.EnvQuantizer.keys_batch`), (b) ONE
  ``solve_envs`` flush for the distinct-bin misses, (c) ONE fused
  ``price_batch`` pricing every session's final mask, baselines and
  §4.3 clamps together.

The decision/drift arithmetic stays host numpy float64 on purpose: the
parity contract below demands bit-identity with the scalar controller,
and jitting it without x64 would demote the comparisons to float32.
(:func:`drift_exceeded_arrays` is namespace-polymorphic, so a TPU
deployment with x64 enabled can move the decision pass on-device without
touching this module.)

Parity contract (asserted by ``tests/test_session_batch.py`` with
``==``, not approx): one :func:`tick_sessions` produces events,
placements and prices **bit-identical** to K
:class:`~repro.service.session.BrokerSession` objects observing the same
environments in session-index order through an
:class:`~repro.service.broker.OffloadBroker` sharing the same cache —
hits probed before any store of the tick, first miss per quantized bin
becomes the representative solve, same-bin followers repriced under
their exact own graph, §4.3 clamps applied through the shared
``baselines`` helpers.

Failure containment differs from the broker deliberately: the broker
re-queues unresolved requests, while a batched tick is atomic — if the
solve flush raises, all decision state is restored to its pre-tick
checkpoint (no events, no counter updates, no stores) and the caller
retries the whole tick.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np

from repro.core import pricing
from repro.core.adaptive import AdaptationEvent, drift_exceeded_arrays
from repro.core.cost_models import AppProfile, CostModel, EnvArrays
from repro.core.mcop import DEFAULT_BUCKETS, MCOPResult, solve_envs
from repro.core.placement_cache import PlacementCache
from repro.obs.trace import NULL_SPAN

__all__ = ["SessionBatch", "SessionTickReport", "tick_sessions"]

# AdaptiveController's "no partition yet" cooldown sentinel: a fresh
# session is always due on its first observation.
_NEVER = 10**9

# array leaves (pytree children); n/threshold/min_interval are static aux
_LEAF_FIELDS = (
    "anchor_up",
    "anchor_down",
    "anchor_speedup",
    "placements",
    "min_cuts",
    "steps",
    "steps_since",
    "has_partition",
    "active",
)


@dataclasses.dataclass
class SessionBatch:
    """K concurrent adaptive sessions as stacked arrays.

    Attributes:
      n:            graph size (the tenant profile's vertex count).
      threshold:    relative drift that triggers re-partitioning.
      min_interval: cooldown in observations between repartitions.
      anchor_*:     (k,) f64 — environment at the last repartition (the
                    drift detector's anchor); 0.0 until one exists.
      placements:   (k, n) bool — each session's current local-mask.
      min_cuts:     (k,) f64 — installed result's cut value (NaN until a
                    partition exists).
      steps:        (k,) i64 — per-session observation clock (events
                    carry it, matching ``AdaptiveController._step``).
      steps_since:  (k,) i64 — observations since the last repartition.
      has_partition:(k,) bool — a partition exists (or none scheduled).
      active:       (k,) bool — slot is occupied by a live session.

    A registered pytree: the arrays are children, the scalars static —
    a batch can cross ``jax.jit`` boundaries (e.g. an on-device decision
    pass under x64) or be checkpointed with one ``tree_map``.
    """

    n: int
    threshold: float
    min_interval: int
    anchor_up: np.ndarray
    anchor_down: np.ndarray
    anchor_speedup: np.ndarray
    placements: np.ndarray
    min_cuts: np.ndarray
    steps: np.ndarray
    steps_since: np.ndarray
    has_partition: np.ndarray
    active: np.ndarray

    # -- construction ----------------------------------------------------
    @classmethod
    def create(
        cls,
        capacity: int,
        n: int,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
    ) -> "SessionBatch":
        """``capacity`` empty session slots for an ``n``-vertex profile."""
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if n <= 0:
            raise ValueError("graph size must be positive")
        return cls(
            n=int(n),
            threshold=float(threshold),
            min_interval=int(min_interval),
            anchor_up=np.zeros(capacity),
            anchor_down=np.zeros(capacity),
            anchor_speedup=np.zeros(capacity),
            placements=np.ones((capacity, n), dtype=bool),
            min_cuts=np.full(capacity, np.nan),
            steps=np.zeros(capacity, dtype=np.int64),
            steps_since=np.full(capacity, _NEVER, dtype=np.int64),
            has_partition=np.zeros(capacity, dtype=bool),
            active=np.zeros(capacity, dtype=bool),
        )

    @property
    def capacity(self) -> int:
        return int(self.steps.shape[0])

    @property
    def active_count(self) -> int:
        return int(np.count_nonzero(self.active))

    def _rows(self, sessions) -> np.ndarray:
        idx = np.asarray(sessions)
        if idx.dtype == bool:
            if idx.shape != (self.capacity,):
                raise ValueError(
                    f"session mask must be ({self.capacity},), got {idx.shape}"
                )
            idx = np.nonzero(idx)[0]
        return idx.astype(np.int64).reshape(-1)

    # -- churn: slot lifecycle ------------------------------------------
    def activate(self, sessions) -> None:
        """Reset the given slots (index array or (k,) bool mask) to a
        fresh session and mark them live — an arrival.  A fresh session
        has no partition, so its first observation is always due."""
        idx = self._rows(sessions)
        if idx.size == 0:
            return
        for f in ("anchor_up", "anchor_down", "anchor_speedup"):
            getattr(self, f)[idx] = 0.0
        self.placements[idx] = True
        self.min_cuts[idx] = np.nan
        self.steps[idx] = 0
        self.steps_since[idx] = _NEVER
        self.has_partition[idx] = False
        self.active[idx] = True

    def deactivate(self, sessions) -> None:
        """Mark the given slots free — a departure.  State is cleared at
        the next :meth:`activate`, so a just-departed slot stays
        inspectable until reused."""
        idx = self._rows(sessions)
        self.active[idx] = False

    # -- atomic-tick checkpointing --------------------------------------
    def checkpoint(self) -> tuple:
        """Copies of all mutable arrays (pair with :meth:`restore`)."""
        return tuple(getattr(self, f).copy() for f in _LEAF_FIELDS)

    def restore(self, state: tuple) -> None:
        for f, a in zip(_LEAF_FIELDS, state):
            setattr(self, f, a)

    # -- the vectorized Fig.-1 decision ---------------------------------
    def begin_step(self, envs: EnvArrays) -> np.ndarray:
        """Advance every active session's clock and decide repartitions.

        One vectorized pass replicating
        :meth:`~repro.core.adaptive.AdaptiveController.begin_step` per
        row: clocks advance, the shared drift test runs against the
        anchors, and every due session's anchor moves to today's
        environment with its cooldown reset.  Returns the (k,) bool
        "repartition due" mask (False on inactive slots).

        Like the scalar controller, the decision never depends on solver
        output — which is exactly what lets :func:`tick_sessions` defer
        all due sessions to one coalesced solve flush.
        """
        if envs.k != self.capacity:
            raise ValueError(
                f"envs must carry {self.capacity} rows, got {envs.k}"
            )
        act = self.active
        self.steps[act] += 1
        self.steps_since[act] += 1
        exceeded = drift_exceeded_arrays(
            self.anchor_up,
            self.anchor_down,
            self.anchor_speedup,
            np.asarray(envs.bandwidth_up, dtype=np.float64),
            np.asarray(envs.bandwidth_down, dtype=np.float64),
            np.asarray(envs.speedup, dtype=np.float64),
            self.threshold,
        )
        due = act & (
            ~self.has_partition
            | (exceeded & (self.steps_since >= self.min_interval))
        )
        self.anchor_up = np.where(due, envs.bandwidth_up, self.anchor_up)
        self.anchor_down = np.where(due, envs.bandwidth_down, self.anchor_down)
        self.anchor_speedup = np.where(due, envs.speedup, self.anchor_speedup)
        self.steps_since = np.where(due, 0, self.steps_since)
        self.has_partition = self.has_partition | due
        return due

    # -- commit ----------------------------------------------------------
    def commit_step(
        self,
        due: np.ndarray,
        final_masks: np.ndarray,
        new_min_cuts: np.ndarray,
    ) -> None:
        """Install the tick's resolved placements (due rows only).

        ``final_masks`` is the full (k, n) mask table with non-due rows
        already carrying their current placement (the form
        :func:`tick_sessions` prices), ``new_min_cuts`` likewise (k,).
        """
        self.placements = np.where(due[:, None], final_masks, self.placements)
        self.min_cuts = np.where(due, new_min_cuts, self.min_cuts)


jax.tree_util.register_pytree_node(
    SessionBatch,
    lambda b: (
        tuple(getattr(b, f) for f in _LEAF_FIELDS),
        (b.n, b.threshold, b.min_interval),
    ),
    lambda aux, children: SessionBatch(aux[0], aux[1], aux[2], *children),
)


@dataclasses.dataclass
class SessionTickReport:
    """One batched tick's outcome, as (k,)/(k, n) arrays.

    The array twin of a list of K
    :class:`~repro.core.adaptive.AdaptationEvent` — at 10⁵–10⁶ sessions
    the tick never materializes Python event objects; benchmarks and
    dashboards consume the arrays, and the parity tests call
    :meth:`event` / :meth:`events` to compare individual sessions
    against the serial loop.
    """

    steps: np.ndarray            # (k,) i64 session clocks at this tick
    active: np.ndarray           # (k,) bool
    repartitioned: np.ndarray    # (k,) bool — the tick's due mask
    cache_hit: np.ndarray        # (k,) bool (followers count as hits)
    placements: np.ndarray       # (k, n) bool final masks
    min_cut: np.ndarray          # (k,) f64 installed result cut values
    partial_cost: np.ndarray     # (k,) f64 Eq.-2 price of the final mask
    no_offload_cost: np.ndarray  # (k,) f64 §7.1 all-local baseline
    full_offload_cost: np.ndarray  # (k,) f64 §7.1 baseline
    gain: np.ndarray             # (k,) f64 offloading gain
    envs: EnvArrays              # the observed environments
    hits: int                    # cache hits among due sessions
    solved: int                  # representative solves dispatched
    coalesced: int               # same-bin followers folded into a solve
    due: int                     # sessions repartitioned this tick
    device_summary: dict | None = None  # fused device telemetry (optional)
    # fault-tolerance (resilient ticks only; see tick_sessions(faults=))
    degraded: np.ndarray | None = None  # (k,) bool — rows served a fallback
    retries: int = 0             # solve-flush retries performed this tick
    faults: int = 0              # injected/observed fault events this tick
    breaker_trips: int = 0       # circuit-breaker open transitions

    @property
    def k(self) -> int:
        return int(self.steps.shape[0])

    def event(self, i: int) -> AdaptationEvent:
        """Materialize session ``i``'s tick as a scalar event (parity/
        debugging path — O(1) Python objects per call, never used by the
        hot tick)."""
        return AdaptationEvent(
            step=int(self.steps[i]),
            env=self.envs.env(i),
            result=MCOPResult(
                min_cut=float(self.min_cut[i]),
                local_mask=self.placements[i].copy(),
                phases=[],
            ),
            partial_cost=float(self.partial_cost[i]),
            no_offload_cost=float(self.no_offload_cost[i]),
            full_offload_cost=float(self.full_offload_cost[i]),
            gain=float(self.gain[i]),
            repartitioned=bool(self.repartitioned[i]),
            cache_hit=bool(self.cache_hit[i]),
        )

    def events(self, sessions=None) -> list[AdaptationEvent]:
        """Events for ``sessions`` (default: every active slot, in order)."""
        if sessions is None:
            sessions = np.nonzero(self.active)[0]
        return [self.event(int(i)) for i in np.asarray(sessions).reshape(-1)]

    def summary(self) -> dict:
        """Aggregate telemetry over active sessions (host reduction)."""
        act = self.active
        n_act = max(int(np.count_nonzero(act)), 1)
        return {
            "sessions": int(np.count_nonzero(act)),
            "repartitioned": self.due,
            "cache_hits": self.hits,
            "coalesced": self.coalesced,
            "solved": self.solved,
            "mean_partial_cost": float(self.partial_cost[act].sum() / n_act)
            if act.any()
            else 0.0,
            "mean_gain": float(self.gain[act].sum() / n_act) if act.any() else 0.0,
        }


def tick_sessions(
    batch: SessionBatch,
    envs: EnvArrays,
    *,
    profile: AppProfile,
    model: CostModel,
    cache: PlacementCache,
    backend: str = "jax",
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    device_telemetry: bool = False,
    faults=None,
    resilience=None,
    tick: int = 0,
    sleep=None,
    tracer=None,
    metrics=None,
    mesh=None,
) -> SessionTickReport:
    """One broker tick over all K sessions of ``batch``.

    The whole tick is three vectorized stages (plus O(due-sessions)
    Python for the dict-backed cache probe):

    1. **Decide + probe** — :meth:`SessionBatch.begin_step` takes every
       drift/cooldown decision in one array pass; due sessions' quantized
       keys come from one :meth:`EnvQuantizer.keys_batch` evaluation and
       probe the shared cache in session order (hits see only entries
       stored by *earlier* ticks, exactly like the broker's
       classification loop).
    2. **Solve** — first-miss-per-bin representatives flush through ONE
       :func:`~repro.core.mcop.solve_envs` call; same-bin followers
       coalesce onto their representative.
    3. **Price + commit** — every session's candidate mask (current
       placement for non-due rows, cached/solved masks for due rows) is
       priced in ONE fused ``price_batch``; the §4.3 clamps resolve
       against the same report (representatives by solver cut, hits and
       followers by repriced cost — the shared ``baselines`` strictness),
       placements install, and cache counters/stores record.

    Bit-identity: with ``backend="reference"`` every event this returns
    equals the serial ``BrokerSession`` loop bitwise (see module
    docstring).  With the f32 jax/pallas backends the *solver* may in
    principle resolve an exact cut tie differently than the broker's
    build-f64-then-cast path (same caveat as ``solve_envs``); prices are
    f64 host arithmetic either way.

    Atomic: any failure (solver error, bad environment) restores the
    batch to its pre-tick state and re-raises — no events, no counter or
    cache mutations; retry the whole tick.

    Resilient mode (``faults``/``resilience``, wired by the broker's
    :meth:`~repro.service.broker.OffloadBroker.tick` when it carries a
    :class:`~repro.service.resilience.ResiliencePolicy`): the solve
    flush retries with backoff under the optional circuit breaker, and
    a flush that exhausts its retries *degrades instead of raising* —
    every due miss row is served a fallback mask (stale cached bin if
    one exists, else the §4.3 all-local plan), flagged in
    ``report.degraded``, and its drift anchor is rolled back so the
    session re-partitions on the next clean tick (convergence once the
    fault storm ends, asserted by the chaos suite).  ``tick`` keys the
    deterministic injector; ``sleep`` charges backoff/latency time to
    the caller's clock.  Pricing failures still restore-and-raise (the
    broker contains them to the group).

    Observability (``tracer``/``metrics``, see ``repro.obs``): when
    attached, the tick emits stage spans (drift, cache probe, solve
    flush, pricing, commit) and fault/retry/breaker/degraded events on
    the tracer, and dispatch timings on the registry.  Both default to
    ``None`` and the instrumented paths then run bit-identically to the
    uninstrumented tick — notably they never read the caller's clock.

    Solver fleet (``mesh``, see ``repro.core.mcop_shard``): ``None``
    auto-shards the solve flush across every device the process sees,
    ``False`` forces single-device, a ``Mesh`` shards over that fleet;
    the flush span carries the resolved device count and the sharded
    flush is bit-identical to the single-device one.
    """
    if faults is not None or resilience is not None:
        # deferred: the fault vocabulary lives in the service layer
        from repro.service.faults import InjectedFault, poison_envs
    attempts = resilience.retry.attempts if resilience is not None else 1
    breaker = resilience.breaker if resilience is not None else None
    n_retries = n_faults = n_trips = 0

    def _charge(seconds: float) -> None:
        if sleep is not None and seconds > 0:
            sleep(seconds)

    def _span(name: str, **attrs):
        return tracer.span(name, **attrs) if tracer is not None else NULL_SPAN

    def _event(name: str, **attrs) -> None:
        if tracer is not None:
            tracer.event(name, **attrs)

    state = batch.checkpoint()
    try:
        with _span("stage.drift", tick=tick, sessions=batch.capacity) as sp:
            due = batch.begin_step(envs)
            n = batch.n
            # one vectorized host f64 build: pricing, baselines and clamps
            # for the whole batch (rows bit-identical to cost_model.build)
            wcg_batch = model.build_batch(profile, envs)
            no_off = np.asarray(wcg_batch.w_local).sum(axis=-1)  # (k,)
            sp.set(due=int(np.count_nonzero(due)))

        # ---- stage 1: classify due sessions against the cache ----------
        due_idx = np.nonzero(due)[0]
        keys = cache.quantizer.keys_batch(envs.take(due_idx)) if due_idx.size else None
        hit_idx: list[int] = []
        hit_masks: list[np.ndarray] = []
        solve_idx: list[int] = []
        solve_keys: list[tuple] = []
        fol_idx: list[int] = []
        fol_slot: list[int] = []
        rep_slot: dict[tuple, int] = {}
        with _span("stage.cache_probe", due=int(due_idx.size)) as sp:
            for row, i in enumerate(due_idx):
                key = tuple(int(v) for v in keys[row])
                lost_load = False
                if faults is not None:
                    d = faults.decide("cache_load", tick, int(i))
                    if d.fires:
                        n_faults += 1
                        _event(
                            "fault",
                            site="cache_load",
                            kind=d.kind,
                            tick=tick,
                            index=int(i),
                        )
                        if d.kind == "latency":
                            _charge(d.delay_s)
                        else:
                            lost_load = True  # probe discarded: miss
                mask = None if lost_load else cache.lookup(key, expected_n=n)
                if mask is not None:
                    hit_idx.append(int(i))
                    hit_masks.append(mask)
                    continue
                slot = rep_slot.get(key)
                if slot is None:
                    rep_slot[key] = len(solve_idx)
                    solve_idx.append(int(i))
                    solve_keys.append(key)
                else:
                    fol_idx.append(int(i))
                    fol_slot.append(slot)
            sp.set(
                hits=len(hit_idx),
                misses=len(solve_idx),
                coalesced=len(fol_idx),
            )

        # ---- stage 2: ONE solve flush for the distinct-bin misses ------
        # Resilient mode retries the flush (injector consulted per
        # attempt, breaker picks the effective backend); exhaustion
        # QUARANTINES the flush: every miss row degrades to a fallback
        # mask below instead of aborting the whole tick.
        solved: list | None = [] if not solve_idx else None
        if solve_idx:
            from repro.core.mcop_shard import resolve_mesh, solver_shards

            use_mesh = resolve_mesh(mesh)
            devices = 1 if use_mesh is None else solver_shards(use_mesh)
            sub = envs.take(solve_idx)
            with _span(
                "stage.solve_flush",
                batch=len(solve_idx),
                backend=backend,
                tick=tick,
                devices=devices,
            ):
                for attempt in range(attempts):
                    if attempt:
                        n_retries += 1
                        _event(
                            "retry", site="solve", attempt=attempt, tick=tick
                        )
                        _charge(resilience.retry.backoff(attempt - 1))
                    eff = (
                        breaker.backend(backend, tick)
                        if breaker is not None
                        else backend
                    )
                    use = sub
                    try:
                        if faults is not None:
                            d = faults.decide("solve", tick, attempt)
                            if d.fires:
                                n_faults += 1
                                _event(
                                    "fault",
                                    site="solve",
                                    kind=d.kind,
                                    tick=tick,
                                    index=attempt,
                                )
                                if d.kind == "latency":
                                    _charge(d.delay_s)
                                elif d.kind == "error":
                                    raise InjectedFault(
                                        "solve", tick, attempt
                                    )
                                else:
                                    use = poison_envs(sub)
                        out = solve_envs(
                            profile,
                            model,
                            use,
                            backend=eff,
                            buckets=buckets,
                            metrics=metrics,
                            # already resolved: span attr and dispatch
                            # must agree on the device count
                            mesh=use_mesh if use_mesh is not None else False,
                            tracer=tracer,
                        )
                        if not all(np.isfinite(r.min_cut) for r in out):
                            raise RuntimeError(
                                "non-finite min_cut from solve flush"
                            )
                        if breaker is not None:
                            breaker.record_success(eff)
                        solved = out
                        break
                    except Exception:
                        if breaker is not None and breaker.record_failure(
                            eff, tick
                        ):
                            n_trips += 1
                            _event(
                                "breaker_trip", backend=eff, tick=tick
                            )
                        if resilience is None:
                            raise
        deg_idx: list[int] = []
        if solved is None:
            # flush quarantined: reps AND their followers fall back to
            # the stale cached bin (uncounted probe) or the §4.3
            # all-local plan; their drift anchors roll back after commit
            # so each retries on the next clean tick
            deg_idx = solve_idx + fol_idx
            deg_keys = solve_keys + [solve_keys[s] for s in fol_slot]
            deg_masks = []
            for key in deg_keys:
                m = cache.lookup(key, expected_n=n)
                deg_masks.append(
                    np.ones(n, dtype=bool) if m is None else m
                )
            solve_idx, solve_keys, fol_idx, fol_slot = [], [], [], []
            solved = []
            _event("degraded", sessions=len(deg_idx), tick=tick)
        solver_cuts = np.array([r.min_cut for r in solved], dtype=np.float64)
        solved_masks = (
            np.stack([r.local_mask for r in solved]).astype(bool)
            if solved
            else np.zeros((0, n), dtype=bool)
        )
        # §4.3 clamp of representatives: strictly cheaper all-local plan
        # wins, judged against the solver's own cut value (the comparison
        # clamp_no_offloading_priced applies)
        rep_clamped = (
            no_off[solve_idx] < solver_cuts
            if solve_idx
            else np.zeros(0, dtype=bool)
        )

        # ---- stage 3: ONE fused pricing pass over candidate masks ------
        rows = batch.placements.copy()
        sel = np.zeros(batch.capacity, dtype=bool)  # rows clamped by price
        if hit_idx:
            rows[hit_idx] = np.stack(hit_masks)
            sel[hit_idx] = True
        if solve_idx:
            rows[solve_idx] = np.where(
                rep_clamped[:, None], True, solved_masks
            )
        if fol_idx:
            # followers carry their representative's mask: the FINAL
            # (clamped) one — all-local when the rep clamped, whose price
            # is exactly the no-offload baseline, so the select below is
            # a no-op for them (matching the broker's explicit all-local
            # follower reply) — the RAW solved mask otherwise.
            slots = np.asarray(fol_slot)
            rows[fol_idx] = np.where(
                rep_clamped[slots][:, None], True, solved_masks[slots]
            )
            sel[fol_idx] = True
        if deg_idx:
            # quarantined rows price exactly like hit rows: the shared
            # §4.3 select below clamps a fallback that is worse than
            # all-local onto the all-ones plan
            rows[deg_idx] = np.stack(deg_masks)
            sel[deg_idx] = True
        report = None
        with _span("stage.pricing", rows=batch.capacity, tick=tick):
            for attempt in range(attempts):
                if attempt:
                    n_retries += 1
                    _event(
                        "retry", site="pricing", attempt=attempt, tick=tick
                    )
                    _charge(resilience.retry.backoff(attempt - 1))
                try:
                    if faults is not None:
                        d = faults.decide("pricing", tick, attempt)
                        if d.fires:
                            n_faults += 1
                            _event(
                                "fault",
                                site="pricing",
                                kind=d.kind,
                                tick=tick,
                                index=attempt,
                            )
                            if d.kind == "latency":
                                _charge(d.delay_s)
                            else:
                                raise InjectedFault("pricing", tick, attempt)
                    if metrics is not None:
                        with metrics.timer("price_batch_duration_s"):
                            report = pricing.price_batch(wcg_batch, rows)
                    else:
                        report = pricing.price_batch(wcg_batch, rows)
                    break
                except Exception:
                    if resilience is None:
                        raise
        if report is None:
            # pricing exhausted its retries: without prices no honest
            # event can be emitted — restore and let the broker contain
            # the failure to this group (staged observation retries)
            raise RuntimeError("pricing exhausted retries; tick aborted")
        partial = np.asarray(report.partial_cost, dtype=np.float64)
        # shared §4.3 strictness: hits/followers whose all-local baseline
        # is strictly cheaper flip to the all-ones plan (reprice_clamped)
        clamped = sel & (no_off < partial)
        rows[clamped] = True
        partial = np.where(clamped, no_off, partial)

        new_min_cuts = batch.min_cuts.copy()
        sel_rows = np.nonzero(sel)[0]
        # hit/follower result cut = repriced (possibly clamped) cost,
        # exactly reprice_clamped_priced's min_cut
        new_min_cuts[sel_rows] = partial[sel_rows]
        if solve_idx:
            # representative result keeps the solver's own cut value
            # unless clamped to the baseline (clamp_no_offloading_priced)
            new_min_cuts[solve_idx] = np.where(
                rep_clamped, no_off[solve_idx], solver_cuts
            )
    except BaseException:
        batch.restore(state)
        raise

    # ---- success: counters, stores, state install (infallible) ---------
    # degraded rows count as misses (they did miss; the fallback is a
    # served answer, not a cache hit) and never store
    with _span("stage.commit", stores=len(solve_idx), tick=tick):
        cache.record_many(
            hits=len(hit_idx), misses=len(solve_idx) + len(deg_idx)
        )
        cache.record_many(hits=len(fol_idx))  # followers hit rep's store
        for slot, i in enumerate(solve_idx):
            if faults is not None:
                d = faults.decide("cache_store", tick, slot)
                if d.fires:
                    n_faults += 1
                    _event(
                        "fault",
                        site="cache_store",
                        kind=d.kind,
                        tick=tick,
                        index=slot,
                    )
                    if d.kind == "latency":
                        _charge(d.delay_s)
                    else:
                        continue  # store dropped: the bin re-solves later
            cache.store(solve_keys[slot], rows[i])
        batch.commit_step(due, rows, new_min_cuts)
    degraded_rows = None
    if deg_idx:
        # roll the quarantined sessions' decision state back to the
        # pre-tick checkpoint (clock keeps ticking): the drift test
        # re-fires next tick, so they converge once faults stop
        idx = np.asarray(deg_idx, dtype=np.int64)
        chk = dict(zip(_LEAF_FIELDS, state))
        for f in (
            "anchor_up",
            "anchor_down",
            "anchor_speedup",
            "steps_since",
            "has_partition",
        ):
            getattr(batch, f)[idx] = chk[f][idx]
        degraded_rows = np.zeros(batch.capacity, dtype=bool)
        degraded_rows[idx] = True

    cache_hit = np.zeros(batch.capacity, dtype=bool)
    cache_hit[hit_idx] = True
    cache_hit[fol_idx] = True
    tick_report = SessionTickReport(
        steps=batch.steps.copy(),
        active=batch.active.copy(),
        repartitioned=due,
        cache_hit=cache_hit,
        placements=rows,
        min_cut=batch.min_cuts.copy(),
        partial_cost=partial,
        no_offload_cost=no_off,
        full_offload_cost=np.asarray(report.full_offload_cost, dtype=np.float64),
        gain=pricing.vector_gain(no_off, partial),
        envs=envs,
        hits=len(hit_idx),
        solved=len(solve_idx),
        coalesced=len(fol_idx),
        due=int(due_idx.size),
        degraded=degraded_rows,
        retries=n_retries,
        faults=n_faults,
        breaker_trips=n_trips,
    )
    if device_telemetry:
        tick_report.device_summary = pricing.device_price_summary(
            profile, model, envs, rows, active=batch.active
        )
    return tick_report
