"""Baselines the paper compares against, plus independent optimality oracles.

* :func:`no_offloading` / :func:`full_offloading` — the paper's §7.1
  comparison schemes.
* :func:`brute_force` — exhaustive enumeration over all 2^k placements of
  the k offloadable vertices (vectorised).  Exponential; the ground-truth
  oracle for property tests.
* :func:`branch_and_bound` — the paper's stand-in for the MAUI/CloneCloud
  "LP solver" (§5.4): best-first branch and bound with an admissible
  lower bound.  Exact, exponential worst case; used by the Fig. 14
  complexity benchmark.
* :func:`maxflow_optimal` — exact polynomial solution via the classical
  min s–t cut reduction (project-selection construction).  The paper does
  not include this; we add it as a second, *independent* oracle and as the
  beyond-paper "exact and still polynomial" reference point.
* :func:`chain_dp` — O(n) dynamic program for linear topologies (the
  Fig. 2(b) case; the [11]-style sequential-call baseline).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque

import numpy as np

from repro.core.graph import WCG

__all__ = [
    "PartitionResult",
    "no_offloading",
    "full_offloading",
    "clamp_no_offloading",
    "clamp_no_offloading_priced",
    "reprice_clamped",
    "reprice_clamped_priced",
    "reprice_clamped_rows",
    "brute_force",
    "branch_and_bound",
    "maxflow_optimal",
    "chain_dp",
]


@dataclasses.dataclass
class PartitionResult:
    cost: float
    local_mask: np.ndarray
    nodes_expanded: int = 0  # search effort (branch & bound reporting)


# ----------------------------------------------------------------------
# Trivial schemes (§7.1)
# ----------------------------------------------------------------------


def no_offloading(g: WCG) -> PartitionResult:
    mask = np.ones(g.n, dtype=bool)
    return PartitionResult(cost=g.total_cost(mask), local_mask=mask)


def full_offloading(g: WCG) -> PartitionResult:
    """Everything offloadable goes to the cloud (unoffloadables stay)."""
    mask = ~g.offloadable
    return PartitionResult(cost=g.total_cost(mask), local_mask=mask)


def clamp_no_offloading(g: WCG, result):
    """Paper §4.3: "we only actually perform the partitioning when it is
    beneficial" — MCOP's phase cuts always offload a non-empty set, so the
    all-local plan must be compared explicitly (Fig. 17's partial curve
    coinciding with no-offloading at low bandwidth).

    Takes and returns an :class:`~repro.core.mcop.MCOPResult`; shared by
    the adaptive controller and the placement mapper so the two paths can
    never disagree about when offloading is beneficial.
    """
    from repro.core.mcop import MCOPResult  # deferred: avoid import cycle

    no_off = no_offloading(g)
    if no_off.cost < result.min_cut:
        return MCOPResult(
            min_cut=no_off.cost,
            local_mask=no_off.local_mask,
            phases=result.phases,
        )
    return result


def reprice_clamped(g: WCG, local_mask):
    """Price a *reused* placement mask under the exact current WCG, then
    apply the §4.3 beneficial-only clamp.

    This is the honesty contract for every cached/coalesced placement:
    the mask may come from a same-bin neighbour environment, but the
    reported cost is always ``g.total_cost(mask)`` at today's prices.
    Shared by the adaptive controller (cache hits, in-sweep reuse) and
    the offload broker (hits and coalesced followers), so the serial and
    served paths can never disagree.
    """
    mask = np.asarray(local_mask, dtype=bool)
    return reprice_clamped_priced(g.total_cost(mask), float(g.w_local.sum()), mask)


def clamp_no_offloading_priced(candidate, no_off_cost: float):
    """:func:`clamp_no_offloading` from a PRECOMPUTED all-local baseline.

    The fused pricing paths (sweep pass 2, the broker tick, the
    placement tier sweep) obtain their no-offloading costs from one
    vectorized evaluation; this is the single place their §4.3 clamp
    lives, so a strictness or mask-construction change can never
    desynchronize them from the scalar path.  ``no_off_cost`` must equal
    ``no_offloading(g).cost`` for the candidate's graph (the batched
    baselines are bit-identical to it — see ``repro.core.pricing``).
    """
    from repro.core.mcop import MCOPResult  # deferred: avoid import cycle

    if no_off_cost < candidate.min_cut:
        return MCOPResult(
            min_cut=float(no_off_cost),
            local_mask=np.ones(len(candidate.local_mask), dtype=bool),
            phases=candidate.phases,
        )
    return candidate


def reprice_clamped_priced(partial_cost: float, no_off_cost: float, local_mask):
    """:func:`reprice_clamped` from precomputed batch pricing.

    ``partial_cost`` must equal ``g.total_cost(local_mask)`` and
    ``no_off_cost`` the graph's all-local baseline; the reused mask is
    kept at the repriced cost, or replaced by the all-local plan when
    the baseline is strictly cheaper (§4.3).
    """
    from repro.core.mcop import MCOPResult  # deferred: avoid import cycle

    mask = np.asarray(local_mask, dtype=bool)
    if no_off_cost < partial_cost:
        return MCOPResult(
            min_cut=float(no_off_cost),
            local_mask=np.ones(mask.shape[0], dtype=bool),
            phases=[],
        )
    return MCOPResult(
        min_cut=float(partial_cost), local_mask=mask.copy(), phases=[]
    )


def reprice_clamped_rows(
    partial_cost: np.ndarray, no_off_cost: np.ndarray, local_masks: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :func:`reprice_clamped_priced` over K rows at once.

    Same strict-`<` §4.3 comparison, applied elementwise: row ``i`` of the
    returned ``(min_cut (k,), masks (k, n), clamped (k,))`` equals the
    scalar helper on ``(partial_cost[i], no_off_cost[i], local_masks[i])``
    — the batched session tick resolves every cache-hit and coalesced
    follower through this in one pass.  ``masks`` is a fresh array; rows
    where the all-local baseline is strictly cheaper come back all-True
    with ``min_cut == no_off_cost`` (whose price is bit-identical to
    re-pricing the all-ones mask — a False cut contributes exactly 0.0).
    """
    partial_cost = np.asarray(partial_cost, dtype=np.float64)
    no_off_cost = np.asarray(no_off_cost, dtype=np.float64)
    masks = np.asarray(local_masks, dtype=bool).copy()
    clamped = no_off_cost < partial_cost
    masks[clamped] = True
    return np.where(clamped, no_off_cost, partial_cost), masks, clamped


# ----------------------------------------------------------------------
# Brute force (vectorised) — ground-truth oracle
# ----------------------------------------------------------------------


def brute_force(g: WCG, *, max_free: int = 22) -> PartitionResult:
    free = np.nonzero(g.offloadable)[0]
    k = free.size
    if k > max_free:
        raise ValueError(f"brute force limited to {max_free} free vertices, got {k}")
    m = 1 << k
    # (m, k) bit table: 1 == run locally
    bits = (np.arange(m, dtype=np.int64)[:, None] >> np.arange(k)) & 1
    placements = np.ones((m, g.n), dtype=bool)
    placements[:, free] = bits.astype(bool)

    node_cost = placements @ g.w_local + (~placements) @ g.w_cloud
    iu, ju = np.nonzero(np.triu(g.adj))
    w_e = g.adj[iu, ju]
    cut = placements[:, iu] != placements[:, ju]
    comm_cost = cut @ w_e
    total = node_cost + comm_cost
    best = int(np.argmin(total))
    return PartitionResult(
        cost=float(total[best]), local_mask=placements[best], nodes_expanded=m
    )


# ----------------------------------------------------------------------
# Branch and bound — the paper's "LP solver" comparator (§5.4)
# ----------------------------------------------------------------------


def branch_and_bound(g: WCG, *, node_limit: int = 5_000_000) -> PartitionResult:
    """Best-first B&B over vertex assignments.

    Lower bound for a partial assignment: committed node+cut cost, plus
    Σ min(w_local, w_cloud) over unassigned vertices (edges among or to
    unassigned vertices are optimistically free).  Admissible ⇒ exact.
    """
    n = g.n
    order = np.argsort(-(np.abs(g.gains)))  # decide high-impact vertices first
    order = np.concatenate(
        [order[~g.offloadable[order]], order[g.offloadable[order]]]
    )
    opt_rest = np.zeros(n + 1)
    mins = np.minimum(g.w_local, g.w_cloud)[order]
    opt_rest[:n] = np.cumsum(mins[::-1])[::-1]

    expanded = 0
    best_cost = np.inf
    best_mask = np.ones(n, dtype=bool)
    # heap items: (bound, counter, depth, assignment list)
    heap = [(opt_rest[0], 0, 0, ())]
    counter = itertools.count(1)
    while heap:
        bound, _, depth, assign = heapq.heappop(heap)
        if bound >= best_cost:
            break
        expanded += 1
        if expanded > node_limit:
            raise RuntimeError("branch_and_bound node limit exceeded")
        if depth == n:
            mask = np.ones(n, dtype=bool)
            for d, a in enumerate(assign):
                mask[order[d]] = bool(a)
            cost = g.total_cost(mask)
            if cost < best_cost:
                best_cost, best_mask = cost, mask
            continue
        v = order[depth]
        choices = (True,) if not g.offloadable[v] else (True, False)
        for local in choices:
            new_assign = assign + (local,)
            # committed cost: nodes decided so far + cut edges both of whose
            # endpoints are decided.
            cost = 0.0
            for d, a in enumerate(new_assign):
                u = order[d]
                cost += g.w_local[u] if a else g.w_cloud[u]
                for d2 in range(d):
                    u2 = order[d2]
                    if g.adj[u, u2] and (a != new_assign[d2]):
                        cost += g.adj[u, u2]
            bound = cost + opt_rest[depth + 1]
            if bound < best_cost:
                heapq.heappush(heap, (bound, next(counter), depth + 1, new_assign))
    return PartitionResult(
        cost=float(best_cost), local_mask=best_mask, nodes_expanded=expanded
    )


# ----------------------------------------------------------------------
# Exact polynomial oracle: min s–t cut via max-flow (Dinic)
# ----------------------------------------------------------------------


class _Dinic:
    def __init__(self, n: int):
        self.n = n
        self.head: list[list[int]] = [[] for _ in range(n)]
        self.to: list[int] = []
        self.cap: list[float] = []

    def add_edge(self, u: int, v: int, c: float) -> None:
        self.head[u].append(len(self.to))
        self.to.append(v)
        self.cap.append(float(c))
        self.head[v].append(len(self.to))
        self.to.append(u)
        self.cap.append(0.0)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: float) -> float:
        if u == t:
            return f
        while self.it[u] < len(self.head[u]):
            eid = self.head[u][self.it[u]]
            v = self.to[eid]
            if self.cap[eid] > 1e-12 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]))
                if d > 1e-12:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            self.it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        flow = 0.0
        while self._bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self._dfs(s, t, np.inf)
                if f <= 1e-12:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> np.ndarray:
        """Vertices reachable from s in the residual graph (source side)."""
        seen = np.zeros(self.n, dtype=bool)
        seen[s] = True
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and not seen[v]:
                    seen[v] = True
                    q.append(v)
        return seen


def maxflow_optimal(g: WCG) -> PartitionResult:
    """Exact optimum of Eq. 2 via the min s–t cut reduction.

    Construction (source side == local tier):
      * s → v with capacity w_cloud(v)   (pay w_cloud iff v ends up remote)
      * v → t with capacity w_local(v)   (pay w_local iff v stays local)
      * u ↔ v with capacity w(e(u, v))   (pay comm iff the edge is cut)
      * s → v with capacity ∞ for unoffloadable v (pins v to the local side)

    The value of the min cut equals min_I C_total(I).
    """
    n = g.n
    s, t = n, n + 1
    net = _Dinic(n + 2)
    big = float(g.w_local.sum() + g.w_cloud.sum() + g.adj.sum() + 1.0)
    for v in range(n):
        cap_s = g.w_cloud[v] + (0.0 if g.offloadable[v] else big)
        if cap_s > 0:
            net.add_edge(s, v, cap_s)
        if g.w_local[v] > 0:
            net.add_edge(v, t, g.w_local[v])
    iu, ju = np.nonzero(np.triu(g.adj))
    for u, v in zip(iu, ju):
        net.add_edge(int(u), int(v), g.adj[u, v])
        net.add_edge(int(v), int(u), g.adj[u, v])
    flow = net.max_flow(s, t)
    local_mask = net.min_cut_side(s)[:n]
    # Degenerate zero-capacity vertices may be unreachable yet must stay
    # local when pinned; enforce and recompute the (equal) cost.
    local_mask |= ~g.offloadable
    return PartitionResult(cost=float(g.total_cost(local_mask)), local_mask=local_mask,
                           nodes_expanded=int(flow == flow))


# ----------------------------------------------------------------------
# Linear-chain dynamic program (Fig. 2(b) topologies)
# ----------------------------------------------------------------------


def chain_dp(g: WCG) -> PartitionResult:
    """O(n) DP for chains: state = (position, side).  Exact for linear WCGs."""
    n = g.n
    for i in range(n):
        for j in range(i + 1, n):
            if g.adj[i, j] and j != i + 1:
                raise ValueError("chain_dp requires a linear topology")
    INF = np.inf
    # dp[side] at vertex i; side 0 = local, 1 = cloud
    dp = np.array(
        [g.w_local[0], g.w_cloud[0] if g.offloadable[0] else INF]
    )
    choice = np.zeros((n, 2), dtype=np.int8)
    for i in range(1, n):
        w_edge = g.adj[i - 1, i]
        here = np.array(
            [g.w_local[i], g.w_cloud[i] if g.offloadable[i] else INF]
        )
        new_dp = np.full(2, INF)
        for side in range(2):
            for prev in range(2):
                c = dp[prev] + here[side] + (w_edge if prev != side else 0.0)
                if c < new_dp[side]:
                    new_dp[side] = c
                    choice[i, side] = prev
        dp = new_dp
    side = int(np.argmin(dp))
    mask = np.zeros(n, dtype=bool)
    for i in range(n - 1, -1, -1):
        mask[i] = side == 0
        side = int(choice[i, side])
    return PartitionResult(cost=float(np.min(dp)), local_mask=mask)
