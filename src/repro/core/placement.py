"""Placement mapper: MCOP partitions → executable distribution artifacts.

This is where the paper's output (a vertex bipartition of the WCG) becomes
something a TPU runtime can act on.  The vertices of the framework-level
WCG are *stages* (embedding, transformer block groups, head, frontends);
the two sides are *tiers* (e.g. pod-0 vs pod-1, or HBM vs host).  The
mapper produces:

* a per-stage tier assignment (the raw MCOP answer),
* a *contiguous pipeline split* for chain-structured models — pipeline
  execution over the ``pod`` mesh axis needs contiguous stage ranges, so
  the mapper computes the optimal contiguous refinement (exact scan over
  boundaries) and reports the contiguity penalty vs. the unconstrained
  MCOP cut,
* cut-edge statistics (activation bytes crossing tiers per microbatch)
  that the runtime uses to size `ppermute` transfers and that the
  roofline analysis charges to the collective term.

Tier and stage descriptions are deliberately analytic (FLOPs, bytes) so
the same machinery serves the dry-run (no hardware) and a real cluster
(profiled numbers swap in transparently — same WCG shape).

For sweeps over link conditions (elastic events, bandwidth forecasts),
:func:`plan_placement_batch` solves every point in one ``mcop_batch``
dispatch instead of one MCOP trace per point.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import baselines
from repro.core.graph import WCG, WCGBatch
from repro.core.mcop import DEFAULT_BUCKETS, MCOPResult, _bucket_size, mcop, mcop_batch

__all__ = [
    "TierSpec",
    "StageSpec",
    "TPUV5E_TIER",
    "build_stage_wcg",
    "PlacementPlan",
    "plan_placement",
    "plan_placement_batch",
]


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One side of the offloading decision: a set of chips (or the host).

    peak_flops:  per-chip peak (bf16 FLOP/s)
    hbm_bw:      per-chip HBM bytes/s
    chips:       chips in the tier
    link_bw:     bytes/s available *to the other tier* (DCN / ICI / PCIe)
    p_compute/p_idle/p_transfer: per-chip watts for the energy model
    """

    name: str
    chips: int
    peak_flops: float
    hbm_bw: float
    link_bw: float
    p_compute: float = 250.0
    p_idle: float = 60.0
    p_transfer: float = 40.0

    @property
    def total_flops(self) -> float:
        return self.chips * self.peak_flops

    @property
    def total_hbm_bw(self) -> float:
        return self.chips * self.hbm_bw


# TPU v5e constants used throughout the roofline analysis.
TPUV5E_TIER = TierSpec(
    name="v5e-pod",
    chips=256,
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
)


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One vertex of the framework-level WCG.

    flops:          FLOPs per step for this stage (fwd+bwd for training).
    bytes_hbm:      HBM traffic per step (weights + activations).
    act_bytes_out:  activation bytes flowing to each successor per step —
                    the WCG edge weight numerator (Eq. 1's in/out data).
    pinned_tier:    None = offloadable; 0/1 = must run on that tier
                    (paper's unoffloadable tasks: ingest, sampler, IO).
    """

    name: str
    flops: float
    bytes_hbm: float
    act_bytes_out: float
    params_bytes: float = 0.0
    pinned_tier: int | None = None
    successors: tuple[int, ...] = ()  # stage indices; default: next in chain


def _stage_time(stage: StageSpec, tier: TierSpec) -> float:
    """Roofline step-time estimate of a stage on a tier: max(compute, memory)."""
    return max(stage.flops / tier.total_flops, stage.bytes_hbm / tier.total_hbm_bw)


def build_stage_wcg(
    stages: Sequence[StageSpec],
    tier_local: TierSpec,
    tier_remote: TierSpec,
    *,
    inter_tier_bw: float | None = None,
) -> WCG:
    """Stage chain/graph → WCG under the response-time cost model.

    ``w_local``/``w_cloud`` are roofline step times on the two tiers;
    edges charge activation transfer over the inter-tier link (Eq. 1 with
    B_up = B_down = link bandwidth).  Stages pinned to the remote tier are
    encoded with an infinite local cost (and vice versa via
    ``offloadable=False``).
    """
    n = len(stages)
    bw = inter_tier_bw or min(tier_local.link_bw, tier_remote.link_bw)
    w_local = np.zeros(n)
    w_cloud = np.zeros(n)
    offloadable = np.ones(n, dtype=bool)
    adj = np.zeros((n, n))
    big = 0.0
    for i, st in enumerate(stages):
        w_local[i] = _stage_time(st, tier_local)
        w_cloud[i] = _stage_time(st, tier_remote)
        big += w_local[i] + w_cloud[i]
    for i, st in enumerate(stages):
        succ = st.successors if st.successors else ((i + 1,) if i + 1 < n else ())
        for j in succ:
            w = st.act_bytes_out / bw
            adj[i, j] += w
            adj[j, i] += w
        if st.pinned_tier == 0:
            offloadable[i] = False
        elif st.pinned_tier == 1:
            # pin to remote: make local execution prohibitively expensive
            w_local[i] = big * 1e3 + w_local[i]
    names = [s.name for s in stages]
    return WCG(w_local, w_cloud, adj, offloadable, names=names)


@dataclasses.dataclass
class PlacementPlan:
    """Executable outcome of one MCOP run over a stage graph."""

    stage_tier: np.ndarray        # (n,) int — 0 local tier, 1 remote tier
    mcop_cost: float              # unconstrained MCOP cut value
    contiguous_boundary: int      # stages [0, b) on tier0, [b, n) on tier1
    contiguous_cost: float        # cost of the contiguous refinement
    contiguity_penalty: float     # contiguous_cost − mcop_cost (≥ −eps)
    cut_bytes: float              # activation bytes crossing tiers per step
    result: MCOPResult

    @property
    def is_split(self) -> bool:
        return 0 < self.contiguous_boundary < self.stage_tier.shape[0]

    def tier_stages(self, tier: int) -> np.ndarray:
        return np.nonzero(self.stage_tier == tier)[0]


def _contiguous_refinement(g: WCG) -> tuple[int, float]:
    """Best chain split: stages [0, b) local, [b, n) remote.  Exact O(n²).

    b == n means everything local (no offloading); b == 0 would violate
    pinned-local stages, so b ranges over [1, n].
    """
    n = g.n
    best_b, best_cost = n, np.inf
    for b in range(1, n + 1):
        mask = np.zeros(n, dtype=bool)
        mask[:b] = True
        if np.any(~mask & ~g.offloadable):
            continue  # would offload a pinned stage
        cost = g.total_cost(mask)
        if cost < best_cost:
            best_b, best_cost = b, cost
    return best_b, float(best_cost)


def _finalize_plan(g: WCG, result: MCOPResult, bw: float) -> PlacementPlan:
    """Partition result → executable plan (tiering, contiguity, cut bytes)."""
    tier = (~result.local_mask).astype(np.int32)
    boundary, contig_cost = _contiguous_refinement(g)
    cut = result.local_mask[:, None] != result.local_mask[None, :]
    # row-major reduction, matching the vectorized batch finalization
    cut_bytes = float((g.adj * cut).sum(axis=-1).sum() / 2.0 * bw)
    return PlacementPlan(
        stage_tier=tier,
        mcop_cost=float(result.min_cut),
        contiguous_boundary=boundary,
        contiguous_cost=contig_cost,
        contiguity_penalty=float(contig_cost - result.min_cut),
        cut_bytes=cut_bytes,
        result=result,
    )


def plan_placement(
    stages: Sequence[StageSpec],
    tier_local: TierSpec,
    tier_remote: TierSpec,
    *,
    backend: str = "reference",
    exact: bool = False,
    inter_tier_bw: float | None = None,
) -> PlacementPlan:
    """Run the partitioning pass and derive the pipeline plan.

    ``exact=True`` swaps MCOP for the max-flow oracle (beyond-paper exact
    mode); the default follows the paper.
    """
    g = build_stage_wcg(stages, tier_local, tier_remote, inter_tier_bw=inter_tier_bw)
    if exact:
        pr = baselines.maxflow_optimal(g)
        result = MCOPResult(min_cut=pr.cost, local_mask=pr.local_mask, phases=[])
    else:
        result = baselines.clamp_no_offloading(g, mcop(g, backend=backend))
    bw = inter_tier_bw or min(tier_local.link_bw, tier_remote.link_bw)
    return _finalize_plan(g, result, bw)


def _contiguous_costs_batch(batch: WCGBatch) -> np.ndarray:
    """Vectorized :func:`_contiguous_refinement` scan over an unpadded batch.

    Returns (k, n) Eq.-2 costs where column ``j`` is the chain split
    ``b = j + 1`` (stages [0, b) local); splits that would offload a
    pinned stage are ``inf``.  Row reductions match the scalar
    ``g.total_cost`` order bit-for-bit, so ``argmin`` resolves exact ties
    to the same boundary the serial first-minimum scan picks.
    """
    wl = np.asarray(batch.w_local)
    wc = np.asarray(batch.w_cloud)
    adj = np.asarray(batch.adj)
    pin = np.asarray(batch.pinned, dtype=bool)
    k, m = wl.shape
    bmasks = np.tril(np.ones((m, m), dtype=bool))  # row j: [0, j] local
    node = np.where(bmasks[None], wl[:, None, :], wc[:, None, :]).sum(axis=-1)
    cut = bmasks[:, :, None] != bmasks[:, None, :]
    comm = np.empty((k, m))
    # chunk the boundary axis: the (k, nb, m, m) temp stays bounded while
    # per-(row, boundary) reduction order — hence bit-parity — is untouched
    step = max(1, int(4_000_000 // max(k * m * m, 1)))
    for s in range(0, m, step):
        comm[:, s : s + step] = (
            adj[:, None, :, :] * cut[None, s : s + step]
        ).sum(axis=-1).sum(axis=-1) / 2.0
    viol = (~bmasks[None, :, :] & pin[:, None, :]).any(axis=-1)
    return np.where(viol, np.inf, node + comm)


def plan_placement_batch(
    stages: Sequence[StageSpec],
    tier_local: TierSpec,
    tier_remote: TierSpec,
    *,
    inter_tier_bws: Sequence[float],
    backend: str = "jax",
) -> list[PlacementPlan]:
    """Tier sweep: one plan per inter-tier bandwidth, solved in ONE batch.

    The elastic/adaptive loops re-plan as link conditions change; sweeping
    candidate bandwidths (or forecast bands) costs one device dispatch for
    the whole sweep instead of one trace per point.  Array-native: the
    stage graph is rooflined ONCE (node weights don't depend on the link),
    the K adjacencies are a single broadcast edge rescale (Eq. 1: edges
    are ``bytes/B``), the stacked :class:`~repro.core.graph.WCGBatch`
    goes straight into :func:`mcop_batch`, and the *pricing* side of the
    plans — §4.3 clamp baselines, cut-byte statistics and the contiguous
    refinement scan — is one vectorized evaluation over the sweep instead
    of O(k·n) scalar ``total_cost`` calls.  Results match calling
    :func:`plan_placement` per bandwidth (boundaries and tiers exactly).

    Args:
      stages:         the framework-level WCG vertices (chain order).
      tier_local/tier_remote: the two placement sides.
      inter_tier_bws: K link bandwidths (bytes/s); 0/None falls back to
        ``min(link_bw)`` exactly like :func:`plan_placement`.
      backend:        MCOP batch backend for the solve.
    Returns:
      list of K :class:`PlacementPlan`, in ``inter_tier_bws`` order.
    """
    # same None/0 fallback plan_placement applies, so results really match
    bws = [
        bw or min(tier_local.link_bw, tier_remote.link_bw) for bw in inter_tier_bws
    ]
    base = build_stage_wcg(stages, tier_local, tier_remote, inter_tier_bw=1.0)
    k, n = len(bws), base.n
    scale = np.asarray(bws, dtype=np.float64)
    batch = WCGBatch.pack(
        np.broadcast_to(base.w_local, (k, n)),
        np.broadcast_to(base.w_cloud, (k, n)),
        base.adj[None] / scale[:, None, None],
        np.broadcast_to(base.offloadable, (k, n)),
        m=_bucket_size(n, DEFAULT_BUCKETS),
        names=base.names,
    )
    results = mcop_batch(batch, backend=backend)

    # ---- vectorized finalization (the sweep's pricing side) -----------
    # Unpadded pricing view: host reductions on (k, n[, n]) tensors are
    # bit-identical to the scalar per-plan path (see WCG.total_cost).
    price = WCGBatch(
        np.ascontiguousarray(batch.w_local[:, :n]),
        np.ascontiguousarray(batch.w_cloud[:, :n]),
        np.ascontiguousarray(batch.adj[:, :n, :n]),
        np.ascontiguousarray(batch.pinned[:, :n]),
        n_valid=(n,) * k,
        names=base.names,
    )
    no_off = np.asarray(price.w_local).sum(axis=-1)  # §7.1 all-local baseline
    clamped = [
        baselines.clamp_no_offloading_priced(r, float(no_off[i]))  # §4.3
        for i, r in enumerate(results)
    ]
    final_masks = np.stack([r.local_mask for r in clamped])
    mcop_costs = np.array([r.min_cut for r in clamped])
    cut = final_masks[:, :, None] != final_masks[:, None, :]
    cut_bytes = (
        (np.asarray(price.adj) * cut).sum(axis=-1).sum(axis=-1) / 2.0 * scale
    )
    ccosts = _contiguous_costs_batch(price)
    b_idx = np.argmin(ccosts, axis=-1)  # first minimum, like the serial scan

    return [
        PlacementPlan(
            stage_tier=(~final_masks[i]).astype(np.int32),
            mcop_cost=float(mcop_costs[i]),
            contiguous_boundary=int(b_idx[i]) + 1,
            contiguous_cost=float(ccosts[i, b_idx[i]]),
            contiguity_penalty=float(ccosts[i, b_idx[i]] - mcop_costs[i]),
            cut_bytes=float(cut_bytes[i]),
            result=result,
        )
        for i, result in enumerate(clamped)
    ]
