"""Config registry: ``--arch <id>`` resolution for every assigned arch."""

from __future__ import annotations

from repro.configs.base import (
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SHAPES,
    reduce_config,
)
from repro.configs import (
    qwen3_32b,
    granite_34b,
    phi3_medium_14b,
    qwen2_7b,
    qwen2_vl_72b,
    deepseek_v2_236b,
    llama4_scout_17b_a16e,
    zamba2_1p2b,
    seamless_m4t_large_v2,
    xlstm_1p3b,
)

ARCHITECTURES: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_32b,
        granite_34b,
        phi3_medium_14b,
        qwen2_7b,
        qwen2_vl_72b,
        deepseek_v2_236b,
        llama4_scout_17b_a16e,
        zamba2_1p2b,
        seamless_m4t_large_v2,
        xlstm_1p3b,
    )
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(ARCHITECTURES)}"
        ) from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}") from None


def valid_cells() -> list[tuple[str, str]]:
    """All runnable (arch × shape) dry-run cells.

    ``long_500k`` needs sub-quadratic sequence mixing and is skipped for
    pure full-attention archs (recorded in DESIGN.md §Arch-applicability).
    No assigned arch is encoder-only, so decode shapes run everywhere.
    """
    cells = []
    for arch, cfg in ARCHITECTURES.items():
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_context:
                continue
            cells.append((arch, shape.name))
    return cells


__all__ = [
    "ARCHITECTURES",
    "SHAPES",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "reduce_config",
    "valid_cells",
]
