"""llama4-scout-17b-a16e: 48L MoE 16 experts top-1 + shared expert, early
fusion (text path here; fused modality enters as embeddings).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
    ),
    rope_theta=5e5,
)
