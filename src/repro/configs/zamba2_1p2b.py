"""zamba2-1.2b: hybrid — Mamba2 backbone with a weight-shared attention
block invoked periodically.  [arXiv:2411.15242; hf]

Sub-quadratic backbone ⇒ runs the long_500k cell.  The shared attention
block is applied every ``shared_attn_every`` Mamba2 layers over a bounded
local window so the 500k cell stays sub-quadratic (see DESIGN.md
§Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=256,
    mamba_headdim=64,
    shared_attn_every=2,
    supports_long_context=True,
    rope_theta=1e4,
)
