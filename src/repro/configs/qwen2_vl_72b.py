"""qwen2-vl-72b: VLM backbone 80L, M-RoPE, dynamic resolution (frontend is a
stub per the assignment — ``input_specs()`` provides precomputed patch
embeddings).  [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_variant="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision_patches",
    frontend_seq=1024,
)
