"""deepseek-v2-236b: 60L MoE, MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]

d_ff=1536 is the per-expert intermediate; the first layer uses a dense FFN
(d_ff_dense=12288) per the published architecture.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102_400,
    attn_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        d_ff_shared=1536,
        first_dense_layers=1,
        d_ff_dense=12288,
    ),
    rope_theta=1e4,
)
