"""Architecture & shape configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``) exposing ``CONFIG`` with the exact published
hyper-parameters, plus a ``reduced()`` smoke-test variant of the same
family (tiny widths/depths, same code paths).

Shapes are global: each architecture is exercised on the four assigned
(seq_len × global_batch) cells; ``decode_*``/``long_*`` lower the serving
step (one new token against a KV cache of seq_len), not the train step.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "reduce_config",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    # layers that use a dense FFN instead of MoE (e.g. deepseek first layer)
    first_dense_layers: int = 0
    d_ff_dense: int = 0
    router_noise: float = 0.0
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 → d_model // n_heads
    # attention flavour
    attn_kind: Literal["full", "mla", "none"] = "full"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_variant: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 1e6
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    # sub-configs
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    # hybrid / ssm
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    mamba_headdim: int = 64
    shared_attn_every: int = 0              # zamba2: shared block cadence
    slstm_every: int = 0                    # xlstm: sLSTM cadence (else mLSTM)
    xlstm_proj_factor: float = 2.0
    # enc-dec
    encoder_layers: int = 0
    # frontends (stubs — assignment: modality frontends provide embeddings)
    frontend: Literal["none", "vision_patches", "audio_frames"] = "none"
    frontend_seq: int = 0                   # tokens contributed by the stub
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # which shapes are valid ("long_500k" only for sub-quadratic mixers)
    supports_long_context: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_subquadratic(self) -> bool:
        return self.supports_long_context

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D MODEL_FLOPS and docs)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            if self.attn_kind == "mla":
                m = self.mla or MLAConfig()
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * nq * qk_head      # W_DQ, W_UQ
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)            # W_DKV + k_rope
                p += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                p += nq * m.v_head_dim * d                                # W_O
                return p
            if self.attn_kind == "none":
                return 0
            p = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            if self.qkv_bias:
                p += (nq + 2 * nkv) * hd
            return p

        def ffn_params(layer: int) -> int:
            if self.moe is not None and layer >= self.moe.first_dense_layers:
                m = self.moe
                expert = 3 * d * m.d_ff_expert
                shared = m.num_shared_experts * 3 * d * m.d_ff_shared
                router = d * m.num_experts
                return m.num_experts * expert + shared + router
            if self.moe is not None and self.moe.d_ff_dense:
                return 3 * d * self.moe.d_ff_dense
            return 3 * d * dff if dff else 0

        def mamba_params() -> int:
            d_inner = self.ssm_expand * d
            n_heads_m = d_inner // self.mamba_headdim
            p = d * (2 * d_inner + 2 * self.ssm_state + n_heads_m)  # in_proj(x,z,B,C,dt)
            p += d_inner * self.ssm_conv                             # conv
            p += n_heads_m * 2                                       # A, D
            p += d_inner * d                                         # out_proj
            return p

        def xlstm_params(slstm: bool) -> int:
            # mirrors ssm.init_mlstm / init_slstm exactly
            dh = d // self.n_heads
            up = int(self.xlstm_proj_factor * d)
            if slstm:
                # w_in (d,4d) + r (4,H,dh,dh) + b (4,H,dh) + w_up + w_down
                return d * 4 * d + 4 * self.n_heads * dh * dh + 4 * d + 2 * d * up
            # w_up + w_gatez (d,up each) + wq/wk/wv (up,up) + w_if (up,2H) + w_down
            return 2 * d * up + 3 * up * up + up * 2 * self.n_heads + up * d

        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d

        if self.family in ("dense", "moe", "vlm"):
            for layer in range(self.n_layers):
                total += attn_params() + ffn_params(layer) + 2 * d
        elif self.family == "encdec":
            enc = self.encoder_layers or self.n_layers
            total += enc * (attn_params() + 3 * d * dff + 2 * d)
            # decoder: self-attn + cross-attn + ffn
            total += self.n_layers * (2 * attn_params() + 3 * d * dff + 3 * d)
        elif self.family == "hybrid":
            total += self.n_layers * (mamba_params() + 2 * d)
            total += attn_params() + 3 * d * dff + 2 * d  # one shared block
        elif self.family == "ssm":
            n_s = self.n_layers // max(self.slstm_every, 1) if self.slstm_every else 0
            n_m = self.n_layers - n_s
            total += n_m * xlstm_params(False) + n_s * xlstm_params(True)
            total += self.n_layers * 2 * d
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed-to experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        per_expert = 3 * d * m.d_ff_expert
        inactive = (m.num_experts - m.top_k) * per_expert
        n_moe_layers = self.n_layers - m.first_dense_layers
        return int(self.param_count() - n_moe_layers * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family/code paths, tiny sizes."""
    small: dict = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.family != "ssm" else 8),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_chunk=16,
        mamba_headdim=16,
        frontend_seq=8 if cfg.frontend != "none" else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
    )
    if cfg.rope_variant == "mrope":
        # rescale the three M-RoPE sections to the reduced head_dim (hd/2 freqs)
        half = small["head_dim"] // 2
        s0 = half // 4
        s1 = (half - s0) // 2
        small["mrope_sections"] = (s0, s1, half - s0 - s1)
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.num_shared_experts else 0,
            d_ff_dense=128 if cfg.moe.first_dense_layers else 0,
        )
    if cfg.mla is not None:
        small["mla"] = MLAConfig(
            kv_lora_rank=32,
            q_lora_rank=48,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    if cfg.slstm_every:
        small["slstm_every"] = 4
    if cfg.shared_attn_every:
        small["shared_attn_every"] = 2
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
