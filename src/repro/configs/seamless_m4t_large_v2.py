"""seamless-m4t-large-v2: encoder-decoder multimodal backbone (24L enc +
24L dec).  The speech frontend is a stub per the assignment —
``input_specs()`` provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    head_dim=64,
    rope_variant="none",
    frontend="audio_frames",
    frontend_seq=1024,
)
