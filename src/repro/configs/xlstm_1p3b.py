"""xlstm-1.3b: 48 blocks of sLSTM + mLSTM (d_ff=0: the up/down projection
lives inside the xLSTM blocks).  [arXiv:2405.04517; unverified]

Recurrent (linear) sequence mixing ⇒ runs the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    attn_kind="none",
    rope_variant="none",
    slstm_every=8,
    xlstm_proj_factor=2.0,
    supports_long_context=True,
)
