"""Process-local metrics plane: counters, gauges, quantile histograms.

Until now the serving tier's only observability was the ad-hoc integer
counters on :class:`~repro.service.broker.BrokerTelemetry` — totals with
no distribution, no per-stage attribution, and no way to aggregate
across future solver workers.  This module is the metrics half of the
telemetry plane (``repro.obs.trace`` is the tracing half):

* :class:`Counter` / :class:`Gauge` — monotonic totals and last-value
  instruments, keyed by (name, sorted label items).
* :class:`Histogram` — **fixed-bucket log-scale** value distribution:
  bucket edges form a geometric series, so relative resolution is
  constant at every magnitude (the right shape for latencies spanning
  µs solver dispatches to second-long fault-storm ticks).  Quantiles
  (:meth:`Histogram.quantile`, ``p50``/``p90``/``p99``) interpolate
  geometrically inside the winning bucket; exact ``sum``/``count``/
  ``min``/``max`` ride along.
* **Mergeable** — two histograms (or whole registries) with the same
  bucket geometry merge by adding count vectors
  (:meth:`Histogram.merge`, :meth:`MetricsRegistry.merge`), the
  property the future multi-process solver fleet needs: workers ship
  snapshots, the management plane merges, quantiles stay correct.
* **Near-zero when disabled** — ``MetricsRegistry(enabled=False)``
  hands out shared null instruments whose methods are constant-time
  no-ops, so instrumented hot paths cost a dict lookup at bind time and
  nothing per event; with no registry *attached* the instrumented code
  paths are not merely cheap but bit-identical to the pre-observability
  behavior (asserted by ``tests/test_observability.py``).

Everything is plain Python + stdlib ``array`` — importable before jax,
usable from tools, and cheap to snapshot as JSON.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_COUNTER",
    "NULL_GAUGE",
    "NULL_HISTOGRAM",
]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic counter.  ``inc`` only; negative increments are errors."""

    __slots__ = ("name", "labels", "_value")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self._value += amount

    def merge(self, other: "Counter") -> None:
        self._value += other._value


class Gauge:
    """Last-value instrument (queue depths, deficits, cache sizes)."""

    __slots__ = ("name", "labels", "_value")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value

    def set(self, value: float) -> None:
        self._value = float(value)

    def add(self, amount: float) -> None:
        self._value += amount

    def merge(self, other: "Gauge") -> None:
        # cross-worker gauges are additive by convention (queue depths,
        # cache sizes); a last-write-wins gauge should not be merged
        self._value += other._value


class Histogram:
    """Fixed-bucket log-scale histogram with quantile estimation.

    Bucket ``i`` (0-based) covers ``[lo·growth^i, lo·growth^(i+1))``;
    values below ``lo`` land in a dedicated underflow bucket, values at
    or above the top edge in an overflow bucket.  With the default
    geometry (``lo=1e-6``, ``growth=2``, 36 buckets) the range spans
    1 µs … ~68 s at a constant 2× relative resolution — wide enough for
    both a solver dispatch and a fault-storm tick.

    Quantiles interpolate geometrically within the winning bucket (the
    natural interpolation for a log-scale bucket), clamped to the exact
    observed ``min``/``max`` so a single-sample histogram reports that
    sample at every quantile.
    """

    __slots__ = (
        "name",
        "labels",
        "lo",
        "growth",
        "counts",
        "underflow",
        "overflow",
        "count",
        "sum",
        "min",
        "max",
    )

    DEFAULT_LO = 1e-6
    DEFAULT_GROWTH = 2.0
    DEFAULT_BUCKETS = 36

    def __init__(
        self,
        name: str,
        labels: dict | None = None,
        *,
        lo: float = DEFAULT_LO,
        growth: float = DEFAULT_GROWTH,
        n_buckets: int = DEFAULT_BUCKETS,
    ):
        if lo <= 0:
            raise ValueError("lo must be positive")
        if growth <= 1.0:
            raise ValueError("growth must be > 1")
        if n_buckets <= 0:
            raise ValueError("n_buckets must be positive")
        self.name = name
        self.labels = dict(labels or {})
        self.lo = float(lo)
        self.growth = float(growth)
        self.counts = [0] * int(n_buckets)
        self.underflow = 0
        self.overflow = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- recording -------------------------------------------------------
    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        if value < self.lo:
            self.underflow += 1
            return
        i = int(math.log(value / self.lo) / math.log(self.growth))
        if i >= len(self.counts):
            self.overflow += 1
        else:
            self.counts[i] += 1

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    # -- quantiles -------------------------------------------------------
    def _edge(self, i: int) -> float:
        return self.lo * self.growth**i

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0 ≤ q ≤ 1); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile must be in [0, 1]")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = self.underflow
        if rank <= seen:
            # underflow bucket: everything below lo; report observed min
            return max(self.min, 0.0)
        value = self.max
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if rank <= seen + c:
                frac = (rank - seen) / c
                # geometric interpolation inside the log-scale bucket
                value = self._edge(i) * self.growth**frac
                break
            seen += c
        # overflow (or interpolation past the data): clamp to observations
        return min(max(value, self.min), self.max)

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p90(self) -> float:
        return self.quantile(0.90)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    # -- merging ---------------------------------------------------------
    def compatible(self, other: "Histogram") -> bool:
        return (
            math.isclose(self.lo, other.lo)
            and math.isclose(self.growth, other.growth)
            and len(self.counts) == len(other.counts)
        )

    def merge(self, other: "Histogram") -> None:
        """Fold ``other``'s observations in (same bucket geometry only)."""
        if not self.compatible(other):
            raise ValueError(
                f"histogram {self.name!r}: incompatible bucket geometry"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.underflow += other.underflow
        self.overflow += other.overflow
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)


class _NullCounter(Counter):
    """Shared no-op counter: the disabled registry's hand-out."""

    def inc(self, amount: float = 1.0) -> None:  # noqa: ARG002
        return


class _NullGauge(Gauge):
    def set(self, value: float) -> None:  # noqa: ARG002
        return

    def add(self, amount: float) -> None:  # noqa: ARG002
        return


class _NullHistogram(Histogram):
    def observe(self, value: float) -> None:  # noqa: ARG002
        return

    def observe_many(self, values) -> None:  # noqa: ARG002
        return


NULL_COUNTER = _NullCounter("null")
NULL_GAUGE = _NullGauge("null")
NULL_HISTOGRAM = _NullHistogram("null")


class _NullTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


class _Timer:
    """Context manager charging elapsed clock time to a histogram."""

    __slots__ = ("_hist", "_clock", "_t0")

    def __init__(self, hist: Histogram, clock: Callable[[], float]):
        self._hist = hist
        self._clock = clock

    def __enter__(self):
        self._t0 = self._clock()
        return self

    def __exit__(self, *exc):
        self._hist.observe(self._clock() - self._t0)
        return False


class MetricsRegistry:
    """Get-or-create instrument store, keyed by (name, sorted labels).

    Parameters:
      enabled: ``False`` hands out shared null instruments — every
               instrumented call site stays wired but records nothing
               (the overhead smoke gate measures this mode).
      clock:   timer clock (:meth:`timer`); injectable — pass the same
               :class:`~repro.service.resilience.InjectedClock` the
               broker runs on and timing histograms become a pure
               function of the fault schedule.
    """

    def __init__(
        self,
        *,
        enabled: bool = True,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.enabled = bool(enabled)
        self.clock = clock
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}

    # -- instrument accessors (get-or-create) ----------------------------
    def counter(self, name: str, **labels) -> Counter:
        if not self.enabled:
            return NULL_COUNTER
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter(name, labels)
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        if not self.enabled:
            return NULL_GAUGE
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge(name, labels)
        return g

    def histogram(
        self,
        name: str,
        *,
        lo: float = Histogram.DEFAULT_LO,
        growth: float = Histogram.DEFAULT_GROWTH,
        n_buckets: int = Histogram.DEFAULT_BUCKETS,
        **labels,
    ) -> Histogram:
        if not self.enabled:
            return NULL_HISTOGRAM
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(
                name, labels, lo=lo, growth=growth, n_buckets=n_buckets
            )
        return h

    def timer(self, name: str, **labels):
        """``with registry.timer("solve_envs_duration_s", backend=...):``
        — observes elapsed ``clock`` seconds into the named histogram."""
        if not self.enabled:
            return _NULL_TIMER
        return _Timer(self.histogram(name, **labels), self.clock)

    # -- export / merge --------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-serializable export of every instrument (the wire format
        a worker would ship to the management plane)."""

        def label_dict(key: tuple) -> dict:
            return dict(key[1])

        return {
            "counters": [
                {"name": k[0], "labels": label_dict(k), "value": c.value}
                for k, c in sorted(self._counters.items())
            ],
            "gauges": [
                {"name": k[0], "labels": label_dict(k), "value": g.value}
                for k, g in sorted(self._gauges.items())
            ],
            "histograms": [
                {
                    "name": k[0],
                    "labels": label_dict(k),
                    "lo": h.lo,
                    "growth": h.growth,
                    "counts": list(h.counts),
                    "underflow": h.underflow,
                    "overflow": h.overflow,
                    "count": h.count,
                    "sum": h.sum,
                    "min": None if h.count == 0 else h.min,
                    "max": None if h.count == 0 else h.max,
                    "p50": h.p50,
                    "p90": h.p90,
                    "p99": h.p99,
                }
                for k, h in sorted(self._histograms.items())
            ],
        }

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in (counters add, gauges add, histograms
        merge bucket-wise) — the fleet-aggregation path."""
        for key, c in other._counters.items():
            mine = self._counters.get(key)
            if mine is None:
                mine = self._counters[key] = Counter(c.name, c.labels)
            mine.merge(c)
        for key, g in other._gauges.items():
            mine = self._gauges.get(key)
            if mine is None:
                mine = self._gauges[key] = Gauge(g.name, g.labels)
            mine.merge(g)
        for key, h in other._histograms.items():
            mine = self._histograms.get(key)
            if mine is None:
                mine = self._histograms[key] = Histogram(
                    h.name, h.labels, lo=h.lo, growth=h.growth,
                    n_buckets=len(h.counts),
                )
            mine.merge(h)

    @classmethod
    def from_snapshot(cls, doc: dict, *, clock=None) -> "MetricsRegistry":
        """Rehydrate a registry from :meth:`snapshot`'s wire format.

        The inverse half of the cross-process telemetry path: a
        :class:`~repro.service.server.SolverServer` ships
        ``snapshot()`` inside a ``telemetry_report`` frame and the
        client rebuilds live instruments from it — ready to
        :meth:`merge` into a fleet-wide registry.  Malformed entries
        are skipped (telemetry must never crash the consumer);
        histogram quantiles are re-derived from the bucket counts, not
        trusted from the document.
        """
        reg = cls(enabled=True, **({"clock": clock} if clock else {}))
        for e in doc.get("counters", ()):
            try:
                reg.counter(e["name"], **e.get("labels", {})).inc(
                    float(e["value"])
                )
            except (KeyError, TypeError, ValueError):
                continue
        for e in doc.get("gauges", ()):
            try:
                reg.gauge(e["name"], **e.get("labels", {})).set(
                    float(e["value"])
                )
            except (KeyError, TypeError, ValueError):
                continue
        for e in doc.get("histograms", ()):
            try:
                h = reg.histogram(
                    e["name"],
                    lo=float(e["lo"]),
                    growth=float(e["growth"]),
                    n_buckets=len(e["counts"]),
                    **e.get("labels", {}),
                )
                h.counts = [int(c) for c in e["counts"]]
                h.underflow = int(e["underflow"])
                h.overflow = int(e["overflow"])
                h.count = int(e["count"])
                h.sum = float(e["sum"])
                h.min = math.inf if e.get("min") is None else float(e["min"])
                h.max = -math.inf if e.get("max") is None else float(e["max"])
            except (KeyError, TypeError, ValueError):
                continue
        return reg

    # -- introspection ---------------------------------------------------
    def get_counter(self, name: str, **labels) -> Counter | None:
        return self._counters.get((name, _label_key(labels)))

    def get_gauge(self, name: str, **labels) -> Gauge | None:
        return self._gauges.get((name, _label_key(labels)))

    def get_histogram(self, name: str, **labels) -> Histogram | None:
        return self._histograms.get((name, _label_key(labels)))

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Counter-or-gauge value by name (0.0 / ``default`` if absent)."""
        c = self.get_counter(name, **labels)
        if c is not None:
            return c.value
        g = self.get_gauge(name, **labels)
        if g is not None:
            return g.value
        return default
