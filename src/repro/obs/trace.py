"""Span-based tracing with ring retention and offline exporters.

The metrics plane (``repro.obs.metrics``) answers *how much / how often*;
this module answers **why was this tick slow** and **where did this
degraded reply come from**:

* :class:`Span` — a named, timed region with free-form attributes and
  point-in-time :meth:`Span.event` records.  Spans nest: the tracer
  keeps an open-span stack, children carry ``parent_id``, and events
  attach to the innermost open span — so a ``fault`` event fired inside
  a solve dispatch lands on that tick's ``stage.solve_flush`` span and a
  degraded reply is traceable to the exact injected fault that caused
  it (the CI trace-audit contract, see ``tools/tracequery.py``).
* :class:`Tracer` — ``with tracer.span("solve_flush", bucket=64):``.
  The clock is injectable; pass the same
  :class:`~repro.service.resilience.InjectedClock` the broker runs on
  and every timestamp in a chaos trace is a pure deterministic function
  of the fault schedule.  Finished spans live in a bounded ring
  (``capacity`` newest are retained), so a long-lived server can keep a
  tracer attached without growing without limit.
* **Exporters** — :meth:`Tracer.export_jsonl` (one span per line; the
  format ``tools/tracequery.py`` consumes) and
  :meth:`Tracer.export_chrome` (Chrome ``trace_event`` JSON: load it in
  ``about://tracing`` / Perfetto for a flame view of broker ticks).

With no tracer attached the instrumented paths never construct a span
(the broker's helpers return the shared :data:`NULL_SPAN`), so detached
behavior is bit-identical to the pre-observability code — asserted by
``tests/test_observability.py``.
"""

from __future__ import annotations

import json
import pathlib
import time
from collections import deque
from typing import Callable

__all__ = ["Span", "Tracer", "NULL_SPAN"]


class _NullSpan:
    """Shared no-op span: what detached/disabled call sites receive."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:  # noqa: ARG002
        return

    def event(self, name, **attrs) -> None:  # noqa: ARG002
        return


NULL_SPAN = _NullSpan()


class Span:
    """One timed region.  Created by :meth:`Tracer.span`; use as a
    context manager.  ``set`` adds attributes mid-span (e.g. the number
    of representatives a flush actually solved); ``event`` records a
    timestamped point annotation on this span."""

    __slots__ = (
        "name",
        "attrs",
        "events",
        "span_id",
        "parent_id",
        "t0",
        "t1",
        "_tracer",
    )

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.events: list[dict] = []
        self.span_id = 0
        self.parent_id: int | None = None
        self.t0 = 0.0
        self.t1 = 0.0
        self._tracer = tracer

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(self)
        return False

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def event(self, name: str, **attrs) -> None:
        self.events.append(
            {"name": name, "ts": self._tracer.clock(), "attrs": attrs}
        )

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {
            "type": "span",
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "ts": self.t0,
            "dur": self.duration,
            "attrs": self.attrs,
            "events": self.events,
        }


class Tracer:
    """Span factory + bounded ring of finished spans.

    Parameters:
      clock:    timestamp source (default ``time.perf_counter``);
                injectable for deterministic chaos traces.
      capacity: finished-span retention — the newest ``capacity`` spans
                are kept (open spans are never dropped).
      enabled:  ``False`` makes :meth:`span` return :data:`NULL_SPAN`
                and :meth:`event` a no-op (the zero-cost switch; flip
                at runtime to start/stop capturing).
    """

    def __init__(
        self,
        *,
        clock: Callable[[], float] = time.perf_counter,
        capacity: int = 4096,
        enabled: bool = True,
    ):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.clock = clock
        self.enabled = bool(enabled)
        self._ring: deque[Span] = deque(maxlen=int(capacity))
        self._stack: list[Span] = []
        self._next_id = 1

    # -- recording -------------------------------------------------------
    def span(self, name: str, **attrs):
        """Open a span (context manager).  Timing starts at ``__enter__``."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """Record a point event on the innermost open span (or as an
        orphan span of zero duration when none is open — events must
        never be silently dropped)."""
        if not self.enabled:
            return
        if self._stack:
            self._stack[-1].event(name, **attrs)
            return
        s = Span(self, name, attrs)
        s.span_id = self._next_id
        self._next_id += 1
        s.t0 = s.t1 = self.clock()
        s.attrs = dict(attrs, orphan_event=True)
        self._ring.append(s)

    def _push(self, span: Span) -> None:
        span.span_id = self._next_id
        self._next_id += 1
        span.parent_id = self._stack[-1].span_id if self._stack else None
        self._stack.append(span)
        span.t0 = self.clock()

    def _pop(self, span: Span) -> None:
        span.t1 = self.clock()
        # tolerate exception-skewed exits: pop through to this span
        while self._stack:
            top = self._stack.pop()
            if top is span:
                break
        self._ring.append(span)

    # -- introspection ---------------------------------------------------
    def spans(self, name: str | None = None) -> list[Span]:
        """Finished spans, oldest first (filtered by ``name`` if given)."""
        return [s for s in self._ring if name is None or s.name == name]

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self) -> None:
        self._ring.clear()

    # -- exporters -------------------------------------------------------
    def export_jsonl(self, path) -> int:
        """One finished span per line (the ``tools/tracequery.py``
        format).  Returns the number of spans written."""
        path = pathlib.Path(path)
        with path.open("w") as f:
            for s in self._ring:
                f.write(json.dumps(s.to_dict(), default=_arg) + "\n")
        return len(self._ring)

    def export_chrome(self, path) -> int:
        """Chrome ``trace_event`` JSON for ``about://tracing`` /
        Perfetto.  Spans export as complete (``"X"``) events in µs,
        span events as instants (``"i"``) bound to the same thread
        track.  Returns the number of trace events written."""
        events: list[dict] = []
        for s in self._ring:
            events.append(
                {
                    "name": s.name,
                    "ph": "X",
                    "ts": s.t0 * 1e6,
                    "dur": s.duration * 1e6,
                    "pid": 0,
                    "tid": 0,
                    "args": {k: _arg(v) for k, v in s.attrs.items()},
                }
            )
            for e in s.events:
                events.append(
                    {
                        "name": e["name"],
                        "ph": "i",
                        "ts": e["ts"] * 1e6,
                        "pid": 0,
                        "tid": 0,
                        "s": "t",
                        "args": {k: _arg(v) for k, v in e["attrs"].items()},
                    }
                )
        path = pathlib.Path(path)
        path.write_text(
            json.dumps({"traceEvents": events, "displayTimeUnit": "ms"})
            + "\n"
        )
        return len(events)


def _arg(v):
    """Chrome args must be JSON-serializable; stringify anything exotic."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)
