"""Observability plane: metrics registry + span tracing + exporters.

``metrics`` — :class:`MetricsRegistry`: counters, gauges and mergeable
              log-scale quantile histograms (p50/p90/p99), near-zero
              cost when disabled and snapshot/merge-able across the
              future solver-worker fleet.
``trace``   — :class:`Tracer`: per-tick stage spans with an injectable
              clock, bounded ring retention, and exporters to JSONL
              (``tools/tracequery.py``) and Chrome ``trace_event``
              format (``about://tracing``).

Both halves are strictly opt-in: a broker or session tick with no
tracer/registry attached runs bit-identically to the pre-observability
code (asserted by ``tests/test_observability.py``).  See
``docs/OBSERVABILITY.md`` for the metric catalog and span taxonomy.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
)
from repro.obs.trace import NULL_SPAN, Span, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_COUNTER",
    "NULL_GAUGE",
    "NULL_HISTOGRAM",
    "NULL_SPAN",
    "Span",
    "Tracer",
]
