"""Sharded AdamW + LR schedules, built from scratch (no optax on this box).

Moments are float32 regardless of param dtype and inherit the parameter's
PartitionSpec leaf-for-leaf (the optimizer state pytree mirrors the param
pytree, so ``runtime.sharding.param_shardings`` applies verbatim — this is
what keeps optimizer memory per-device constant under TP/DP).

``clip_by_global_norm`` runs in float32 over the whole pytree; under pjit
the norm reduction compiles to one small all-reduce fused with the grad
all-reduces.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "OptState",
    "init_opt_state",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


# {'mu': pytree f32, 'nu': pytree f32, 'step': scalar i32} — a plain dict
# so it is a registered pytree (jit/donation/checkpointing all just work).
OptState = dict


def init_opt_state(params: Any) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        mu=jax.tree_util.tree_map(f32, params),
        nu=jax.tree_util.tree_map(f32, params),
        step=jnp.zeros((), jnp.int32),
    )


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
        prog = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1 + jnp.cos(math.pi * prog))
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
        return cfg.lr * warm * frac

    return lr


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(
    cfg: AdamWConfig, params: Any, grads: Any, state: OptState
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_schedule(cfg)(step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu, nu

    out = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(mu=new_mu, nu=new_nu, step=step)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
