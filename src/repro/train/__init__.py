from repro.train.optimizer import AdamWConfig, OptState, adamw_update, clip_by_global_norm, cosine_schedule, init_opt_state
from repro.train.trainer import TrainConfig, TrainState, init_train_state, make_train_step, train_loop
