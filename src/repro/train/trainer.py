"""Training loop: jitted step with microbatch accumulation + compression.

``make_train_step`` builds the canonical pjit-able step:

    (params, opt_state, batch) → (params, opt_state, metrics)

* **Microbatch accumulation**: the global batch is reshaped to
  (n_micro, micro_bsz, …) and consumed with ``lax.scan``; gradients are
  accumulated in float32.  Because each microbatch's grads feed one
  accumulator that is only all-reduced at use (the optimizer), XLA's
  latency-hiding scheduler is free to overlap microbatch k+1's compute
  with k's reduce — the structural property the §Perf log verifies in HLO.
* **Gradient compression** (optional): top-k-with-error-feedback or int8
  stochastic rounding applied to the accumulated grads before the
  optimizer (i.e., before the DP all-reduce boundary in the sharded
  lowering); wire accounting feeds the roofline's collective term.
* **Donation**: params/opt state are donated so the compiled step updates
  in place (halves peak HBM on real hardware).

The same function lowers for the 1-CPU smoke tests, the 256-chip pod and
the 512-chip multi-pod mesh — only the shardings differ.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.runtime import compression as comp_lib
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

__all__ = ["TrainConfig", "make_train_step", "train_loop", "TrainState"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    n_micro: int = 1
    compression: str = "none"          # "none" | "topk" | "int8"
    topk_frac: float = 0.01


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: OptState
    comp_state: comp_lib.CompressionState | None


def init_train_state(params: Any, cfg: TrainConfig) -> TrainState:
    comp = (
        comp_lib.init_compression_state(params)
        if cfg.compression == "topk"
        else None
    )
    return TrainState(params=params, opt_state=init_opt_state(params), comp_state=comp)


def make_train_step(
    loss_fn: Callable[[Any, dict], tuple[jnp.ndarray, dict]],
    cfg: TrainConfig,
):
    """Returns step(params, opt_state, comp_state, batch, rng) → (...)."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params: Any, batch: dict) -> tuple[jnp.ndarray, Any, dict]:
        if cfg.n_micro == 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads
            ), aux

        def split(x):
            return x.reshape(cfg.n_micro, x.shape[0] // cfg.n_micro, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, _aux), grads = grad_fn(params, mb)
            grads_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / cfg.n_micro,
                grads_acc,
                grads,
            )
            return (loss_acc + loss / cfg.n_micro, grads_acc), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), micro)
        return loss, grads, {}

    def step(params, opt_state, comp_state, batch, rng):
        loss, grads, _aux = accumulate(params, batch)
        if cfg.compression == "topk":
            grads, comp_state = comp_lib.topk_compress_with_ef(
                grads, comp_state, frac=cfg.topk_frac
            )
        elif cfg.compression == "int8":
            q8, scales = comp_lib.int8_compress(grads, rng)
            grads = comp_lib.int8_decompress(q8, scales)
        params, opt_state, om = adamw_update(cfg.optimizer, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, comp_state, metrics

    return step


def train_loop(
    model_loss_fn: Callable[[Any, dict], tuple[jnp.ndarray, dict]],
    params: Any,
    batches,                    # iterable of batch dicts
    cfg: TrainConfig,
    *,
    jit: bool = True,
    donate: bool = False,  # donating caller-owned params invalidates them
    hooks: list[Callable[[int, dict], None]] | None = None,
) -> tuple[TrainState, list[dict]]:
    """Drive ``make_train_step`` over an iterable of batches (host loop)."""
    state = init_train_state(params, cfg)
    step_fn = make_train_step(model_loss_fn, cfg)
    if jit:
        step_fn = jax.jit(
            step_fn, donate_argnums=(0, 1) if donate else ()
        )
    history: list[dict] = []
    rng = jax.random.PRNGKey(0)
    for i, batch in enumerate(batches):
        rng, sub = jax.random.split(rng)
        state.params, state.opt_state, state.comp_state, metrics = step_fn(
            state.params, state.opt_state, state.comp_state, batch, sub
        )
        metrics = {k: float(v) for k, v in metrics.items()}
        history.append(metrics)
        for h in hooks or []:
            h(i, metrics)
    return state, history
