"""Fault-tolerant checkpointing: sharded save/restore with resharding.

Layout (one directory per step):

    <root>/step_000042/
        manifest.json     — step, tree structure, per-leaf dtype/shape,
                            writer fingerprints, completion marker
        leaf_00000.npy …  — one array per leaf (row-sharded writes would
                            add .shard_k suffixes on a multi-host fleet;
                            single-host here writes whole leaves)

Design points that matter at 1000-node scale (all implemented, all
tested):

* **Atomicity** — writes go to ``<dir>.tmp`` and are renamed only after
  the manifest (with leaf checksums) is fsync'd: a machine dying mid-save
  can never leave a directory that ``latest_step`` would pick up.
* **Async saves** — ``save_async`` snapshots params to host memory
  synchronously (cheap) and writes in a daemon thread, so the train loop
  donates its buffers without waiting on the filesystem.
* **Restore-with-resharding** — restore takes target shardings (from a
  *different* mesh if the fleet was resized) and device_puts each leaf
  accordingly: the elastic path "checkpoint on 512 chips, resume on 256"
  is a first-class operation, not a repair script.
* **Retention** — ``keep`` limits how many recent steps survive.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointStore", "CheckpointMeta"]

_NATIVE_NUMPY_DTYPES = {
    "float64", "float32", "float16", "int64", "int32", "int16", "int8",
    "uint64", "uint32", "uint16", "uint8", "bool",
}
_BITS_DTYPE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _decode_leaf(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _NATIVE_NUMPY_DTYPES:
        return arr
    import ml_dtypes  # ships with jax

    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    path: str
    extra: dict


class CheckpointStore:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: list[threading.Thread] = []

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.root, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> str:
        """Synchronous atomic save.  ``tree`` is any pytree of arrays."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        return self._write(step, host_leaves, treedef, extra or {})

    def save_async(self, step: int, tree: Any, *, extra: dict | None = None) -> None:
        """Snapshot to host now; write in the background."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]  # sync D2H copy

        t = threading.Thread(
            target=self._write, args=(step, host_leaves, treedef, extra or {}),
            daemon=True,
        )
        t.start()
        with self._lock:
            self._pending.append(t)

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    # ------------------------------------------------------------------
    def _write(self, step: int, host_leaves, treedef, extra: dict) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        records = []
        for i, leaf in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            dtype_name = str(leaf.dtype)
            to_write = leaf
            if dtype_name not in _NATIVE_NUMPY_DTYPES:
                # extended dtypes (bfloat16, fp8, …) don't survive np.save —
                # store raw bits and reinterpret on restore
                to_write = leaf.view(_BITS_DTYPE[leaf.dtype.itemsize])
            np.save(os.path.join(tmp, fname), to_write)
            records.append(
                {
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": dtype_name,
                    "crc32": zlib.crc32(np.ascontiguousarray(leaf).tobytes()) & 0xFFFFFFFF,
                }
            )
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "num_leaves": len(host_leaves),
            "leaves": records,
            "extra": extra,
            "complete": True,
        }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(
        self,
        step: int,
        tree_like: Any,
        *,
        shardings: Any | None = None,
        verify: bool = True,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``tree_like``.

        ``shardings`` (optional pytree of NamedSharding / Sharding) places
        each leaf on the *current* mesh — pass shardings built from a
        different mesh shape to reshard on restore (elastic resume).
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if not manifest.get("complete"):
            raise IOError(f"checkpoint at {d} is incomplete")
        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        if len(leaves_like) != manifest["num_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['num_leaves']} leaves, "
                f"target tree has {len(leaves_like)}"
            )
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        out = []
        for i, (rec, like) in enumerate(zip(manifest["leaves"], leaves_like)):
            arr = _decode_leaf(np.load(os.path.join(d, rec["file"])), rec["dtype"])
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
                if crc != rec["crc32"]:
                    raise IOError(f"leaf {i} checksum mismatch in {d}")
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"leaf {i} shape {arr.shape} != expected {like.shape}"
                )
            arr = arr.astype(like.dtype) if str(arr.dtype) != str(like.dtype) else arr
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, tree_like: Any, **kw) -> tuple[int, Any, dict]:
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        tree, extra = self.restore(step, tree_like, **kw)
        return step, tree, extra
