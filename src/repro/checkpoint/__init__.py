from repro.checkpoint.store import CheckpointMeta, CheckpointStore
