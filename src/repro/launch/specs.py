"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell.

No device allocation happens here — everything is ``jax.eval_shape``-land.
For a training cell the specs cover (params, opt_state, batch); for
prefill/decode cells they cover (params, cache, tokens).  The same
functions produce the matching NamedShardings so ``dryrun.py`` can lower
with explicit in/out shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import make_batch_shapes
from repro.models.transformer import Model, build_model
from repro.runtime import sharding as shard_lib

__all__ = ["CellSpec", "build_cell"]


@dataclasses.dataclass
class CellSpec:
    """Everything dryrun needs for one (arch × shape × mesh) cell."""

    model: Model
    kind: str                  # "train" | "prefill" | "decode"
    arg_shapes: tuple          # positional ShapeDtypeStructs for step_fn
    in_shardings: tuple
    out_shardings: Any
    step_fn: Any               # callable(*args)
    donate_argnums: tuple


def _params_shapes(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _opt_shapes(params_shapes: Any) -> Any:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return dict(
        mu=jax.tree_util.tree_map(f32, params_shapes),
        nu=jax.tree_util.tree_map(f32, params_shapes),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def build_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    n_micro: int = 1,
    remat: bool = True,
    fsdp: bool = True,
    vocab_chunk: int = 0,
    cache_prefer: str = "largest",
) -> CellSpec:
    from repro.train.optimizer import AdamWConfig, adamw_update

    model = build_model(cfg)
    model.remat = remat
    model.vocab_chunk = vocab_chunk
    p_shapes = _params_shapes(model)
    p_shard = shard_lib.param_shardings(p_shapes, mesh, fsdp=fsdp is True)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        batch_shapes = make_batch_shapes(cfg, shape.seq_len, shape.global_batch)
        b_shard = shard_lib.input_shardings(batch_shapes, mesh)
        o_shapes = _opt_shapes(p_shapes)
        # fsdp=True → ZeRO-3 (params+moments 2D); "zero1" → params TP-only,
        # moments 2D-sharded (grads reduce-scatter to the moment layout).
        o_fsdp = fsdp in (True, "zero1")
        o_shard = dict(
            mu=shard_lib.param_shardings(o_shapes["mu"], mesh, fsdp=o_fsdp),
            nu=shard_lib.param_shardings(o_shapes["nu"], mesh, fsdp=o_fsdp),
            step=repl,
        )
        opt_cfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            def loss_fn(p, b):
                loss, _ = model.train_loss(p, b)
                return loss

            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                def split(x):
                    return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

                micro = jax.tree_util.tree_map(split, batch)

                def body(carry, mb):
                    l_acc, g_acc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, x: a + x.astype(jnp.float32) / n_micro, g_acc, g
                    )
                    return (l_acc + l / n_micro, g_acc), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                # unroll follows the layer-scan knob so depth-probe
                # measurements see every microbatch body too
                from repro.models import transformer as _tf

                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), zeros), micro,
                    unroll=_tf._LAYER_SCAN_UNROLL,
                )
            params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
            return params, opt_state, loss, om["grad_norm"]

        return CellSpec(
            model=model,
            kind="train",
            arg_shapes=(p_shapes, o_shapes, batch_shapes),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, repl, repl),
            step_fn=train_step,
            donate_argnums=(0, 1),
        )

    # ---------------- serving cells -----------------------------------
    bsz = shape.global_batch
    if shape.kind == "prefill":
        batch_shapes = make_batch_shapes(cfg, shape.seq_len, bsz)
        batch_shapes.pop("labels")
        b_shard = shard_lib.input_shardings(batch_shapes, mesh)
        cache_shapes = jax.eval_shape(lambda: model.init_cache(bsz, shape.seq_len))
        c_shard = shard_lib.state_shardings(cache_shapes, mesh, batch_size=bsz, prefer=cache_prefer)

        def prefill_step(params, batch, cache):
            logits, cache = model.prefill(params, batch, cache)
            return logits, cache

        return CellSpec(
            model=model,
            kind="prefill",
            arg_shapes=(p_shapes, batch_shapes, cache_shapes),
            in_shardings=(p_shard, b_shard, c_shard),
            out_shardings=(
                shard_lib.input_shardings(
                    jax.ShapeDtypeStruct((bsz, cfg.vocab_size), jnp.float32), mesh
                ),
                c_shard,
            ),
            step_fn=prefill_step,
            donate_argnums=(2,),
        )

    # decode: one new token against a cache of seq_len
    max_len = shape.seq_len
    cache_shapes = jax.eval_shape(lambda: model.init_cache(bsz, max_len))
    c_shard = shard_lib.state_shardings(cache_shapes, mesh, batch_size=bsz, prefer=cache_prefer)
    tok_shapes = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
    t_shard = shard_lib.input_shardings(tok_shapes, mesh)
    extras = {}
    if cfg.rope_variant == "mrope":
        extras["positions"] = jax.ShapeDtypeStruct((bsz, 1, 3), jnp.int32)
    e_shard = shard_lib.input_shardings(extras, mesh)

    def decode_step(params, tokens, cache, extras):
        logits, cache = model.decode_step(params, tokens, cache, extras)
        return logits, cache

    return CellSpec(
        model=model,
        kind="decode",
        arg_shapes=(p_shapes, tok_shapes, cache_shapes, extras),
        in_shardings=(p_shard, t_shard, c_shard, e_shard),
        out_shardings=(
            shard_lib.input_shardings(
                jax.ShapeDtypeStruct((bsz, cfg.vocab_size), jnp.float32), mesh
            ),
            c_shard,
        ),
        step_fn=decode_step,
        donate_argnums=(2,),
    )
