"""Serving driver: batched requests through the KV-cache engine.

    python -m repro.launch.serve --arch qwen2-7b --reduced \\
        --requests 16 --max-new-tokens 32

Includes the paper's placement pass for the serving stage graph: the
prefill pool (compute-heavy) and decode pool (bandwidth-heavy) are priced
as the two tiers and MCOP decides which layers would host-offload under
the configured interconnect — printed as a report before serving starts.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduce_config
    from repro.configs.base import ShapeConfig
    from repro.core.placement import TPUV5E_TIER, plan_placement
    from repro.models.transformer import build_model
    from repro.profilers.program import stage_specs
    from repro.serving import ServingConfig, ServingEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.family == "encdec":
        extras_shape = ShapeConfig("cli", "decode", 4096, args.max_batch)
    shape = ShapeConfig("cli", "decode", 4096, args.max_batch)
    plan = plan_placement(
        stage_specs(cfg, shape, group=max(cfg.n_layers // 8, 1)),
        dataclasses.replace(TPUV5E_TIER, name="decode-pool", chips=64),
        dataclasses.replace(TPUV5E_TIER, name="prefill-pool", chips=192),
    )
    print(
        f"[serve] MCOP placement: cut={plan.mcop_cost:.3e}s "
        f"split={plan.contiguous_boundary}/{plan.stage_tier.shape[0]} "
        f"cut_bytes={plan.cut_bytes:.3e}",
        flush=True,
    )

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    extras = {}
    if cfg.frontend == "vision_patches":
        extras["patch_embeds"] = jax.numpy.zeros(
            (args.max_batch, cfg.frontend_seq or 8, cfg.d_model), jax.numpy.bfloat16
        )
    if cfg.frontend == "audio_frames":
        extras["frame_embeds"] = jax.numpy.zeros(
            (args.max_batch, cfg.frontend_seq or 8, cfg.d_model), jax.numpy.bfloat16
        )

    engine = ServingEngine(
        model,
        params,
        ServingConfig(
            max_batch=args.max_batch,
            max_prompt_len=args.prompt_len,
            max_len=args.prompt_len + args.max_new_tokens + 1,
        ),
        extras=extras,
        rng_seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len))
        engine.submit(
            rng.integers(1, cfg.vocab_size, size=plen),
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature,
        )
    out = engine.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(v) for v in out.values())
    print(
        f"[serve] {len(out)} requests, {toks} tokens in {dt:.1f}s "
        f"({toks/max(dt,1e-9):.1f} tok/s aggregate)",
        flush=True,
    )
    for uid in list(out)[:3]:
        print(f"[serve]   req {uid}: {out[uid][:12]}…", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
