import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this file — jax
locks the device count on first init, and the dry-run needs 512 virtual
host devices to build the production meshes.  Nothing else in the repo
sets this flag (smoke tests and benches see the real single CPU).

Per cell this driver:
  1. builds the full-size architecture config (no allocation — params,
     optimizer state, caches are all ShapeDtypeStructs),
  2. jit's the train/prefill/decode step with explicit in/out shardings,
  3. ``.lower(...)`` then ``.compile()`` — a failure here (sharding
     mismatch, collective error, OOM-at-compile) is a bug in the system,
  4. records ``memory_analysis()`` / ``cost_analysis()`` and the
     collective bytes parsed from the optimized HLO,
  5. derives the three roofline terms (compute / HBM / interconnect).

Conventions: the compiled module is the per-device SPMD program, so FLOPs
and bytes from ``cost_analysis()`` are **per device**; roofline terms
divide by *per-chip* peak rates.  Collective bytes sum the operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (async ``-start`` counted once, ``-done`` skipped).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --arch qwen2-7b --shape decode_32k --multi-pod
"""

import argparse
import json
import re
import sys
import time

import jax

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link — conservative single-link figure

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*")
_NAME_RE = re.compile(r"%[\w.\-]+")


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO.

    Optimized HLO omits operand shape annotations, so a first pass records
    every instruction's *output* bytes by name; collective operand names
    are then resolved against that table ("sum operand sizes" — the bytes
    each device contributes to the wire).
    """
    out_bytes: dict[str, float] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m or "=" not in line:
            continue
        name = m.group(1)
        rest = line[m.end():]
        head = rest.split("(", 1)[0]  # output type (possibly a tuple)
        nb = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
        if nb:
            out_bytes[name.lstrip("%")] = float(nb)

    per_kind: dict[str, float] = {}
    count = 0
    for line in lines:
        m = _COLL_RE.search(line)
        if not m:
            continue
        opcode_seg = line.split("=", 1)[1] if "=" in line else line
        if re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)-done\b", opcode_seg):
            continue
        kind = m.group(1)
        start = m.end() - 1  # the call '(' — regex ends with '\('
        depth, end = 0, start
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = line[start + 1 : end]
        # explicit annotations first; fall back to name resolution
        nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands))
        if nbytes == 0:
            for nm in _NAME_RE.findall(operands):
                nbytes += out_bytes.get(nm.lstrip("%"), 0.0)
        per_kind[kind] = per_kind.get(kind, 0.0) + float(nbytes)
        count += 1
    per_kind["total"] = float(sum(v for k, v in per_kind.items() if k != "total"))
    per_kind["num_ops"] = count
    return per_kind


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (global)."""
    n_act = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * shape.tokens


def _depth_variant(cfg, n_layers: int):
    """Same architecture at a reduced layer count (divisibility-aware)."""
    import dataclasses

    kw = {"n_layers": n_layers}
    if cfg.encoder_layers:
        kw["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _probe_depths(cfg, *, scale: int = 4) -> tuple[int, int]:
    """Two reduced depths compatible with the arch's grouping constraints.

    Larger probes give a cleaner per-layer slope (XLA picks slightly
    different fusion/collective strategies per depth; at depth 4–8 the
    layer term dominates that noise).
    """
    step = 1
    if cfg.shared_attn_every:
        step = max(step, cfg.shared_attn_every)
    if cfg.slstm_every:
        step = max(step, cfg.slstm_every)
    base = cfg.moe.first_dense_layers if (cfg.moe and cfg.moe.first_dense_layers) else 0
    return base + scale * step, base + 2 * scale * step


def _measure_cell(cfg, shape, mesh, *, unroll_layers: bool = False, **build_kw) -> dict:
    """Lower+compile one concrete config; return raw per-device terms.

    ``unroll_layers=True`` fully unrolls the layer scans so every body is
    visible to cost_analysis — required by the depth probes (a rolled scan
    of length 2 is still a while loop counted once).
    """
    from repro.launch.mesh import use_mesh
    from repro.launch.specs import build_cell
    from repro.models import attention as attn_lib
    from repro.models import transformer as tf

    from repro.runtime import sharding as shard_lib

    decode_flash = build_kw.pop("decode_flash", False)
    expert_mode = build_kw.pop("expert_mode", "ep_model")
    if unroll_layers:
        tf.set_layer_scan_unroll(True)
    attn_lib.set_decode_flash_partitioning(decode_flash)
    shard_lib.set_expert_sharding(expert_mode)
    try:
        cell = build_cell(cfg, shape, mesh, **build_kw)
        with use_mesh(mesh):
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.arg_shapes)
            compiled = lowered.compile()
    finally:
        if unroll_layers:
            tf.set_layer_scan_unroll(1)
        attn_lib.set_decode_flash_partitioning(False)
        shard_lib.set_expert_sharding("ep_model")
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict] per computation
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
        "coll_by_kind": coll,
        "memory_analysis": compiled.memory_analysis(),
        "hlo": None,  # dropped to keep memory bounded
    }


def depth_corrected_terms(cfg, shape, mesh, *, probe_scale: int = 4, **build_kw) -> dict:
    """Fix the while-loop single-count: measure at two reduced depths,
    fit term(L) = a + b·L, extrapolate to the full layer count.

    XLA's cost_analysis (and HLO text) count a while body ONCE regardless
    of trip count, so scan-over-layers models under-report FLOPs/bytes/
    collective bytes by ~L×.  The linear fit recovers the per-layer body
    cost b exactly and the loop-invariant overhead a (embed, head, optimizer,
    top-level collectives).  Caveat: *sequence*-level scans inside a layer
    (chunked attention, recurrent cells) are still counted once — the
    analytic terms reported alongside bound that residual.
    """
    lo, hi = _probe_depths(cfg, scale=probe_scale)
    lo = min(lo, cfg.n_layers)
    hi = min(hi, cfg.n_layers)
    m_lo = _measure_cell(_depth_variant(cfg, lo), shape, mesh,
                         unroll_layers=True, **build_kw)
    if hi == lo:
        return {k: m_lo[k] for k in ("flops", "bytes", "coll")}
    m_hi = _measure_cell(_depth_variant(cfg, hi), shape, mesh,
                         unroll_layers=True, **build_kw)
    out = {}
    for k in ("flops", "bytes", "coll"):
        b = (m_hi[k] - m_lo[k]) / (hi - lo)
        a = m_lo[k] - b * lo
        out[k] = max(a + b * cfg.n_layers, m_hi[k])
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, n_micro: int = 1,
             fsdp: bool = True, remat: bool = True, vocab_chunk: int = 0,
             cache_prefer: str = "largest", depth_correct: bool = False,
             decode_flash: bool = False, expert_mode: str = "ep_model",
             verbose: bool = True) -> dict:
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh, use_mesh
    from repro.launch.specs import build_cell

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": "full-attention arch: long_500k needs sub-quadratic mixing"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    build_kw = dict(n_micro=n_micro, fsdp=fsdp, remat=remat,
                    vocab_chunk=vocab_chunk, cache_prefer=cache_prefer,
                    decode_flash=decode_flash, expert_mode=expert_mode)
    from repro.models import attention as attn_lib
    from repro.runtime import sharding as shard_lib

    t0 = time.time()
    bk = dict(build_kw)
    bk.pop("decode_flash")
    bk.pop("expert_mode")
    shard_lib.set_expert_sharding(expert_mode)
    cell = build_cell(cfg, shape, mesh, **bk)

    attn_lib.set_decode_flash_partitioning(decode_flash)
    try:
        with use_mesh(mesh):
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.arg_shapes)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        attn_lib.set_decode_flash_partitioning(False)
        shard_lib.set_expert_sharding("ep_model")

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict] per computation
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    n_chips = mesh.devices.size

    raw_terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll["total"] / ICI_BW,
    }

    # --- depth-corrected terms (fixes the while-body single-count) -----
    if depth_correct and cfg.n_layers > 2:
        corr = depth_corrected_terms(cfg, shape, mesh, probe_scale=4, **build_kw)
        terms = {
            "compute_s": corr["flops"] / PEAK_FLOPS,
            "memory_s": corr["bytes"] / HBM_BW,
            "collective_s": corr["coll"] / ICI_BW,
        }
        flops_dev_corr = corr["flops"]
    else:
        terms = dict(raw_terms)
        flops_dev_corr = flops_dev
    dominant = max(terms, key=terms.get)

    # --- analytic cross-check (no loop-count issues at all) ------------
    from repro.profilers.program import stage_specs

    stages = stage_specs(cfg, shape, group=1)
    analytic = {
        "compute_s": sum(s_.flops for s_ in stages) / (n_chips * PEAK_FLOPS),
        "memory_s": sum(s_.bytes_hbm for s_ in stages) / (n_chips * HBM_BW),
    }

    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_dev_corr * n_chips
    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "mesh": list(mesh.devices.shape),
        "chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None)
            if hasattr(mem, "peak_memory_in_bytes")
            else None,
        },
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "step_time_s": max(terms.values()),
        },
        "roofline_raw": raw_terms,
        "analytic": analytic,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else None,
    }
    if verbose:
        mb = (result["memory"]["argument_bytes"] or 0) / 2**30
        print(
            f"[dryrun] {arch:>24s} × {shape_name:<12s} mesh={result['mesh']} "
            f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
            f"args={mb:.2f}GiB/dev flops/dev={flops_dev:.3e} "
            f"coll={coll['total']:.3e}B dominant={dominant}",
            flush=True,
        )
    return result


def main(argv=None) -> int:
    from repro.configs import valid_cells

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--shard", help="K/N — run the K-th of N slices of --all")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--vocab-chunk", type=int, default=0)
    ap.add_argument("--cache-prefer", default="largest", choices=["largest", "last"])
    ap.add_argument("--depth-correct", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args(argv)

    if args.all:
        cells = valid_cells()
        if args.shard:
            k, n = map(int, args.shard.split("/"))
            cells = [c for i, c in enumerate(cells) if i % n == k]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    results = []
    failures = 0

    def flush_out():
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(
                    run_cell(arch, shape, multi_pod=mp, n_micro=args.n_micro,
                             fsdp=not args.no_fsdp, remat=not args.no_remat,
                             vocab_chunk=args.vocab_chunk,
                             cache_prefer=args.cache_prefer,
                             depth_correct=args.depth_correct)
                )
            except Exception as e:  # noqa: BLE001 — report, continue, fail at exit
                failures += 1
                print(f"[dryrun] FAIL {arch} × {shape} multi_pod={mp}: {e!r}",
                      flush=True)
                results.append(
                    {"arch": arch, "shape": shape, "multi_pod": mp,
                     "error": repr(e)}
                )
            flush_out()  # incremental — a crash loses at most one cell
    if args.out:
        print(f"[dryrun] wrote {len(results)} cells → {args.out}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
