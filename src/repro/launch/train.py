"""End-to-end training driver.

    python -m repro.launch.train --arch qwen2-7b --reduced \\
        --steps 200 --seq-len 128 --global-batch 16 --ckpt-dir /tmp/ckpt

On this CPU container ``--reduced`` swaps in the smoke-scale config of the
same family; on a real fleet the full config + production mesh apply.  The
driver wires together every substrate: config → model → MCOP placement
report → data pipeline → sharded train step → checkpoint/restore (resume
is automatic if the checkpoint dir has state) → adaptive repartition hooks.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compression", default="none", choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduce_config
    from repro.core.placement import TPUV5E_TIER, plan_placement
    from repro.data import DataConfig, SyntheticLMDataset
    from repro.models.transformer import build_model
    from repro.profilers.program import stage_specs
    from repro.train import AdamWConfig, TrainConfig, init_train_state, make_train_step
    from repro.checkpoint import CheckpointStore
    from repro.configs.base import ShapeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)

    # --- MCOP placement report (the paper's pass, on this model) --------
    shape = ShapeConfig("cli", "train", args.seq_len, args.global_batch)
    plan = plan_placement(
        stage_specs(cfg, shape, group=max(cfg.n_layers // 8, 1)),
        dataclasses.replace(TPUV5E_TIER, name="local", chips=128),
        dataclasses.replace(TPUV5E_TIER, name="remote", chips=128),
    )
    print(
        f"[train] MCOP placement: cut={plan.mcop_cost:.3e}s "
        f"boundary={plan.contiguous_boundary} cut_bytes={plan.cut_bytes:.3e}",
        flush=True,
    )

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params", flush=True)

    data = SyntheticLMDataset(
        DataConfig(
            seq_len=args.seq_len,
            global_batch=args.global_batch,
            vocab_size=cfg.vocab_size,
            seed=args.seed,
        ),
        cfg,
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                              total_steps=args.steps),
        n_micro=args.n_micro,
        compression=args.compression,
    )
    state = init_train_state(params, tcfg)
    step_fn = jax.jit(make_train_step(lambda p, b: model.train_loss(p, b), tcfg),
                      donate_argnums=(0, 1))

    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if store and store.latest_step() is not None:
        start, (state.params, state.opt_state), extra = store.restore_latest(
            (state.params, state.opt_state)
        )
        print(f"[train] resumed from step {start}", flush=True)

    rng = jax.random.PRNGKey(args.seed + 1)
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = data.batch(step)
        rng, sub = jax.random.split(rng)
        state.params, state.opt_state, state.comp_state, m = step_fn(
            state.params, state.opt_state, state.comp_state, batch, sub
        )
        losses.append(float(m["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step - start + 1) * args.seq_len * args.global_batch / max(dt, 1e-9)
            print(
                f"[train] step {step:5d} loss {losses[-1]:.4f} "
                f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f} "
                f"tok/s {tok_s:,.0f}",
                flush=True,
            )
        if store and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            store.save_async(step + 1, (state.params, state.opt_state),
                             extra={"arch": cfg.name})
    if store:
        store.wait()
        store.save(args.steps, (state.params, state.opt_state),
                   extra={"arch": cfg.name})
    print(
        f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f} "
        f"({np.mean(losses[:5]):.3f}→{np.mean(losses[-5:]):.3f} smoothed)",
        flush=True,
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
