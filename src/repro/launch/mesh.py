"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else sees the real device count).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh", "POD_CHIPS"]

POD_CHIPS = 256  # one v5e pod = 16×16


def _mk(shape, axes) -> Mesh:
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod, or 2×16×16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(*, data: int | None = None, model: int = 1) -> Mesh:
    """Mesh over whatever devices actually exist (tests / examples)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    assert data * model == n, (data, model, n)
    return _mk((data, model), ("data", "model"))
