"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else sees the real device count).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_solver_mesh",
    "use_mesh",
    "POD_CHIPS",
]

POD_CHIPS = 256  # one v5e pod = 16×16


def _mk(shape, axes) -> Mesh:
    # axis_types landed after jax 0.4.x; fall back to the plain signature
    # so the mesh builders work across the jax versions the repo supports.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod, or 2×16×16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(*, data: int | None = None, model: int = 1) -> Mesh:
    """Mesh over whatever devices actually exist (tests / examples)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    assert data * model == n, (data, model, n)
    return _mk((data, model), ("data", "model"))


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for sharded jit compilation.

    ``jax.set_mesh`` where it exists; on the older jax line the ``Mesh``
    object is itself the equivalent context manager (it installs the
    axis-resource environment ``in_shardings``/``out_shardings`` compile
    against).
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def make_solver_mesh(devices=None) -> Mesh:
    """1-D mesh over the solver fleet's devices, axis name ``"solve"``.

    The MCOP shard dispatcher (``repro.core.mcop_shard``) splits a tick's
    solve batch along this axis: one shard of graphs per device, gathered
    back bit-identically.  ``devices=None`` takes every device the
    process sees (``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    simulates an N-device fleet on CPU hosts).
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    if not devs:
        raise ValueError("cannot build a solver mesh over zero devices")
    return Mesh(np.array(devs), ("solve",))
