"""Gradient compression for DP all-reduces (distributed-optimization layer).

Two schemes, composable with the trainer's gradient accumulation:

* **Top-k sparsification with error feedback** — keep the k largest-|g|
  entries per leaf, accumulate the residual locally and add it back next
  step (memory = one extra grad copy).  Classic DGC/EF-SGD; keeps SGD
  convergence under mild assumptions because the residual is eventually
  applied.

* **Int8 stochastic-rounding quantization** — linear quantization of each
  leaf to int8 with a per-leaf scale, stochastic rounding to keep the
  estimator unbiased; 4× fewer bytes on the wire than bf16.

Both are *simulated-wire* implementations: compress → (optionally sum
across replicas) → decompress, written so the compressed representation is
what would cross the network.  ``wire_bytes`` reports exactly what the
roofline's collective term should charge — EXPERIMENTS.md uses it for the
compression ablation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "CompressionState",
    "init_compression_state",
    "topk_compress_with_ef",
    "int8_compress",
    "int8_decompress",
    "wire_bytes",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    """Error-feedback residuals, one per grad leaf (same pytree)."""

    residual: Any


def init_compression_state(grads_like: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


# ----------------------------------------------------------------------
# Top-k with error feedback
# ----------------------------------------------------------------------


def _topk_leaf(g: jnp.ndarray, r: jnp.ndarray, frac: float):
    """Returns (sparse grad to send, new residual)."""
    acc = g.astype(jnp.float32) + r
    flat = acc.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    sent = jnp.where(mask, flat, 0.0)
    new_r = flat - sent
    return sent.reshape(g.shape).astype(g.dtype), new_r.reshape(g.shape)


def topk_compress_with_ef(
    grads: Any, state: CompressionState, *, frac: float = 0.01
) -> tuple[Any, CompressionState]:
    """Sparsify each leaf to its top-``frac`` entries; bank the residual.

    The returned grads are dense tensors with zeros outside the top-k —
    the all-reduce still works unmodified (sparse sum == dense sum of
    sparsified tensors); the wire format would be (indices, values) of
    size ``wire_bytes(grads, scheme="topk", frac=frac)``.
    """
    out = jax.tree_util.tree_map(
        lambda g, r: _topk_leaf(g, r, frac), grads, state.residual,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )
    sent = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return sent, CompressionState(residual=resid)


# ----------------------------------------------------------------------
# Int8 stochastic quantization
# ----------------------------------------------------------------------


def int8_compress(grads: Any, rng: jax.Array) -> tuple[Any, Any]:
    """Per-leaf linear int8 quantization with stochastic rounding.

    Returns (q8 pytree, scales pytree).  E[decompress(q8)] == grads.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(rng, len(leaves))

    def q(leaf, key):
        g = leaf.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
        x = g / scale
        lo = jnp.floor(x)
        p_up = x - lo
        up = jax.random.uniform(key, x.shape) < p_up
        q_val = jnp.clip(lo + up.astype(jnp.float32), -127, 127).astype(jnp.int8)
        return q_val, scale

    qs = [q(l, k) for l, k in zip(leaves, keys)]
    q8 = jax.tree_util.tree_unflatten(treedef, [a for a, _ in qs])
    scales = jax.tree_util.tree_unflatten(treedef, [s for _, s in qs])
    return q8, scales


def int8_decompress(q8: Any, scales: Any, dtype=jnp.float32) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype), q8, scales
    )


# ----------------------------------------------------------------------
# Wire accounting (feeds the roofline collective term)
# ----------------------------------------------------------------------


def wire_bytes(grads: Any, *, scheme: str, frac: float = 0.01) -> int:
    """Bytes one replica would put on the wire for a single all-reduce."""
    n = sum(int(l.size) for l in jax.tree_util.tree_leaves(grads))
    if scheme == "none":  # bf16 dense
        return 2 * n
    if scheme == "int8":
        return n + 4 * len(jax.tree_util.tree_leaves(grads))  # values + scales
    if scheme == "topk":  # (int32 index + f16 value) per kept entry
        k = sum(
            max(1, int(l.size * frac)) for l in jax.tree_util.tree_leaves(grads)
        )
        return 6 * k
    raise ValueError(scheme)
