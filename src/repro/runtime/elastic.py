"""Elastic scaling & straggler mitigation — the paper's adaptive loop at
cluster scale.

The paper re-partitions when the *environment* drifts (bandwidth, cloud
speed).  On a TPU fleet the same events are: chips/pods lost or added
(changes tier compute capacity ⇒ the speedup factor F), and stragglers
(changes the *effective* tier speed).  Both are routed through the same
MCOP re-partitioning path via :class:`ElasticMeshManager`.

Nothing here touches real hardware: failures are *injected* (tests drive
``mark_failed``/``heartbeat`` with a fake clock), and the manager's output
is the thing a real deployment would act on — a new mesh shape, new tier
specs, and a fresh MCOP placement.

:meth:`ElasticMeshManager.resize` solves synchronously;
:meth:`ElasticMeshManager.submit_resize` instead enqueues the solve on a
:class:`repro.service.broker.OffloadBroker`, where it coalesces with
per-user controller requests into the same per-bucket batched dispatch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.cost_models import Environment
from repro.core.placement import (
    PlacementPlan,
    StageSpec,
    TierSpec,
    _finalize_plan,
    build_stage_wcg,
    plan_placement,
)

__all__ = [
    "DeviceState",
    "HeartbeatMonitor",
    "ElasticMeshManager",
    "ElasticEvent",
    "PendingElasticEvent",
]


@dataclasses.dataclass
class DeviceState:
    device_id: int
    last_heartbeat: float
    step_time_ewma: float = 0.0  # seconds per step, EWMA
    alive: bool = True


class HeartbeatMonitor:
    """Deadline-based failure & straggler detection with an injectable clock.

    * a device missing ``deadline`` seconds of heartbeats is *failed*;
    * a device whose EWMA step time exceeds ``straggler_factor`` × the
      fleet median is a *straggler* — its microbatches are reassigned
      (returned by :meth:`reassignment`) rather than the whole step
      waiting on it.
    """

    def __init__(
        self,
        device_ids: Sequence[int],
        *,
        deadline: float = 30.0,
        straggler_factor: float = 2.0,
        ewma: float = 0.3,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.clock = clock
        self.deadline = deadline
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        now = clock()
        self.devices = {d: DeviceState(d, last_heartbeat=now) for d in device_ids}

    # ------------------------------------------------------------------
    def heartbeat(self, device_id: int, step_time: float | None = None) -> None:
        st = self.devices[device_id]
        st.last_heartbeat = self.clock()
        st.alive = True
        if step_time is not None:
            st.step_time_ewma = (
                step_time
                if st.step_time_ewma == 0.0
                else (1 - self.ewma) * st.step_time_ewma + self.ewma * step_time
            )

    def mark_failed(self, device_id: int) -> None:
        self.devices[device_id].alive = False

    # ------------------------------------------------------------------
    def failed(self) -> list[int]:
        now = self.clock()
        out = []
        for d, st in self.devices.items():
            if not st.alive or (now - st.last_heartbeat) > self.deadline:
                st.alive = False
                out.append(d)
        return sorted(out)

    def stragglers(self) -> list[int]:
        alive = [st for st in self.devices.values() if st.alive and st.step_time_ewma > 0]
        if len(alive) < 2:
            return []
        median = float(np.median([st.step_time_ewma for st in alive]))
        return sorted(
            st.device_id
            for st in alive
            if st.step_time_ewma > self.straggler_factor * median
        )

    def reassignment(self, n_micro: int) -> dict[int, int]:
        """Microbatches per alive device, shifting load off stragglers.

        Straggler devices get half weight; failed devices get zero.  The
        returned dict maps device_id → microbatch count, summing to
        ``n_micro`` (deterministic largest-remainder rounding).
        """
        self.failed()  # refresh liveness
        slow = set(self.stragglers())
        weights = {
            d: (0.0 if not st.alive else (0.5 if d in slow else 1.0))
            for d, st in self.devices.items()
        }
        total = sum(weights.values())
        if total == 0:
            raise RuntimeError("no alive devices to assign microbatches to")
        raw = {d: n_micro * w / total for d, w in weights.items()}
        base = {d: int(np.floor(r)) for d, r in raw.items()}
        rem = n_micro - sum(base.values())
        order = sorted(raw, key=lambda d: raw[d] - base[d], reverse=True)
        for d in order[:rem]:
            base[d] += 1
        return base


@dataclasses.dataclass
class ElasticEvent:
    step: int
    reason: str                    # "failure" | "scale_up" | "straggler"
    tier_local: TierSpec
    tier_remote: TierSpec
    plan: PlacementPlan


class ElasticMeshManager:
    """Rebuilds tier specs on chip-count changes and re-runs MCOP.

    The paper's F = cloud_speed/device_speed becomes
    (chips_remote·peak)/(chips_local·peak); losing chips on either side
    changes F and therefore potentially the optimal cut — exactly the
    paper's "environment change ⇒ re-partition" loop (Fig. 1).
    """

    def __init__(
        self,
        stages: Sequence[StageSpec],
        tier_local: TierSpec,
        tier_remote: TierSpec,
        *,
        backend: str = "reference",
    ):
        self.stages = list(stages)
        self.tier_local = tier_local
        self.tier_remote = tier_remote
        self.backend = backend
        self.events: list[ElasticEvent] = []
        # monotone resize serials: a pending (async) resolve must never
        # clobber self.plan with a plan older than the installed one
        self._resize_serial = 0
        self._plan_serial = 0
        self.plan = plan_placement(
            self.stages, tier_local, tier_remote, backend=backend
        )

    @property
    def speedup(self) -> float:
        return self.tier_remote.total_flops / self.tier_local.total_flops

    def _apply_chip_counts(
        self, local_chips: int | None, remote_chips: int | None
    ) -> None:
        """Shared tier mutation for resize()/submit_resize().  Validates
        BEFORE mutating so a rejected resize leaves the tiers intact."""
        new_local = self.tier_local.chips if local_chips is None else local_chips
        new_remote = self.tier_remote.chips if remote_chips is None else remote_chips
        if min(new_local, new_remote) <= 0:
            raise RuntimeError("a tier lost all its chips; cannot re-place")
        if local_chips is not None:
            self.tier_local = dataclasses.replace(self.tier_local, chips=local_chips)
        if remote_chips is not None:
            self.tier_remote = dataclasses.replace(self.tier_remote, chips=remote_chips)

    def resize(self, step: int, *, local_chips: int | None = None,
               remote_chips: int | None = None, reason: str = "failure") -> ElasticEvent:
        self._apply_chip_counts(local_chips, remote_chips)
        self._resize_serial += 1
        self._plan_serial = self._resize_serial
        self.plan = plan_placement(
            self.stages, self.tier_local, self.tier_remote, backend=self.backend
        )
        ev = ElasticEvent(step, reason, self.tier_local, self.tier_remote, self.plan)
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------------
    def submit_resize(
        self,
        broker,
        tenant: str,
        step: int,
        *,
        local_chips: int | None = None,
        remote_chips: int | None = None,
        reason: str = "failure",
    ) -> "PendingElasticEvent":
        """Async :meth:`resize`: enqueue the MCOP solve on an OffloadBroker.

        Elastic events are just another client of the serving tier: the
        stage WCG is rebuilt under the new chip counts and submitted to
        the broker's queue, joining user solves in the same coalesced
        per-bucket dispatch at the next tick.  Recurring fleet states are
        cache hits — the bin key encodes everything the stage WCG is
        built from (link bandwidth, F, and the *absolute* per-tier
        throughputs, because compute times scale with total FLOPs while
        transfer times don't: two fleets with equal F but different
        sizes can have different optimal cuts).  The returned handle
        finalizes the plan — call :meth:`PendingElasticEvent.resolve`
        after ``broker.tick()``.  ``tenant`` must be registered on the
        broker (``profile=None`` raw-graph tenants are fine).
        """
        self._apply_chip_counts(local_chips, remote_chips)
        bw = min(self.tier_local.link_bw, self.tier_remote.link_bw)
        g = build_stage_wcg(self.stages, self.tier_local, self.tier_remote)
        # the quantizer bins all six Environment fields, so the power
        # slots carry the absolute tier scales into the key
        bin_env = Environment(
            bandwidth_up=bw,
            bandwidth_down=bw,
            speedup=self.speedup,
            p_compute=self.tier_local.total_flops,
            p_idle=self.tier_remote.total_flops,
            p_transfer=min(
                self.tier_local.total_hbm_bw, self.tier_remote.total_hbm_bw
            ),
        )
        # elastic events ride the broker's priority lane: a fleet resize
        # re-places before user refreshes drained in the same tick
        future = broker.submit_graph(tenant, g, bin_env, lane="elastic")
        self._resize_serial += 1
        return PendingElasticEvent(
            manager=self,
            step=step,
            reason=reason,
            future=future,
            graph=g,
            bw=bw,
            tier_local=self.tier_local,
            tier_remote=self.tier_remote,
            serial=self._resize_serial,
        )


@dataclasses.dataclass
class PendingElasticEvent:
    """A resize whose MCOP solve is in flight on the broker.

    Tier specs are *captured at submit time*: overlapping resizes may
    mutate the manager before this one resolves, and the recorded event
    must describe the fleet state its plan was actually solved on.
    """

    manager: ElasticMeshManager
    step: int
    reason: str
    future: object  # repro.service.broker.PlacementFuture
    graph: object   # the stage WCG the solve was priced on
    bw: float
    tier_local: TierSpec
    tier_remote: TierSpec
    serial: int     # manager resize serial at submit time

    @property
    def done(self) -> bool:
        return self.future.done

    def resolve(self) -> ElasticEvent:
        """Finalize the plan from the broker reply and record the event.

        Raises if the broker has not ticked yet.  The reply is already
        clamped and priced on :attr:`graph`, so the resulting plan
        matches a synchronous :meth:`ElasticMeshManager.resize` under
        the same tier state.  ``manager.plan`` is only replaced when no
        newer resize has been installed meanwhile (out-of-order resolves
        never roll the fleet back to a stale plan).
        """
        reply = self.future.result
        mgr = self.manager
        plan = _finalize_plan(self.graph, reply.result, self.bw)
        if self.serial >= mgr._plan_serial:
            mgr.plan = plan
            mgr._plan_serial = self.serial
        ev = ElasticEvent(
            self.step, self.reason, self.tier_local, self.tier_remote, plan
        )
        mgr.events.append(ev)
        return ev
