"""Elastic scaling & straggler mitigation — the paper's adaptive loop at
cluster scale.

The paper re-partitions when the *environment* drifts (bandwidth, cloud
speed).  On a TPU fleet the same events are: chips/pods lost or added
(changes tier compute capacity ⇒ the speedup factor F), and stragglers
(changes the *effective* tier speed).  Both are routed through the same
MCOP re-partitioning path via :class:`ElasticMeshManager`.

Nothing here touches real hardware: failures are *injected* (tests drive
``mark_failed``/``heartbeat`` with a fake clock), and the manager's output
is the thing a real deployment would act on — a new mesh shape, new tier
specs, and a fresh MCOP placement.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.placement import PlacementPlan, StageSpec, TierSpec, plan_placement

__all__ = ["DeviceState", "HeartbeatMonitor", "ElasticMeshManager", "ElasticEvent"]


@dataclasses.dataclass
class DeviceState:
    device_id: int
    last_heartbeat: float
    step_time_ewma: float = 0.0  # seconds per step, EWMA
    alive: bool = True


class HeartbeatMonitor:
    """Deadline-based failure & straggler detection with an injectable clock.

    * a device missing ``deadline`` seconds of heartbeats is *failed*;
    * a device whose EWMA step time exceeds ``straggler_factor`` × the
      fleet median is a *straggler* — its microbatches are reassigned
      (returned by :meth:`reassignment`) rather than the whole step
      waiting on it.
    """

    def __init__(
        self,
        device_ids: Sequence[int],
        *,
        deadline: float = 30.0,
        straggler_factor: float = 2.0,
        ewma: float = 0.3,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.clock = clock
        self.deadline = deadline
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        now = clock()
        self.devices = {d: DeviceState(d, last_heartbeat=now) for d in device_ids}

    # ------------------------------------------------------------------
    def heartbeat(self, device_id: int, step_time: float | None = None) -> None:
        st = self.devices[device_id]
        st.last_heartbeat = self.clock()
        st.alive = True
        if step_time is not None:
            st.step_time_ewma = (
                step_time
                if st.step_time_ewma == 0.0
                else (1 - self.ewma) * st.step_time_ewma + self.ewma * step_time
            )

    def mark_failed(self, device_id: int) -> None:
        self.devices[device_id].alive = False

    # ------------------------------------------------------------------
    def failed(self) -> list[int]:
        now = self.clock()
        out = []
        for d, st in self.devices.items():
            if not st.alive or (now - st.last_heartbeat) > self.deadline:
                st.alive = False
                out.append(d)
        return sorted(out)

    def stragglers(self) -> list[int]:
        alive = [st for st in self.devices.values() if st.alive and st.step_time_ewma > 0]
        if len(alive) < 2:
            return []
        median = float(np.median([st.step_time_ewma for st in alive]))
        return sorted(
            st.device_id
            for st in alive
            if st.step_time_ewma > self.straggler_factor * median
        )

    def reassignment(self, n_micro: int) -> dict[int, int]:
        """Microbatches per alive device, shifting load off stragglers.

        Straggler devices get half weight; failed devices get zero.  The
        returned dict maps device_id → microbatch count, summing to
        ``n_micro`` (deterministic largest-remainder rounding).
        """
        self.failed()  # refresh liveness
        slow = set(self.stragglers())
        weights = {
            d: (0.0 if not st.alive else (0.5 if d in slow else 1.0))
            for d, st in self.devices.items()
        }
        total = sum(weights.values())
        if total == 0:
            raise RuntimeError("no alive devices to assign microbatches to")
        raw = {d: n_micro * w / total for d, w in weights.items()}
        base = {d: int(np.floor(r)) for d, r in raw.items()}
        rem = n_micro - sum(base.values())
        order = sorted(raw, key=lambda d: raw[d] - base[d], reverse=True)
        for d in order[:rem]:
            base[d] += 1
        return base


@dataclasses.dataclass
class ElasticEvent:
    step: int
    reason: str                    # "failure" | "scale_up" | "straggler"
    tier_local: TierSpec
    tier_remote: TierSpec
    plan: PlacementPlan


class ElasticMeshManager:
    """Rebuilds tier specs on chip-count changes and re-runs MCOP.

    The paper's F = cloud_speed/device_speed becomes
    (chips_remote·peak)/(chips_local·peak); losing chips on either side
    changes F and therefore potentially the optimal cut — exactly the
    paper's "environment change ⇒ re-partition" loop (Fig. 1).
    """

    def __init__(
        self,
        stages: Sequence[StageSpec],
        tier_local: TierSpec,
        tier_remote: TierSpec,
        *,
        backend: str = "reference",
    ):
        self.stages = list(stages)
        self.tier_local = tier_local
        self.tier_remote = tier_remote
        self.backend = backend
        self.events: list[ElasticEvent] = []
        self.plan = plan_placement(
            self.stages, tier_local, tier_remote, backend=backend
        )

    @property
    def speedup(self) -> float:
        return self.tier_remote.total_flops / self.tier_local.total_flops

    def resize(self, step: int, *, local_chips: int | None = None,
               remote_chips: int | None = None, reason: str = "failure") -> ElasticEvent:
        if local_chips is not None:
            self.tier_local = dataclasses.replace(self.tier_local, chips=local_chips)
        if remote_chips is not None:
            self.tier_remote = dataclasses.replace(self.tier_remote, chips=remote_chips)
        if min(self.tier_local.chips, self.tier_remote.chips) <= 0:
            raise RuntimeError("a tier lost all its chips; cannot re-place")
        self.plan = plan_placement(
            self.stages, self.tier_local, self.tier_remote, backend=self.backend
        )
        ev = ElasticEvent(step, reason, self.tier_local, self.tier_remote, self.plan)
        self.events.append(ev)
        return ev
