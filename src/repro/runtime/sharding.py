"""Sharding rules: parameter/activation PartitionSpecs for every family.

The rules are *path-based*: a parameter's position in the pytree plus its
rank decides its PartitionSpec.  All model weights carry a leading stacked
layer axis (scan-over-layers), which is never sharded; the interesting
axes are the trailing two.

Conventions on the production mesh (("pod",) "data", "model"):

* tensor parallelism over "model":
    - attention wq/wk/wv:   (d, H·hd)    → shard output dim  P(None, "model")
    - attention wo:         (H·hd, d)    → shard input dim   P("model", None)
    - FFN wi/wg:            (d, d_ff)    → P(None, "model")
    - FFN wo:               (d_ff, d)    → P("model", None)
    - MoE experts (E, d, f): expert-parallel over "model" → P("model", None, None)
    - embedding (V, d):     vocab-sharded P("model", None)
    - lm_head (d, V):       vocab-sharded P(None, "model")
    - norm scales, biases, small vectors: replicated.
* data parallelism over "data" (and "pod" in the baseline multi-pod
  config): the batch axis of every input/activation.
* sequence parallelism: long-context shapes shard the sequence axis of
  activations over "model" (weights stay TP-sharded; attention for those
  shapes is window/chunk-local, so no cross-shard score matrix exists).

``logical_batch_spec(mesh)`` returns the batch PartitionSpec for whatever
axes exist in the mesh, so the same code serves (data, model) and
(pod, data, model) meshes.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "batch_axes",
    "state_shardings",
    "FSDP_MIN_ELEMENTS",
    "logical_batch_spec",
    "param_spec",
    "param_shardings",
    "input_shardings",
    "shard_params",
    "SOLVE_AXIS",
    "solver_axis",
    "solver_shards",
    "solve_batch_spec",
]


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over ("pod" joins DP when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def logical_batch_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


# ----------------------------------------------------------------------
# Solver-fleet axis plumbing (the MCOP shard dispatcher's mesh contract)
# ----------------------------------------------------------------------

# canonical axis name of a dedicated solver mesh (launch.mesh.make_solver_mesh)
SOLVE_AXIS = "solve"


def solver_axis(mesh: Mesh) -> str:
    """The mesh axis a solve batch shards over.

    A dedicated solver mesh carries the ``"solve"`` axis; on a shared
    production mesh the solver fleet rides the data-parallel axis (the
    model axis stays free for tensor-parallel serving).  Falls back to
    the first axis so any 1-D mesh works unmodified.
    """
    names = mesh.axis_names
    if SOLVE_AXIS in names:
        return SOLVE_AXIS
    if "data" in names:
        return "data"
    return names[0]


def solver_shards(mesh: Mesh) -> int:
    """Device count along the solver axis (the fleet's shard count)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes[solver_axis(mesh)])


def solve_batch_spec(mesh: Mesh) -> P:
    """PartitionSpec sharding a solve batch's leading axis over the fleet."""
    return P(solver_axis(mesh))


# ----------------------------------------------------------------------
# Parameter rules
# ----------------------------------------------------------------------

# (path regex, rank of the *unstacked* param) → spec for the trailing dims.
# Rank counts the non-layer-stacked dimensions.  The spec below is for the
# trailing `rank` axes; leading stack axes are padded with None.
# Order matters: first match wins.
_RULES: list[tuple[str, int, tuple[Any, ...]]] = [
    # --- embeddings / heads -------------------------------------------
    (r"embed/embedding$", 2, ("model", None)),
    (r"lm_head/w$", 2, (None, "model")),
    # --- MoE (expert-parallel over "model") ------------------------------
    (r"moe/router/w$", 2, (None, None)),                    # small, replicated
    (r"moe/shared/(w_gate|w_up)/w$", 2, (None, "model")),
    (r"moe/shared/w_down/w$", 2, ("model", None)),
    (r"moe/(w_gate|w_up|w_down)$", 3, ("model", None, None)),  # (E, d, f)/(E, f, d)
    # --- MLA projections (before generic attn rules) ----------------------
    (r"attn/w_dq/w$", 2, (None, None)),          # d → q_lora (small rank)
    (r"attn/w_uq/w$", 2, (None, "model")),       # q_lora → H·qk_head
    (r"attn/w_dkv/w$", 2, (None, None)),         # d → kv_lora (+rope)
    (r"attn/w_uk/w$", 2, (None, "model")),       # kv_lora → H·nope
    (r"attn/w_uv/w$", 2, (None, "model")),       # kv_lora → H·v_head
    # --- attention ------------------------------------------------------
    (r"(attn|self_attn|cross_attn|shared_attn)/(wq|wk|wv)/w$", 2, (None, "model")),
    (r"(attn|self_attn|cross_attn|shared_attn)/(wq|wk|wv)/b$", 1, ("model",)),
    (r"(attn|self_attn|cross_attn|shared_attn)/wo/w$", 2, ("model", None)),
    # --- dense FFN --------------------------------------------------------
    (r"(ffn|shared_ffn)/(w_gate|w_up)/w$", 2, (None, "model")),
    (r"(ffn|shared_ffn)/w_down/w$", 2, ("model", None)),
    # --- mamba -----------------------------------------------------------
    (r"in_proj/w$", 2, (None, "model")),         # d → (2·d_inner + 2N + H)
    (r"out_proj/w$", 2, ("model", None)),        # d_inner → d
    (r"conv_w$", 2, (None, "model")),            # (K, conv_channels)
    (r"conv_b$", 1, ("model",)),
    # --- xlstm ------------------------------------------------------------
    (r"(wq|wk|wv|w_up|w_gatez|w_in|w_if)/w$", 2, (None, "model")),
    (r"w_down/w$", 2, ("model", None)),
]

_COMPILED = [(re.compile(pat), rank, spec) for pat, rank, spec in _RULES]


# Leaves bigger than this get the FSDP ("data") axis on top of TP —
# ZeRO-3-style 2D weight sharding.  Small tables stay replicated: the
# all-gather would cost more than the memory saved.
FSDP_MIN_ELEMENTS = 1 << 20

# MoE expert-weight layout (§Perf hillclimb knob):
#   "ep_model"          — experts sharded over "model" (+FSDP over "data"):
#                         memory-equivalent but every use all-gathers the
#                         FSDP axis of every expert's weights.
#   "ep_data_tp_model"  — experts sharded over "data" (EP), d_ff over
#                         "model" (TP inside the expert): same per-device
#                         memory, NO per-step weight gathers — tokens move
#                         (all-to-all), weights stay.
_EXPERT_MODE = "ep_model"


def set_expert_sharding(mode: str) -> None:
    global _EXPERT_MODE
    assert mode in ("ep_model", "ep_data_tp_model"), mode
    _EXPERT_MODE = mode


def param_spec(
    path: str, shape: tuple[int, ...], mesh: Mesh, *, fsdp: bool = True
) -> P:
    """PartitionSpec for one parameter, given its '/'-joined tree path.

    TP rule first (the table above), then — for large leaves — the first
    still-unsharded trailing axis that divides the "data" axis is sharded
    over "data" (FSDP / ZeRO-3).  Optimizer moments inherit these specs
    leaf-for-leaf, so parameter+optimizer memory scales with 1/(TP·DP).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    have_model = "model" in sizes

    def apply_fsdp(lead_n: int, fixed: list[Any]) -> list[Any]:
        if not fsdp or "data" not in sizes or sizes["data"] == 1:
            return fixed
        import math as _math

        if _math.prod(shape) < FSDP_MIN_ELEMENTS:
            return fixed
        tail_shape = shape[lead_n:]
        for i, (dim, ax) in enumerate(zip(tail_shape, fixed)):
            if ax is None and dim % sizes["data"] == 0 and dim > 1:
                fixed[i] = "data"
                break
        return fixed

    for pat, rank, trailing in _COMPILED:
        if pat.search(path):
            if len(shape) < rank:
                break
            lead_n = len(shape) - rank
            if (
                _EXPERT_MODE == "ep_data_tp_model"
                and rank == 3
                and re.search(r"moe/(w_gate|w_up|w_down)$", path)
            ):
                # (E, d, f) / (E, f, d): experts over "data", d_ff over "model"
                trailing = (
                    ("data", None, "model")
                    if path.endswith(("w_gate", "w_up"))
                    else ("data", "model", None)
                )
                spec = tuple(
                    (a if (a is None or a in sizes) else None) for a in trailing
                )
                fixed = []
                for dim, ax in zip(shape[lead_n:], spec):
                    if ax is not None and dim % sizes.get(ax, 1) != 0:
                        ax = None
                    fixed.append(ax)
                return P(*((None,) * lead_n), *fixed)  # no extra FSDP
            spec = tuple(
                (a if (a is None or have_model) else None) for a in trailing
            )
            fixed = []
            for dim, ax in zip(shape[lead_n:], spec):
                if ax is not None and dim % sizes.get(ax, 1) != 0:
                    ax = None  # axis doesn't divide the mesh — replicate
                fixed.append(ax)
            fixed = apply_fsdp(lead_n, fixed)
            return P(*((None,) * lead_n), *fixed)
    # unmatched: replicate small leaves, FSDP-shard anything big
    fixed = apply_fsdp(0, [None] * len(shape))
    return P(*fixed) if any(a is not None for a in fixed) else P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shapes: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """Pytree of NamedShardings matching a pytree of ShapeDtypeStructs."""

    def leaf(path, x):
        return NamedSharding(
            mesh, param_spec(_path_str(path), tuple(x.shape), mesh, fsdp=fsdp)
        )

    return jax.tree_util.tree_map_with_path(leaf, params_shapes)


def state_shardings(
    state_shapes: Any,
    mesh: Mesh,
    *,
    batch_size: int | None = None,
    prefer: str = "largest",
) -> Any:
    """Shardings for decode caches / recurrent states (heuristic, documented).

    Per leaf: the axis equal to ``batch_size`` (searched left-to-right)
    shards over the DP axes; then one remaining axis divisible by the
    "model" axis shards over "model":

      * ``prefer="largest"`` — the largest such axis (for KV caches this is
        the sequence axis — flash-decoding-style sequence sharding);
      * ``prefer="last"`` — the right-most such axis (head_dim/feature
        sharding; keeps the cache layout aligned with TP weight sharding).

    Scalars and tiny leaves stay replicated.  ``prefer`` is a §Perf
    hillclimbing knob — the two layouts trade softmax-stat all-reduces
    against score-matrix all-reduces in the decode attention.
    """
    ba = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([sizes[a] for a in ba])) if ba else 1
    tp = sizes.get("model", 1)

    def leaf(x):
        spec: list[Any] = [None] * x.ndim
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        b_axis = None
        if batch_size is not None and dp > 1 and batch_size % dp == 0:
            for i, dim in enumerate(x.shape):
                if dim == batch_size:
                    spec[i] = ba
                    b_axis = i
                    break
        if tp > 1:
            cand = [
                (dim, i)
                for i, dim in enumerate(x.shape)
                if i != b_axis and spec[i] is None and dim % tp == 0 and dim > 1
            ]
            if cand:
                if prefer == "last":
                    _, i = max((i, i) for _, i in cand)
                else:
                    _, i = max(cand)
                spec[i] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(leaf, state_shapes)


def input_shardings(batch_shapes: Any, mesh: Mesh, *, shard_seq: bool = False) -> Any:
    """Batch inputs: shard the leading batch axis over the DP axes.

    ``shard_seq=True`` additionally shards axis 1 (sequence) over "model" —
    the sequence-parallel layout used by the long-context cells.
    """
    ba = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([sizes[a] for a in ba])) if ba else 1

    def leaf(path, x):
        if x.ndim == 0 or x.shape[0] % max(dp, 1) != 0:
            return NamedSharding(mesh, P())
        axes: list[Any] = [ba if ba else None]
        if (
            shard_seq
            and x.ndim >= 2
            and "model" in mesh.axis_names
            and x.shape[1] % sizes["model"] == 0
            and x.shape[1] > 1
        ):
            axes.append("model")
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(leaf, batch_shapes)


def shard_params(params: Any, mesh: Mesh) -> Any:
    """Device-put a real param pytree according to the rules."""
    shardings = param_shardings(
        jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        mesh,
    )
    return jax.tree_util.tree_map(jax.device_put, params, shardings)
