"""MCOP-driven pipeline execution over the ``pod`` mesh axis.

The placement mapper (repro.core.placement) turns an MCOP partition of the
layer graph into a *contiguous* stage split; this module executes that
split as a GPipe-style pipeline inside ``shard_map``:

* stage parameters are stacked on a leading ``n_stages`` axis and sharded
  ``P("pod")`` — each pod holds exactly its stage's weights;
* activations hop pods with ``jax.lax.ppermute`` (the cut edge of the WCG
  — the paper's `E_cut` — becomes exactly one collective-permute per
  microbatch per boundary, which is what the roofline's collective term
  charges);
* the schedule is the classic ``n_micro + n_stages − 1`` slot ramp; every
  pod computes every slot (SPMD) and validity is masked, so the HLO is
  identical across devices;
* outputs are only real on the last pod and are broadcast back with a
  masked ``psum`` over "pod" — one extra collective, charged to the
  roofline.

The paper's cost model maps 1:1: per-microbatch stage time = node weight
``w(v)`` of the merged stage vertex; the ppermute bytes = cut edge weight
``w(e)·B``; the pipeline bubble = the paper's "idle power while the cloud
computes" energy term (§4.3.2).

Within a stage, tensors stay sharded over ("data", "model") exactly as in
the non-pipelined path — shard_map only manages the "pod" axis; the body
re-enters the auto-sharding world for the other axes via
``jax.experimental.shard_map``'s ``check_rep=False`` escape.
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # JAX moved shard_map out of experimental in 0.6+
    from jax import shard_map as _shard_map_mod  # type: ignore

    shard_map = _shard_map_mod  # jax.shard_map is the function itself
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

__all__ = ["stack_stage_params", "pipeline_apply", "pipeline_spec_for"]


def stack_stage_params(layer_params: Any, n_stages: int) -> Any:
    """(L, …) stacked per-layer params → (n_stages, L/n_stages, …)."""

    def leaf(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree_util.tree_map(leaf, layer_params)


def pipeline_spec_for(params_stacked: Any) -> Any:
    """P("pod") on the stage axis for every stacked stage-param leaf."""
    return jax.tree_util.tree_map(lambda _: P("pod"), params_stacked)


def pipeline_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    params_stacked: Any,          # (n_stages, L/S, …) leaves
    x: jnp.ndarray,               # (B, S, d) activations entering stage 0
    *,
    mesh: Mesh,
    n_micro: int,
    axis: str = "pod",
) -> jnp.ndarray:
    """Run ``x`` through the staged blocks as a microbatched pipeline.

    ``stage_fn(stage_params, x_micro) -> y_micro`` must preserve the
    activation shape (it is typically a ``lax.scan`` over the stage's
    layer group).  The batch axis of ``x`` must divide ``n_micro``.
    """
    n_stages = mesh.shape[axis]
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    # Activations: batch sharded over the remaining data axes, replicated
    # over "pod" (each pod sees the full microbatch stream; only pod 0's
    # copy is semantically the input — SPMD masking handles the rest).
    data_axes = tuple(a for a in ("data",) if a in other_axes)
    x_spec = P(data_axes if data_axes else None)

    # the replication-check escape hatch was renamed check_rep→check_vma
    # across jax versions; pass whichever this jax accepts
    check_kw = (
        "check_vma"
        if "check_vma" in inspect.signature(shard_map).parameters
        else "check_rep"
    )

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(pipeline_spec_for(params_stacked), x_spec),
        out_specs=x_spec,
        **{check_kw: False},
    )
    def run(stage_params, x_local):
        p_local = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        pod = jax.lax.axis_index(axis)
        b = x_local.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        mb = b // n_micro
        micro = x_local.reshape(n_micro, mb, *x_local.shape[1:])

        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]
        n_slots = n_micro + n_stages - 1

        def slot(carry, t):
            in_buf, outs = carry
            my_idx = t - pod
            # stage 0 consumes fresh microbatches; later stages consume
            # whatever arrived over the wire last slot.
            feed_idx = jnp.clip(my_idx, 0, n_micro - 1)
            x_in = jnp.where(pod == 0, micro[feed_idx], in_buf)
            y = stage_fn(p_local, x_in)
            # hop pod i → i+1 (the WCG cut edge)
            in_buf = jax.lax.ppermute(y, axis, fwd_perm)
            # last pod banks its (valid) result
            valid = (my_idx >= 0) & (my_idx < n_micro) & (pod == n_stages - 1)
            write = jnp.where(valid, y, outs[feed_idx])
            outs = jax.lax.dynamic_update_slice(
                outs, write[None], (feed_idx,) + (0,) * y.ndim
            )
            return (in_buf, outs), None

        in_buf0 = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros_like(micro)
        (_, outs), _ = jax.lax.scan(slot, (in_buf0, outs0), jnp.arange(n_slots))

        # results live on the last pod only — masked psum broadcasts them
        outs = jax.lax.psum(
            jnp.where(pod == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs.reshape(b, *x_local.shape[1:])

    return run(params_stacked, x)
