"""Distributed runtime: sharding rules, pipeline, compression, elasticity."""

from repro.runtime.sharding import (
    batch_axes,
    state_shardings,
    input_shardings,
    logical_batch_spec,
    param_shardings,
    param_spec,
    shard_params,
)
from repro.runtime.compression import (
    CompressionState,
    init_compression_state,
    int8_compress,
    int8_decompress,
    topk_compress_with_ef,
    wire_bytes,
)
from repro.runtime.elastic import (
    DeviceState,
    ElasticEvent,
    ElasticMeshManager,
    HeartbeatMonitor,
)
from repro.runtime.pipeline import (
    pipeline_apply,
    pipeline_spec_for,
    stack_stage_params,
)

__all__ = [
    "batch_axes",
    "state_shardings",
    "input_shardings",
    "logical_batch_spec",
    "param_shardings",
    "param_spec",
    "shard_params",
    "CompressionState",
    "init_compression_state",
    "int8_compress",
    "int8_decompress",
    "topk_compress_with_ef",
    "wire_bytes",
    "DeviceState",
    "ElasticEvent",
    "ElasticMeshManager",
    "HeartbeatMonitor",
    "pipeline_apply",
    "pipeline_spec_for",
    "stack_stage_params",
]
