"""Shared model building blocks (pure-functional JAX, explicit param pytrees).

Conventions used across the model zoo:

* Parameters are nested dicts of ``jnp.ndarray``.  Layer stacks carry a
  leading layer axis and are consumed with ``jax.lax.scan`` so compiled
  HLO size is independent of depth (critical for the 512-device dry-run).
* ``init_*`` functions take an ``rng`` **or** run under ``jax.eval_shape``
  for allocation-free initialization (the dry-run path).
* Compute dtype is configurable (bf16 default); normalization statistics,
  softmax and losses accumulate in float32.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "Params",
    "dense_init",
    "embed_init",
    "rmsnorm_init",
    "linear",
    "rmsnorm",
    "make_rope_cache",
    "apply_rope",
    "apply_mrope",
    "softmax_cross_entropy",
    "softmax_cross_entropy_chunked",
    "dtype_of",
]

Params = dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


# ----------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------


def dense_init(
    rng, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.bfloat16
) -> Params:
    scale = 1.0 / math.sqrt(d_in)
    k_w, _ = jax.random.split(rng)
    p: Params = {"w": (jax.random.normal(k_w, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def embed_init(rng, vocab: int, d_model: int, *, dtype=jnp.bfloat16) -> Params:
    e = jax.random.normal(rng, (vocab, d_model), jnp.float32) * 0.02
    return {"embedding": e.astype(dtype)}


def rmsnorm_init(d: int, *, dtype=jnp.float32) -> Params:
    # norm scales stay float32: they are tiny and precision-sensitive
    return {"scale": jnp.ones((d,), dtype)}


# ----------------------------------------------------------------------
# Core ops
# ----------------------------------------------------------------------


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf / rms) * p["scale"]).astype(dt)


def _rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> jnp.ndarray:
    """(…, dim/2) rotation angles for integer positions."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv_freq


def make_rope_cache(seq_len: int, dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    ang = _rope_angles(jnp.arange(seq_len), dim, theta)  # (S, dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x_even, x_odd) by the given angles.  x: (..., d)."""
    x1, x2 = jnp.split(x, 2, axis=-1)  # neox-style half split
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    ang = _rope_angles(positions, hd, theta)          # (B, S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return _rotate(x, cos[..., None, :], sin[..., None, :])


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (B, S, 3) — temporal / height / width
    position ids.  The hd/2 rotary frequencies are partitioned into three
    contiguous sections, each driven by its own position stream; for pure
    text all three streams are equal and M-RoPE degenerates to RoPE
    (tested property).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    ang_t = _rope_angles(positions[..., 0], hd, theta)  # (B, S, hd/2)
    ang_h = _rope_angles(positions[..., 1], hd, theta)
    ang_w = _rope_angles(positions[..., 2], hd, theta)
    s0, s1, _ = sections
    ang = jnp.concatenate(
        [ang_t[..., :s0], ang_h[..., s0 : s0 + s1], ang_w[..., s0 + s1 :]], axis=-1
    )
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return _rotate(x, cos[..., None, :], sin[..., None, :])


def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, *, ignore_id: int = -100
) -> jnp.ndarray:
    """Mean token NLL in float32.  logits: (..., V); labels: (...)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - gold
    mask = labels != ignore_id
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


def softmax_cross_entropy_chunked(
    h: jnp.ndarray,           # (B, S, d) final hidden states (already normed)
    head: Params,             # lm_head {"w": (d, V)}
    labels: jnp.ndarray,      # (B, S)
    *,
    chunk: int = 8192,
    ignore_id: int = -100,
) -> jnp.ndarray:
    """Cross-entropy without materialising the (B, S, V) logits tensor.

    Scans vocab chunks with an online logsumexp; live memory is one
    (B, S, chunk) block.  The scan body is rematerialised in the backward
    pass, trading ~2× head FLOPs for a V/chunk reduction in peak logits
    memory — the §Perf memory-term lever for large-vocab training cells.
    """
    b, s, d = h.shape
    w = head["w"]
    v = w.shape[1]
    pad = (-v) % chunk
    n_chunks = (v + pad) // chunk
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    w_chunks = wp.reshape(d, n_chunks, chunk).transpose(1, 0, 2)  # (NC, d, c)
    labels_c = labels.clip(0)

    def body(carry, inputs):
        m, l, gold = carry
        wc, ci = inputs
        logits = (h @ wc).astype(jnp.float32)                  # (B, S, c)
        col0 = ci * chunk
        cols = col0 + jnp.arange(chunk)
        valid = cols < v
        logits = jnp.where(valid[None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(logits - m_new[..., None]).sum(-1)
        # gather the gold logit if it falls in this chunk
        in_chunk = (labels_c >= col0) & (labels_c < col0 + chunk)
        idx = (labels_c - col0).clip(0, chunk - 1)
        gold_here = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, gold_here, gold)
        return (m_new, l, gold), None

    m0 = jnp.full((b, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, s), jnp.float32)
    g0 = jnp.zeros((b, s), jnp.float32)
    (m, l, gold), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, g0), (w_chunks, jnp.arange(n_chunks))
    )
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    nll = lse - gold
    mask = labels != ignore_id
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
