"""Attention variants: GQA (with qk_norm / bias / M-RoPE options) and
DeepSeek-V2 MLA (multi-head latent attention), plus a memory-bounded
chunked ("flash-style") jnp attention used for long prefills.

The chunked jnp implementation is also the numerical oracle for the Pallas
flash kernel in ``repro/kernels`` — same online-softmax recurrence, pure
jnp.  The model forward uses the jnp paths (they are what the multi-pod
dry-run compiles); the Pallas kernel is the TPU-target drop-in validated
separately in interpret mode.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import common
from repro.models.common import Params, linear, rmsnorm

__all__ = [
    "KVCache",
    "init_attention",
    "attention_forward",
    "init_mla",
    "mla_forward",
    "naive_attention",
    "chunked_attention",
    "set_decode_flash_partitioning",
]

NEG_INF = -2.0**30

# §Perf knob: when True, decode attention is computed sequence-sharded
# ("flash-decoding"): q is replicated over the TP axis (it is tiny — one
# token), scores/softmax/PV stay local to each sequence shard of the KV
# cache, and only the per-token output + softmax stats are all-reduced.
# This removes the S→heads cache reshard (XLA's "involuntary full
# rematerialization") that otherwise streams the whole cache per step.
_DECODE_FLASH_PARTITION = False


def set_decode_flash_partitioning(on: bool) -> None:
    global _DECODE_FLASH_PARTITION
    _DECODE_FLASH_PARTITION = on


def _ambient_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None, None
        ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tp = "model" if "model" in mesh.axis_names else None
        return (ba or None), tp
    except Exception:  # pragma: no cover
        return None, None


def _constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # pragma: no cover — no ambient mesh
        return x


def _flash_decode_attention(
    q: jnp.ndarray,        # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S_max, Hkv, hd) — sequence-sharded over TP
    v_cache: jnp.ndarray,
    new_len: jnp.ndarray,
    *,
    scale: float,
) -> jnp.ndarray:
    """Sequence-sharded GQA decode ("flash-decoding" layout).

    The naive path repeats K/V to H query heads — a broadcast the SPMD
    partitioner can only realise by resharding (replicating!) the cache
    S-shards into a head-sharded layout, which streams the entire cache
    through HBM every step.  Here the grouped-query einsum consumes the
    cache in its stored (batch, SEQ-sharded) layout; only the softmax
    statistics and the (B,1,H,hd) output cross the TP axis.
    """
    b, s1, h, hd = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    ba, tp = _ambient_axes()
    qg = q.reshape(b, s1, hkv, g, hd)
    if ba or tp:
        qg = _constrain(qg, ba, None, None, None, None)
        k_cache = _constrain(k_cache, ba, tp, None, None)
        v_cache = _constrain(v_cache, ba, tp, None, None)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale                                             # (B, kv, g, 1, S)
    if ba or tp:
        scores = _constrain(scores, ba, None, None, None, tp)
    s_max = k_cache.shape[1]
    valid = jnp.arange(s_max) < new_len
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)              # stats all-reduce over tp
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache.astype(jnp.float32))
    out = out.reshape(b, s1, h, hd).astype(q.dtype)
    if ba or tp:
        out = _constrain(out, ba, None, None, None)
    return out


class KVCache(NamedTuple):
    """Per-layer decode cache.  k/v: (B, S_max, n_kv, hd); length: scalar."""

    k: jnp.ndarray
    v: jnp.ndarray
    length: jnp.ndarray  # int32 scalar — tokens already cached


# ----------------------------------------------------------------------
# Core attention math
# ----------------------------------------------------------------------


def _mask_bias(
    mask_kind: str,
    q_pos: jnp.ndarray,  # (Sq,) absolute positions of queries
    k_pos: jnp.ndarray,  # (Sk,)
    window: int | None = None,
) -> jnp.ndarray:
    """(Sq, Sk) additive bias in float32."""
    if mask_kind == "full":
        bias = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    elif mask_kind == "causal":
        bias = jnp.where(k_pos[None, :] <= q_pos[:, None], 0.0, NEG_INF)
    else:
        raise ValueError(mask_kind)
    if window is not None:
        bias = jnp.where(k_pos[None, :] > q_pos[:, None] - window, bias, NEG_INF)
    return bias


def naive_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,  # (B, Sk, Hkv, hd)
    *,
    mask_kind: str = "causal",
    q_pos: jnp.ndarray | None = None,
    k_pos: jnp.ndarray | None = None,
    kv_valid_len: jnp.ndarray | None = None,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference attention — materialises the (Sq, Sk) score matrix."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if k_pos is None:
        k_pos = jnp.arange(k.shape[1])
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    scores = scores + _mask_bias(mask_kind, q_pos, k_pos, window)[None, None]
    if kv_valid_len is not None:
        valid = jnp.arange(k.shape[1]) < kv_valid_len
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vr)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    mask_kind: str = "causal",
    chunk_q: int = 1024,
    chunk_k: int = 1024,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp.

    Peak live memory is O(chunk_q · chunk_k) scores + O(chunk_q · hd)
    accumulators instead of O(Sq · Sk) — the path long prefills compile
    through.  Numerics match :func:`naive_attention` to float32 rounding
    (property-tested).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    pad_q = (-sq) % chunk_q
    pad_k = (-sk) % chunk_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // chunk_q, kp.shape[1] // chunk_k

    # (nq, B, cq, H, hd) — scan over query chunks
    q_chunks = qp.reshape(b, nq, chunk_q, h, hd).transpose(1, 0, 2, 3, 4)
    k_chunks = kp.reshape(b, nk, chunk_k, hkv, hd).transpose(1, 0, 2, 3, 4)
    v_chunks = vp.reshape(b, nk, chunk_k, hkv, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = iq * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, kv_and_idx):
            acc, m, l = carry
            (ki, vi), ik = kv_and_idx
            k_pos = ik * chunk_k + jnp.arange(chunk_k)
            kr = jnp.repeat(ki, rep, axis=2)
            vr = jnp.repeat(vi, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kr).astype(jnp.float32) * scale
            s = s + _mask_bias(mask_kind, q_pos, k_pos, window)[None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vr.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, chunk_q, hd), jnp.float32)
        m0 = jnp.full((b, h, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), ((k_chunks, v_chunks), jnp.arange(nk))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)  # (B, H, cq, hd)

    _, outs = jax.lax.scan(q_step, None, (q_chunks, jnp.arange(nq)))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * chunk_q, h, hd)
    return out[:, :sq]


# ----------------------------------------------------------------------
# GQA attention layer
# ----------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 4)
    p: Params = {
        "wq": common.dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": common.dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": common.dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": common.dense_init(ks[3], cfg.n_heads * hd, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_init(hd)
        p["k_norm"] = common.rmsnorm_init(hd)
    return p


def _positions_for(cfg: ModelConfig, pos: jnp.ndarray) -> jnp.ndarray:
    """Expand (B, S) int positions to M-RoPE (B, S, 3) when needed."""
    if cfg.rope_variant == "mrope" and pos.ndim == 2:
        return jnp.broadcast_to(pos[..., None], (*pos.shape, 3))
    return pos


def attention_forward(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,                    # (B, S, d)
    *,
    positions: jnp.ndarray,            # (B, S) or (B, S, 3) for mrope
    cache: KVCache | None = None,
    mask_kind: str = "causal",
    window: int | None = None,
    kv_source: jnp.ndarray | None = None,   # cross-attention memory
    use_chunked: bool = False,
    ring: bool = False,                # sliding-window cache is a ring buffer
) -> tuple[jnp.ndarray, KVCache | None]:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    kv_in = x if kv_source is None else kv_source
    sk = kv_in.shape[1]
    k = linear(p["wk"], kv_in).reshape(b, sk, cfg.n_kv_heads, hd)
    v = linear(p["wv"], kv_in).reshape(b, sk, cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, eps=cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, eps=cfg.norm_eps)

    if cfg.rope_variant != "none" and kv_source is None:
        pos = _positions_for(cfg, positions)
        if cfg.rope_variant == "mrope":
            q = common.apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
            k = common.apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = common.apply_rope(q, pos, cfg.rope_theta)
            k = common.apply_rope(k, pos, cfg.rope_theta)

    new_cache = None
    if (
        cache is not None
        and kv_source is None
        and not ring
        and s == 1
        and window is None
        and _DECODE_FLASH_PARTITION
    ):
        # flash-decoding: consume the cache in its sequence-sharded layout
        k_cache = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0)
        )
        new_len = cache.length + s
        new_cache = KVCache(k_cache, v_cache, new_len)
        out = _flash_decode_attention(
            q, k_cache, v_cache, new_len, scale=1.0 / math.sqrt(hd)
        )
        out = out.reshape(b, s, cfg.n_heads * hd)
        return linear(p["wo"], out), new_cache
    if cache is not None and ring and kv_source is None:
        # --- sliding-window ring cache -------------------------------
        # slot of absolute position p is p % w.  The ring always holds
        # the last min(L, w) tokens after the write.
        w = cache.k.shape[1]
        q_pos = cache.length + jnp.arange(s)
        if s > w:  # only the last w tokens survive the write
            k_w, v_w, pos_w = k[:, -w:], v[:, -w:], q_pos[-w:]
        else:
            k_w, v_w, pos_w = k, v, q_pos
        slots = pos_w % w
        k_cache = cache.k.at[:, slots].set(k_w.astype(cache.k.dtype))
        v_cache = cache.v.at[:, slots].set(v_w.astype(cache.v.dtype))
        new_len = cache.length + s
        new_cache = KVCache(k_cache, v_cache, new_len)
        if s == 1:
            # decode: attend the ring.  Slot j holds absolute position
            # L−1−((L−1−j) mod w); unwritten slots map negative → masked
            # by pushing them past the query (causal kills them).
            j = jnp.arange(w)
            k_pos = new_len - 1 - ((new_len - 1 - j) % w)
            k_pos = jnp.where(k_pos >= 0, k_pos, jnp.int32(2**30))
            out = naive_attention(
                q, k_cache, v_cache, mask_kind="causal",
                q_pos=q_pos, k_pos=k_pos, window=w,
            )
        else:
            # prefill: exact windowed attention over the fresh k/v (the
            # ring is a decode artifact; early tokens must still see
            # their full in-window history, which a ring overwrites)
            attn = chunked_attention if use_chunked else naive_attention
            out = attn(q, k, v, mask_kind="causal", window=w)
        out = out.reshape(b, s, cfg.n_heads * hd)
        return linear(p["wo"], out), new_cache
    if cache is not None:
        if kv_source is None:
            # append this step's k/v at cache.length
            k_cache = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0)
            )
            new_len = cache.length + s
            new_cache = KVCache(k_cache, v_cache, new_len)
            q_pos = cache.length + jnp.arange(s)
            out = naive_attention(
                q, k_cache, v_cache,
                mask_kind="causal",
                q_pos=q_pos,
                k_pos=jnp.arange(k_cache.shape[1]),
                kv_valid_len=new_len,
                window=window,
            )
        else:
            # cross-attention with a fixed memory: cache holds projected k/v
            out = naive_attention(q, cache.k, cache.v, mask_kind="full")
            new_cache = cache
    else:
        attn = chunked_attention if use_chunked else naive_attention
        out = attn(q, k, v, mask_kind=mask_kind, window=window)

    out = out.reshape(b, s, cfg.n_heads * hd)
    return linear(p["wo"], out), new_cache


# ----------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed-KV latent attention
# ----------------------------------------------------------------------


class MLACache(NamedTuple):
    """Decode cache holds the *compressed* latents (the whole point of MLA).

    c_kv:   (B, S_max, kv_lora_rank)
    k_rope: (B, S_max, qk_rope_head_dim)
    length: int32 scalar
    """

    c_kv: jnp.ndarray
    k_rope: jnp.ndarray
    length: jnp.ndarray


def init_mla(rng, cfg: ModelConfig) -> Params:
    m = cfg.mla or MLAConfig()
    d = cfg.d_model
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 6)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": common.dense_init(ks[0], d, m.q_lora_rank, dtype=dt),
        "q_norm": common.rmsnorm_init(m.q_lora_rank),
        "w_uq": common.dense_init(ks[1], m.q_lora_rank, cfg.n_heads * qk_head, dtype=dt),
        "w_dkv": common.dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dt),
        "kv_norm": common.rmsnorm_init(m.kv_lora_rank),
        "w_uk": common.dense_init(ks[3], m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim, dtype=dt),
        "w_uv": common.dense_init(ks[4], m.kv_lora_rank, cfg.n_heads * m.v_head_dim, dtype=dt),
        "wo": common.dense_init(ks[5], cfg.n_heads * m.v_head_dim, d, dtype=dt),
    }


def _mla_compress(cfg: ModelConfig, p: Params, x: jnp.ndarray):
    """x → (c_kv normalised, k_rope rotated later)."""
    m = cfg.mla or MLAConfig()
    ckv_full = linear(p["w_dkv"], x)
    c_kv = rmsnorm(p["kv_norm"], ckv_full[..., : m.kv_lora_rank], eps=cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank :]
    return c_kv, k_rope


def _mla_queries(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions):
    m = cfg.mla or MLAConfig()
    b, s, _ = x.shape
    q = linear(p["w_uq"], rmsnorm(p["q_norm"], linear(p["w_dq"], x), eps=cfg.norm_eps))
    q = q.reshape(b, s, cfg.n_heads, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = common.apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    cache: MLACache | None = None,
    use_chunked: bool = False,
) -> tuple[jnp.ndarray, MLACache | None]:
    """MLA attention.

    Prefill/train: decompress K/V (standard formulation).  Decode: the
    *absorbed* formulation — queries are mapped into latent space and
    attention runs directly against the compressed cache, so per-step cost
    scales with kv_lora_rank (512) instead of n_heads·head_dim (16384):
    the 32× KV-bandwidth saving that makes MLA decode-friendly.
    """
    m = cfg.mla or MLAConfig()
    b, s, _ = x.shape
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = _mla_queries(cfg, p, x, positions)
    c_kv, k_rope_raw = _mla_compress(cfg, p, x)

    if cache is None:
        # --- decompressed path (train / prefill-without-cache) ----------
        k_pos = jnp.arange(s)
        k_rope = common.apply_rope(k_rope_raw[:, :, None, :], k_pos[None, :], cfg.rope_theta)
        k_nope = linear(p["w_uk"], c_kv).reshape(b, s, cfg.n_heads, m.qk_nope_head_dim)
        val = linear(p["w_uv"], c_kv).reshape(b, s, cfg.n_heads, m.v_head_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, cfg.n_heads, m.qk_rope_head_dim))],
            axis=-1,
        )
        attn = chunked_attention if use_chunked else naive_attention
        out = attn(q, k, val, mask_kind="causal", scale=scale)
        out = out.reshape(b, s, cfg.n_heads * m.v_head_dim)
        return linear(p["wo"], out), None

    # --- absorbed decode path -------------------------------------------
    pos = cache.length + jnp.arange(s)
    k_rope = common.apply_rope(k_rope_raw[:, :, None, :], pos[None, :], cfg.rope_theta)[:, :, 0]
    c_cache = jax.lax.dynamic_update_slice(
        cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache.length, 0)
    )
    r_cache = jax.lax.dynamic_update_slice(
        cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, cache.length, 0)
    )
    new_len = cache.length + s
    new_cache = MLACache(c_cache, r_cache, new_len)

    # absorb W_UK into q: q_lat (B,S,H,kv_lora) = q_nope @ W_UK(head)ᵀ
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    s_max = c_cache.shape[1]
    scores = (
        jnp.einsum("bshr,bkr->bhsk", q_lat, c_cache)
        + jnp.einsum("bshd,bkd->bhsk", q_rope, r_cache)
    ).astype(jnp.float32) * scale
    k_positions = jnp.arange(s_max)
    causal = k_positions[None, None, None, :] <= (cache.length + jnp.arange(s))[None, None, :, None]
    valid = k_positions[None, None, None, :] < new_len
    scores = jnp.where(causal & valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    # attend in latent space, then decompress once per query token
    lat = jnp.einsum("bhsk,bkr->bshr", probs, c_cache)
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", lat, w_uv)
    out = out.reshape(b, s, cfg.n_heads * m.v_head_dim)
    return linear(p["wo"], out), new_cache
