"""State-space & recurrent sequence mixers: Mamba2 (SSD) and xLSTM blocks.

Mamba2 uses the chunked SSD formulation: quadratic attention-like compute
*within* fixed-size chunks (MXU-friendly batched matmuls) plus a sequential
inter-chunk state recurrence — O(S·Q) instead of O(S²).  A step-by-step
recurrence (`mamba2_step`) serves decode and doubles as the numerical
oracle in tests (chunked ≡ sequential, property-tested).

xLSTM: mLSTM (matrix memory, exponentially gated, fully parallelizable
à la linear attention — implemented here as a stabilized sequential scan
with a chunked variant in ``repro/kernels``) and sLSTM (scalar memory with
recurrent block-diagonal weights — inherently sequential).  Both carry
O(d²) state per layer, which is what makes the 500k-token decode cell
feasible where full attention is not.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import Params, linear

__all__ = [
    "MambaState",
    "init_mamba2",
    "mamba2_forward",
    "mamba2_step",
    "XLSTMState",
    "init_mlstm",
    "mlstm_forward",
    "mlstm_step",
    "init_slstm",
    "slstm_forward",
    "slstm_step",
]


# ======================================================================
# Mamba2
# ======================================================================


class MambaState(NamedTuple):
    """Decode state: SSM state h (B, H, P, N) + conv ring buffer."""

    h: jnp.ndarray          # (B, H, P, N) float32
    conv: jnp.ndarray       # (B, conv_w - 1, d_conv_in)


def _mamba_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.mamba_headdim
    return d_inner, n_heads, cfg.ssm_state


def init_mamba2(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, n_heads, n_state = _mamba_dims(cfg)
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 4)
    d_in_proj = 2 * d_inner + 2 * n_state + n_heads   # z, x, B, C, dt
    d_conv_in = d_inner + 2 * n_state                 # conv over [x, B, C]
    return {
        "in_proj": common.dense_init(ks[0], d, d_in_proj, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_conv_in), jnp.float32)
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dt),
        "conv_b": jnp.zeros((d_conv_in,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": common.rmsnorm_init(d_inner),
        "out_proj": common.dense_init(ks[3], d_inner, d, dtype=dt),
    }


def _mamba_project(cfg: ModelConfig, p: Params, x: jnp.ndarray):
    """Shared input path: projections + causal conv + gate computation."""
    d_inner, n_heads, n_state = _mamba_dims(cfg)
    zxbcdt = linear(p["in_proj"], x)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * n_state], axis=-1
    )
    return z, xbc, dt_raw


def _causal_conv(
    p: Params,
    xbc: jnp.ndarray,
    conv_state: jnp.ndarray | None,
    valid_len: int | None = None,
):
    """Depthwise causal conv over time.  xbc: (B, S, C).

    ``valid_len`` (static) marks the number of real tokens when the caller
    right-padded the sequence; the returned conv state then holds the last
    K−1 *real* inputs so decode continues seamlessly after a padded prefill.
    """
    w = p["conv_w"]  # (K, C)
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)            # (B, S+K-1, C)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(k))
    out = jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))
    if k > 1:
        if valid_len is not None and valid_len != xbc.shape[1]:
            new_state = jax.lax.dynamic_slice_in_dim(xp, valid_len, k - 1, axis=1)
        else:
            new_state = xp[:, -(k - 1):]
    else:
        new_state = pad
    return out, new_state


def _split_xbc(cfg: ModelConfig, xbc: jnp.ndarray):
    d_inner, n_heads, n_state = _mamba_dims(cfg)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + n_state], axis=-1)
    return xs, b, c


def mamba2_forward(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,                       # (B, S, d)
    state: MambaState | None = None,
) -> tuple[jnp.ndarray, MambaState]:
    """Chunked SSD over a full sequence.  Returns output + final state.

    Sequences that don't divide the chunk are right-padded internally;
    padded steps get dt = 0 (no decay, no input contribution), so the
    final state is exactly the state after the real tokens.
    """
    bsz, s_in, _ = x.shape
    d_inner, n_heads, n_state = _mamba_dims(cfg)
    hd = cfg.mamba_headdim
    q = min(cfg.ssm_chunk, s_in)
    pad = (-s_in) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s_in + pad
    n_chunks = s // q

    z, xbc, dt_raw = _mamba_project(cfg, p, x)
    conv_in_state = state.conv if state is not None else None
    xbc, conv_state = _causal_conv(p, xbc, conv_in_state, valid_len=s_in)
    xs, b, c = _split_xbc(cfg, xbc)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    if pad:
        dt = dt * (jnp.arange(s) < s_in)[None, :, None]
    a = -jnp.exp(p["a_log"])                                          # (H,)
    log_decay = dt * a                                                # (B,S,H)

    xh = xs.reshape(bsz, n_chunks, q, n_heads, hd).astype(jnp.float32)
    bh = b.reshape(bsz, n_chunks, q, n_state).astype(jnp.float32)
    ch = c.reshape(bsz, n_chunks, q, n_state).astype(jnp.float32)
    dth = dt.reshape(bsz, n_chunks, q, n_heads)
    ld = log_decay.reshape(bsz, n_chunks, q, n_heads)
    cum = jnp.cumsum(ld, axis=2)                                      # (B,NC,Q,H)

    # ---- intra-chunk quadratic term ----------------------------------
    # scores[t, s] = exp(cum_t − cum_s) · (C_t · B_s) · dt_s   for s ≤ t
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]             # (B,NC,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: exp of masked (upper-triangle) entries can overflow
    # and a post-hoc where() still back-propagates NaN through the inf branch
    gate = jnp.exp(jnp.where(causal[None, None, :, :, None], decay, -1e30))
    scores = jnp.einsum("bntk,bnsk->bnts", ch, bh)                    # (B,NC,Q,Q)
    w = scores[..., None] * gate * dth[:, :, None, :, :]              # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", w, xh)                 # (B,NC,Q,H,P)

    # ---- inter-chunk recurrence ---------------------------------------
    # per-chunk input-to-state: S_n = Σ_s exp(cum_end − cum_s)·dt_s·B_s⊗x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                           # (B,NC,Q,H)
    contrib = tail * dth                                              # (B,NC,Q,H)
    chunk_states = jnp.einsum("bnsh,bnsk,bnshp->bnhpk", contrib, bh, xh)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # (B,NC,H)

    h0 = (state.h if state is not None
          else jnp.zeros((bsz, n_heads, hd, n_state), jnp.float32))

    def chunk_step(h, inputs):
        s_n, g_n = inputs  # (B,H,P,N), (B,H)
        h_out = h  # state *entering* the chunk
        h_new = h * g_n[..., None, None] + s_n
        return h_new, h_out

    (h_final, h_enter) = jax.lax.scan(
        chunk_step,
        h0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    # y_inter[t] = exp(cum_t) · C_t · h_enter(chunk)
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)                        # (B,NC,H,P,N)
    y_inter = jnp.einsum(
        "bnth,bntk,bnhpk->bnthp", jnp.exp(cum), ch, h_enter
    )

    y = (y_intra + y_inter).reshape(bsz, s, n_heads, hd)
    y = y + p["d_skip"][None, None, :, None] * xs.reshape(bsz, s, n_heads, hd).astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    y = y[:, :s_in] if pad else y
    return linear(p["out_proj"], y), MambaState(h=h_final, conv=conv_state)


def mamba2_step(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, state: MambaState
) -> tuple[jnp.ndarray, MambaState]:
    """Single-token recurrence (decode path / test oracle).  x: (B, 1, d)."""
    bsz = x.shape[0]
    d_inner, n_heads, n_state = _mamba_dims(cfg)
    hd = cfg.mamba_headdim

    z, xbc, dt_raw = _mamba_project(cfg, p, x)
    xbc, conv_state = _causal_conv(p, xbc, state.conv)
    xs, b, c = _split_xbc(cfg, xbc)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]   # (B,H)
    a = -jnp.exp(p["a_log"])
    g = jnp.exp(dt * a)                                                     # (B,H)
    xh = xs[:, 0].reshape(bsz, n_heads, hd).astype(jnp.float32)
    bv = b[:, 0].astype(jnp.float32)                                        # (B,N)
    cv = c[:, 0].astype(jnp.float32)

    h = state.h * g[..., None, None] + jnp.einsum(
        "bh,bk,bhp->bhpk", dt, bv, xh
    )
    y = jnp.einsum("bk,bhpk->bhp", cv, h) + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    return linear(p["out_proj"], y), MambaState(h=h, conv=conv_state)


# ======================================================================
# xLSTM — mLSTM (matrix memory)
# ======================================================================


class XLSTMState(NamedTuple):
    c: jnp.ndarray  # mLSTM: (B, H, P, P) matrix memory | sLSTM: (B, H, P) cell
    n: jnp.ndarray  # normalizer: (B, H, P) | (B, H, P)
    m: jnp.ndarray  # stabilizer: (B, H)   | (B, H, P)
    h: jnp.ndarray  # sLSTM hidden (B, H, P); unused (zeros) for mLSTM


def _xlstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    return cfg.n_heads, cfg.d_model // cfg.n_heads


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    """mLSTM operates in the up-projected space: (n_heads, up, hd_up)."""
    up = int(cfg.xlstm_proj_factor * cfg.d_model)
    return cfg.n_heads, up, up // cfg.n_heads


def init_mlstm(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    n_heads, up, hd = _mlstm_dims(cfg)
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 8)
    return {
        "w_up": common.dense_init(ks[0], d, up, dtype=dt),       # cell stream
        "w_gatez": common.dense_init(ks[1], d, up, dtype=dt),    # output gating
        "wq": common.dense_init(ks[2], up, up, dtype=dt),
        "wk": common.dense_init(ks[3], up, up, dtype=dt),
        "wv": common.dense_init(ks[4], up, up, dtype=dt),
        "w_if": common.dense_init(ks[5], up, 2 * n_heads, dtype=jnp.float32),
        "norm": common.rmsnorm_init(up),
        "w_down": common.dense_init(ks[6], up, d, dtype=dt),
    }


def mlstm_init_state(cfg: ModelConfig, bsz: int) -> XLSTMState:
    n_heads, up, hd = _mlstm_dims(cfg)
    return XLSTMState(
        c=jnp.zeros((bsz, n_heads, hd, hd), jnp.float32),
        n=jnp.zeros((bsz, n_heads, hd), jnp.float32),
        m=jnp.full((bsz, n_heads), -1e30, jnp.float32),
        h=jnp.zeros((bsz, n_heads, hd), jnp.float32),
    )


def _mlstm_inner_step(q, k, v, i_raw, f_raw, state: XLSTMState):
    """One stabilized mLSTM update.  q/k/v: (B, H, P) f32; gates (B, H)."""
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + state.m - m_new)
    c = state.c * f_g[..., None, None] + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )
    n = state.n * f_g[..., None] + i_g[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]
    return h, XLSTMState(c=c, n=n, m=m_new, h=state.h)


def _mlstm_qkv(cfg, p, x):
    """x: (B, S, d) → q/k/v in the up-projected head space + gate pre-acts."""
    bsz, s, d = x.shape
    n_heads, up, hd = _mlstm_dims(cfg)
    scale = 1.0 / math.sqrt(hd)
    u = linear(p["w_up"], x)                                          # (B,S,up)
    q = linear(p["wq"], u).reshape(bsz, s, n_heads, hd).astype(jnp.float32) * scale
    k = linear(p["wk"], u).reshape(bsz, s, n_heads, hd).astype(jnp.float32)
    v = linear(p["wv"], u).reshape(bsz, s, n_heads, hd).astype(jnp.float32)
    gates = linear(p["w_if"], u.astype(jnp.float32))                  # (B,S,2H)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    z = jax.nn.silu(linear(p["w_gatez"], x))                          # (B,S,up)
    return q, k, v, i_raw, f_raw, z


def mlstm_forward(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, state: XLSTMState | None = None
) -> tuple[jnp.ndarray, XLSTMState]:
    """Sequential (scan-over-time) mLSTM over a sequence.  x: (B, S, d)."""
    bsz, s, d = x.shape
    n_heads, up, hd = _mlstm_dims(cfg)
    q, k, v, i_raw, f_raw, z = _mlstm_qkv(cfg, p, x)
    st = state if state is not None else mlstm_init_state(cfg, bsz)

    def step(st, inputs):
        qt, kt, vt, it, ft = inputs
        h, st2 = _mlstm_inner_step(qt, kt, vt, it, ft, st)
        return st2, h

    seq = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_raw.transpose(1, 0, 2),
        f_raw.transpose(1, 0, 2),
    )
    st_final, hs = jax.lax.scan(step, st, seq)
    h = hs.transpose(1, 0, 2, 3).reshape(bsz, s, up).astype(x.dtype)
    h = common.rmsnorm(p["norm"], h, eps=cfg.norm_eps)
    return linear(p["w_down"], h * z), st_final


def mlstm_step(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, state: XLSTMState
) -> tuple[jnp.ndarray, XLSTMState]:
    """Single-token mLSTM decode step.  x: (B, 1, d)."""
    bsz, _, d = x.shape
    n_heads, up, hd = _mlstm_dims(cfg)
    q, k, v, i_raw, f_raw, z = _mlstm_qkv(cfg, p, x)
    h, st = _mlstm_inner_step(q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0], f_raw[:, 0], state)
    h = h.reshape(bsz, 1, up).astype(x.dtype)
    h = common.rmsnorm(p["norm"], h, eps=cfg.norm_eps)
    return linear(p["w_down"], h * z), st


# ======================================================================
# xLSTM — sLSTM (scalar memory, recurrent)
# ======================================================================


def init_slstm(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    n_heads, hd = _xlstm_dims(cfg)
    up = int(cfg.xlstm_proj_factor * d)
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 4)
    # 4 gates (i, f, z, o), each with input weights and block-diagonal
    # per-head recurrent weights (the xLSTM "memory mixing").
    return {
        "w_in": common.dense_init(ks[0], d, 4 * d, dtype=dt),
        "r": (jax.random.normal(ks[1], (4, n_heads, hd, hd), jnp.float32)
              / math.sqrt(hd)).astype(jnp.float32),
        "b": jnp.zeros((4, n_heads, hd), jnp.float32),
        "norm": common.rmsnorm_init(d),
        "w_up": common.dense_init(ks[2], d, up, dtype=dt),
        "w_down": common.dense_init(ks[3], up, d, dtype=dt),
    }


def slstm_init_state(cfg: ModelConfig, bsz: int) -> XLSTMState:
    n_heads, hd = _xlstm_dims(cfg)
    z = jnp.zeros((bsz, n_heads, hd), jnp.float32)
    return XLSTMState(c=z, n=z, m=jnp.full((bsz, n_heads, hd), -1e30), h=z)


def _slstm_inner_step(cfg, p, xt, state: XLSTMState):
    """xt: (B, 4, H, P) pre-projected gate inputs."""
    rec = jnp.einsum("ghvp,bhp->bghv", p["r"], state.h)  # (B,4,H,P)
    pre = xt.astype(jnp.float32) + rec + p["b"][None]
    i_raw, f_raw, z_raw, o_raw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + state.m - m_new)
    c = f_g * state.c + i_g * jnp.tanh(z_raw)
    n = f_g * state.n + i_g
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    return h, XLSTMState(c=c, n=n, m=m_new, h=h)


def slstm_forward(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, state: XLSTMState | None = None
) -> tuple[jnp.ndarray, XLSTMState]:
    bsz, s, d = x.shape
    n_heads, hd = _xlstm_dims(cfg)
    st = state if state is not None else slstm_init_state(cfg, bsz)
    gates_in = linear(p["w_in"], x).reshape(bsz, s, 4, n_heads, hd)

    def step(st, xt):
        h, st2 = _slstm_inner_step(cfg, p, xt, st)
        return st2, h

    st_final, hs = jax.lax.scan(step, st, gates_in.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(bsz, s, d).astype(x.dtype)
    h = common.rmsnorm(p["norm"], h, eps=cfg.norm_eps)
    return linear(p["w_down"], jax.nn.gelu(linear(p["w_up"], h))), st_final


def slstm_step(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, state: XLSTMState
) -> tuple[jnp.ndarray, XLSTMState]:
    bsz, _, d = x.shape
    n_heads, hd = _xlstm_dims(cfg)
    xt = linear(p["w_in"], x).reshape(bsz, 4, n_heads, hd)
    h, st = _slstm_inner_step(cfg, p, xt, state)
    h = h.reshape(bsz, 1, d).astype(x.dtype)
    h = common.rmsnorm(p["norm"], h, eps=cfg.norm_eps)
    return linear(p["w_down"], jax.nn.gelu(linear(p["w_up"], h))), st
