"""Feed-forward layers: dense SwiGLU and Mixture-of-Experts.

The MoE uses capacity-bounded *sort-based dispatch* (megablocks-style
rather than GShard one-hot einsums): tokens are sorted by expert id,
scattered into an (E, C, d) buffer, processed with a batched expert
matmul (MXU-friendly ``(E, C, d) × (E, d, f)``), and scattered back with
their gate weights.  This avoids the O(T·E·C) one-hot dispatch tensor —
at deepseek-v2 scale (T=65k tokens/shard, E=160, C≈3k) the one-hot tensor
alone would be ~3·10¹³ elements; sort dispatch keeps memory at
O(T·k + E·C·d).

Under pjit, sharding experts over the ``model`` mesh axis makes XLA insert
the token all-to-alls at the (T, d)→(E, C, d) and back reshardings —
expert parallelism falls out of the sharding annotations, matching how the
dry-run measures its collective bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common
from repro.models.common import Params, linear

__all__ = [
    "init_swiglu",
    "swiglu_forward",
    "init_moe",
    "moe_forward",
]


def init_swiglu(rng, d_model: int, d_ff: int, *, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": common.dense_init(k1, d_model, d_ff, dtype=dtype),
        "w_up": common.dense_init(k2, d_model, d_ff, dtype=dtype),
        "w_down": common.dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu_forward(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["w_down"], jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x))


# ----------------------------------------------------------------------
# Mixture of Experts
# ----------------------------------------------------------------------


def init_moe(rng, cfg: ModelConfig) -> Params:
    m = cfg.moe
    assert m is not None
    d = cfg.d_model
    dt = common.dtype_of(cfg.dtype)
    k_router, k_experts, k_shared = jax.random.split(rng, 3)

    def stacked(rng, n, d_in, d_out):
        keys = jax.random.split(rng, n)
        return jnp.stack([common.dense_init(k, d_in, d_out, dtype=dt)["w"] for k in keys])

    ke = jax.random.split(k_experts, 3)
    p: Params = {
        "router": common.dense_init(k_router, d, m.num_experts, dtype=jnp.float32),
        "w_gate": stacked(ke[0], m.num_experts, d, m.d_ff_expert),
        "w_up": stacked(ke[1], m.num_experts, d, m.d_ff_expert),
        "w_down": stacked(ke[2], m.num_experts, m.d_ff_expert, d),
    }
    if m.num_shared_experts:
        p["shared"] = init_swiglu(
            k_shared, d, m.num_shared_experts * m.d_ff_shared, dtype=dt
        )
    return p


def _capacity(m: MoEConfig, n_tokens: int) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    # round up to an MXU-aligned multiple where it matters
    return max(8, -(-cap // 8) * 8)


def moe_forward(
    cfg: ModelConfig, p: Params, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).  x: (B, S, d) → flattened internally."""
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    # --- routing (float32 for a stable softmax) -------------------------
    logits = linear(p["router"], xf.astype(jnp.float32))           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                        # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], m.num_experts)
    ce = one_hot_top1.mean(axis=0)
    aux = m.num_experts * jnp.sum(me * ce) * m.aux_loss_weight

    # --- sort-based dispatch --------------------------------------------
    cap = _capacity(m, t)
    flat_expert = expert_ids.reshape(-1)                           # (T·k,)
    flat_token = jnp.repeat(jnp.arange(t), m.top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # position of each routed token within its expert's block: the array is
    # sorted by expert, so position = global index − segment start.
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(m.num_experts), side="left")
    pos_in_expert = jnp.arange(t * m.top_k) - seg_start[sorted_expert]
    keep = pos_in_expert < cap                                     # capacity drop
    slot = sorted_expert * cap + jnp.where(keep, pos_in_expert, 0)

    # scatter token features into (E·C, d); dropped tokens write nowhere
    buf = jnp.zeros((m.num_experts * cap, d), x.dtype)
    src = jnp.where(keep[:, None], xf[sorted_token], 0.0)
    buf = buf.at[jnp.where(keep, slot, m.num_experts * cap - 1)].add(
        jnp.where(keep[:, None], src, 0.0)
    )
    buf = buf.reshape(m.num_experts, cap, d)

    # --- expert computation: batched matmuls (E, C, d) × (E, d, f) ------
    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(-1, d)

    # --- combine: gather back, weight by gates, scatter-add to tokens ---
    gathered = jnp.where(keep[:, None], out_buf[slot], 0.0)
    combined = jnp.zeros((t, d), x.dtype)
    combined = combined.at[sorted_token].add(
        gathered * sorted_gate[:, None].astype(x.dtype)
    )

    if "shared" in p:
        combined = combined + swiglu_forward(p["shared"], xf)
    return combined.reshape(b, s, d), aux
