"""Model assembly for every assigned architecture family.

One :class:`Model` facade per config exposes:

* ``init(rng)``            — parameter pytree (works under ``jax.eval_shape``)
* ``train_loss(params, batch)``  — mean token NLL (+ MoE aux losses)
* ``init_cache(batch, max_len)`` — decode-cache pytree
* ``prefill(params, batch, cache)`` — run the prompt, fill the cache
* ``decode_step(params, tokens, cache)`` — one token with the cache

Depth is always consumed with ``jax.lax.scan`` over stacked layer
parameters, so compiled HLO size — and 512-device dry-run compile time —
is independent of layer count.  Heterogeneous stacks (zamba2's shared
attention cadence, xlstm's sLSTM cadence) scan over *groups* whose body
contains the repeating pattern.

Frontends for ``[vlm]``/``[audio]`` archs are stubs per the assignment:
precomputed patch/frame embeddings arrive in the batch and are spliced
into the token embedding stream.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import common, ffn, ssm
from repro.models.common import Params, linear, rmsnorm

__all__ = ["Model", "build_model"]

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable

# Layer-scan unroll factor.  1 = rolled while-loop (small HLO — the normal
# mode).  True/int = unrolled bodies; the dry-run's depth-probe measurements
# use full unroll so XLA cost_analysis (which counts a while body ONCE)
# sees every layer.  Set via ``set_layer_scan_unroll`` or Model.scan_unroll.
_LAYER_SCAN_UNROLL: int | bool = 1


def set_layer_scan_unroll(unroll: int | bool) -> None:
    global _LAYER_SCAN_UNROLL
    _LAYER_SCAN_UNROLL = unroll


def _layer_scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=_LAYER_SCAN_UNROLL)


# ======================================================================
# Shared helpers
# ======================================================================


def _sinusoidal_positions(seq_len: int, d: int, offset=0) -> jnp.ndarray:
    pos = jnp.arange(seq_len)[:, None] + offset
    div = jnp.exp(jnp.arange(0, d, 2) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq_len, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _embed_tokens(cfg: ModelConfig, params: Params, batch: dict) -> jnp.ndarray:
    dt = common.dtype_of(cfg.dtype)
    x = params["embed"]["embedding"][batch["tokens"]].astype(dt)
    if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
        x = jax.lax.dynamic_update_slice(
            x, batch["patch_embeds"].astype(dt), (0, 0, 0)
        )
    return x


def _default_positions(cfg: ModelConfig, b: int, s: int, batch: dict) -> jnp.ndarray:
    if cfg.rope_variant == "mrope":
        if "positions" in batch:
            return batch["positions"]
        pos = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3))
        return pos
    return jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))


def _lm_logits(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(params["lm_head"], rmsnorm(params["final_norm"], x, eps=cfg.norm_eps))


def _stack_init(rng, n: int, init_fn: Callable[[Any], Params]) -> Params:
    """Initialise n layers and stack leaves along a leading axis."""
    keys = jax.random.split(rng, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


# ======================================================================
# Dense / MoE / VLM decoder-only family
# ======================================================================


def _init_decoder_block(rng, cfg: ModelConfig, *, moe_layer: bool) -> Params:
    dt = common.dtype_of(cfg.dtype)
    k1, k2 = jax.random.split(rng)
    p: Params = {"ln1": common.rmsnorm_init(cfg.d_model), "ln2": common.rmsnorm_init(cfg.d_model)}
    if cfg.attn_kind == "mla":
        p["attn"] = attn_lib.init_mla(k1, cfg)
    else:
        p["attn"] = attn_lib.init_attention(k1, cfg)
    if moe_layer:
        p["moe"] = ffn.init_moe(k2, cfg)
    else:
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.first_dense_layers) else cfg.d_ff
        p["ffn"] = ffn.init_swiglu(k2, cfg.d_model, d_ff, dtype=dt)
    return p


def _decoder_block(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    cache: dict | None,
    use_chunked: bool,
) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    """Returns (x, new_cache_slice, aux_loss)."""
    h = rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
    if cfg.attn_kind == "mla":
        mcache = (
            attn_lib.MLACache(cache["c_kv"], cache["k_rope"], cache["length"])
            if cache is not None
            else None
        )
        a, new_mcache = attn_lib.mla_forward(
            cfg, p["attn"], h, positions=positions, cache=mcache, use_chunked=use_chunked
        )
        new_cache = (
            {"c_kv": new_mcache.c_kv, "k_rope": new_mcache.k_rope}
            if new_mcache is not None
            else None
        )
    else:
        kcache = (
            attn_lib.KVCache(cache["k"], cache["v"], cache["length"])
            if cache is not None
            else None
        )
        a, new_kcache = attn_lib.attention_forward(
            cfg, p["attn"], h, positions=positions, cache=kcache, use_chunked=use_chunked
        )
        new_cache = (
            {"k": new_kcache.k, "v": new_kcache.v} if new_kcache is not None else None
        )
    x = x + a
    h = rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
    if "moe" in p:
        f, aux = ffn.moe_forward(cfg, p["moe"], h)
    else:
        f, aux = ffn.swiglu_forward(p["ffn"], h), jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


def _init_decoder_lm(rng, cfg: ModelConfig) -> Params:
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 4)
    n_dense0 = cfg.moe.first_dense_layers if cfg.moe else 0
    params: Params = {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt),
        "blocks": _stack_init(
            ks[1],
            cfg.n_layers - n_dense0,
            lambda k: _init_decoder_block(k, cfg, moe_layer=cfg.moe is not None),
        ),
        "final_norm": common.rmsnorm_init(cfg.d_model),
        "lm_head": common.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype=dt),
    }
    if n_dense0:
        params["dense0"] = _stack_init(
            ks[3], n_dense0, lambda k: _init_decoder_block(k, cfg, moe_layer=False)
        )
    return params


def _run_decoder_stack(
    cfg: ModelConfig,
    params: Params,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    cache: dict | None,
    use_chunked: bool,
    remat: bool,
) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    """Scan x through (dense0 blocks +) the main stacked blocks."""

    def make_body(which: str):
        def body(carry, layer_in):
            x, aux = carry
            p, c = layer_in
            x, new_c, a = _decoder_block(
                cfg, p, x, positions=positions, cache=c, use_chunked=use_chunked
            )
            return (x, aux + a), new_c

        return jax.checkpoint(body, policy=REMAT_POLICY) if remat else body

    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    length = cache["length"] if cache is not None else None

    def slice_cache(prefix: str):
        if cache is None:
            return None
        sub = {k[len(prefix):]: v for k, v in cache.items() if k.startswith(prefix)}
        return sub or None

    if "dense0" in params:
        c0 = slice_cache("dense0/")
        c0 = None if c0 is None else {**c0, "length": length}
        xs = (params["dense0"], {k: v for k, v in (c0 or {}).items() if k != "length"} or None)

        def body0(carry, layer_in):
            x, aux = carry
            p, c = layer_in
            if c is not None:
                c = {**c, "length": length}
            x, new_c, a = _decoder_block(
                cfg, p, x, positions=positions, cache=c, use_chunked=use_chunked
            )
            if new_c is not None:
                new_c.pop("length", None)
            return (x, aux + a), new_c

        body0 = jax.checkpoint(body0, policy=REMAT_POLICY) if remat else body0
        (x, aux), nc0 = _layer_scan(body0, (x, aux), xs)
        if nc0 is not None and cache is not None:
            new_cache.update({f"dense0/{k}": v for k, v in nc0.items()})

    main_c = slice_cache("main/")

    def body_main(carry, layer_in):
        x, aux = carry
        p, c = layer_in
        if c is not None:
            c = {**c, "length": length}
        x, new_c, a = _decoder_block(
            cfg, p, x, positions=positions, cache=c, use_chunked=use_chunked
        )
        if new_c is not None:
            new_c.pop("length", None)
        return (x, aux + a), new_c

    body_main = jax.checkpoint(body_main, policy=REMAT_POLICY) if remat else body_main
    (x, aux), nc = _layer_scan(body_main, (x, aux), (params["blocks"], main_c))
    if nc is not None and cache is not None:
        new_cache.update({f"main/{k}": v for k, v in nc.items()})
        new_cache["length"] = length + (1 if positions.shape[1] == 1 else positions.shape[1])
    return x, (new_cache if cache is not None else None), aux


# ======================================================================
# Encoder-decoder family (seamless backbone)
# ======================================================================


def _init_encoder_block(rng, cfg: ModelConfig) -> Params:
    dt = common.dtype_of(cfg.dtype)
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": common.rmsnorm_init(cfg.d_model),
        "attn": attn_lib.init_attention(k1, cfg),
        "ln2": common.rmsnorm_init(cfg.d_model),
        "ffn": ffn.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype=dt),
    }


def _init_cross_block(rng, cfg: ModelConfig) -> Params:
    dt = common.dtype_of(cfg.dtype)
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": common.rmsnorm_init(cfg.d_model),
        "self_attn": attn_lib.init_attention(k1, cfg),
        "ln_x": common.rmsnorm_init(cfg.d_model),
        "cross_attn": attn_lib.init_attention(k2, cfg),
        "ln2": common.rmsnorm_init(cfg.d_model),
        "ffn": ffn.init_swiglu(k3, cfg.d_model, cfg.d_ff, dtype=dt),
    }


def _init_encdec(rng, cfg: ModelConfig) -> Params:
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 5)
    return {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt),
        "enc_blocks": _stack_init(ks[1], cfg.encoder_layers, lambda k: _init_encoder_block(k, cfg)),
        "enc_norm": common.rmsnorm_init(cfg.d_model),
        "dec_blocks": _stack_init(ks[2], cfg.n_layers, lambda k: _init_cross_block(k, cfg)),
        "final_norm": common.rmsnorm_init(cfg.d_model),
        "lm_head": common.dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype=dt),
    }


def _run_encoder(cfg: ModelConfig, params: Params, src: jnp.ndarray, *, remat: bool):
    b, s, d = src.shape
    x = src + _sinusoidal_positions(s, d).astype(src.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, p):
        h = rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
        a, _ = attn_lib.attention_forward(cfg, p["attn"], h, positions=pos, mask_kind="full")
        x = x + a
        h = rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
        return x + ffn.swiglu_forward(p["ffn"], h), None

    body = jax.checkpoint(body, policy=REMAT_POLICY) if remat else body
    x, _ = _layer_scan(body, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, eps=cfg.norm_eps)


def _run_decoder_encdec(
    cfg: ModelConfig,
    params: Params,
    x: jnp.ndarray,
    memory: jnp.ndarray | None,
    cache: dict | None,
    *,
    remat: bool,
):
    b, s, d = x.shape
    length = cache["length"] if cache is not None else jnp.zeros((), jnp.int32)
    x = x + _sinusoidal_positions(s, d, offset=length).astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, layer_in):
        p, c = layer_in
        h = rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
        if c is not None:
            self_c = attn_lib.KVCache(c["self_k"], c["self_v"], length)
        else:
            self_c = None
        a, new_self = attn_lib.attention_forward(
            cfg, p["self_attn"], h, positions=pos, cache=self_c
        )
        x = x + a
        h = rmsnorm(p["ln_x"], x, eps=cfg.norm_eps)
        if c is not None:
            cross_c = attn_lib.KVCache(c["cross_k"], c["cross_v"], jnp.zeros((), jnp.int32))
            a, _ = attn_lib.attention_forward(
                cfg, p["cross_attn"], h, positions=pos, cache=cross_c, kv_source=h
            )
        else:
            assert memory is not None
            a, _ = attn_lib.attention_forward(
                cfg, p["cross_attn"], h, positions=pos, kv_source=memory, mask_kind="full"
            )
        x = x + a
        h = rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
        x = x + ffn.swiglu_forward(p["ffn"], h)
        out_c = None
        if c is not None:
            out_c = {"self_k": new_self.k, "self_v": new_self.v,
                     "cross_k": c["cross_k"], "cross_v": c["cross_v"]}
        return x, out_c

    body = jax.checkpoint(body, policy=REMAT_POLICY) if remat else body
    cache_xs = None
    if cache is not None:
        cache_xs = {k: v for k, v in cache.items() if k != "length"}
    x, new_c = _layer_scan(body, x, (params["dec_blocks"], cache_xs))
    if cache is not None:
        new_c["length"] = length + s
    return x, (new_c if cache is not None else None)


# ======================================================================
# Hybrid (zamba2) — mamba backbone + weight-shared attention block
# ======================================================================


def _init_zamba(rng, cfg: ModelConfig) -> Params:
    dt = common.dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 6)
    every = cfg.shared_attn_every
    groups = cfg.n_layers // every
    return {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt),
        "mamba": _stack_init(
            ks[1], groups, lambda k: _stack_init(k, every, lambda k2: ssm.init_mamba2(k2, cfg))
        ),
        # one set of shared attention-block weights + per-invocation LN
        "shared_attn": attn_lib.init_attention(ks[2], cfg),
        "shared_ffn": ffn.init_swiglu(ks[3], cfg.d_model, cfg.d_ff, dtype=dt),
        "shared_ln": {"scale": jnp.ones((groups, cfg.d_model), jnp.float32)},
        "shared_ln2": {"scale": jnp.ones((groups, cfg.d_model), jnp.float32)},
        "final_norm": common.rmsnorm_init(cfg.d_model),
        "lm_head": common.dense_init(ks[4], cfg.d_model, cfg.vocab_size, dtype=dt),
    }


ZAMBA_WINDOW = 4096  # shared-attn sliding window: keeps long_500k sub-quadratic


def _run_zamba(
    cfg: ModelConfig,
    params: Params,
    x: jnp.ndarray,
    cache: dict | None,
    *,
    decode: bool,
    remat: bool,
):
    b, s, _ = x.shape
    length = cache["length"] if cache is not None else jnp.zeros((), jnp.int32)
    window = min(ZAMBA_WINDOW, 1 << 62)

    def group_body(carry, layer_in):
        x = carry
        p_group, ln_scale, ln2_scale, c = layer_in

        # --- `every` mamba layers (inner scan over stacked params) ------
        def mamba_body(x, inner):
            p_m, st = inner
            if decode:
                y, new_st = ssm.mamba2_step(cfg, p_m, x, ssm.MambaState(**st))
            else:
                y, new_st = ssm.mamba2_forward(
                    cfg, p_m, x, ssm.MambaState(**st) if st is not None else None
                )
            return x + y, new_st._asdict() if new_st is not None else None

        inner_states = c["mamba"] if c is not None else None
        if inner_states is None:
            d_inner = cfg.ssm_expand * cfg.d_model
            n_heads = d_inner // cfg.mamba_headdim
            every = cfg.n_layers // params["shared_ln"]["scale"].shape[0]
            inner_states = {
                "h": jnp.zeros((every, b, n_heads, cfg.mamba_headdim, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((every, b, cfg.ssm_conv - 1,
                                   d_inner + 2 * cfg.ssm_state), common.dtype_of(cfg.dtype)),
            }
        x, new_mamba = _layer_scan(mamba_body, x, (p_group, inner_states))

        # --- shared attention + FFN block -------------------------------
        h = rmsnorm({"scale": ln_scale}, x, eps=cfg.norm_eps)
        if c is not None:
            kv = attn_lib.KVCache(c["attn_k"], c["attn_v"], length)
            a, new_kv = attn_lib.attention_forward(
                cfg, params["shared_attn"], h,
                positions=(length + jnp.arange(s))[None, :].repeat(b, 0),
                cache=kv, window=window, ring=True, use_chunked=s > 4096,
            )
            new_attn = {"attn_k": new_kv.k, "attn_v": new_kv.v}
        else:
            a, _ = attn_lib.attention_forward(
                cfg, params["shared_attn"], h,
                positions=jnp.broadcast_to(jnp.arange(s)[None, :], (b, s)),
                window=window, use_chunked=s > 4096,
            )
            new_attn = None
        x = x + a
        h = rmsnorm({"scale": ln2_scale}, x, eps=cfg.norm_eps)
        x = x + ffn.swiglu_forward(params["shared_ffn"], h)

        new_c = None
        if c is not None:
            new_c = {"mamba": new_mamba, **(new_attn or {})}
        return x, new_c

    group_body = jax.checkpoint(group_body, policy=REMAT_POLICY) if remat else group_body
    cache_xs = None
    if cache is not None:
        cache_xs = {k: v for k, v in cache.items() if k != "length"}
        cache_xs = {"mamba": cache_xs["mamba"], "attn_k": cache_xs["attn_k"],
                    "attn_v": cache_xs["attn_v"]}
    x, new_cache = _layer_scan(
        group_body,
        x,
        (params["mamba"], params["shared_ln"]["scale"], params["shared_ln2"]["scale"], cache_xs),
    )
    if cache is not None:
        new_cache["length"] = length + s
    return x, (new_cache if cache is not None else None)


# ======================================================================
# SSM (xlstm) — groups of (slstm_every − 1) mLSTM + 1 sLSTM
# ======================================================================


def _init_xlstm(rng, cfg: ModelConfig) -> Params:
    dt = common.dtype_of(cfg.dtype)
    every = cfg.slstm_every
    groups = cfg.n_layers // every
    ks = jax.random.split(rng, 5)
    return {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt),
        "mlstm": _stack_init(
            ks[1], groups, lambda k: _stack_init(k, every - 1, lambda k2: ssm.init_mlstm(k2, cfg))
        ),
        "slstm": _stack_init(ks[2], groups, lambda k: ssm.init_slstm(k, cfg)),
        "ln_m": {"scale": jnp.ones((groups, every - 1, cfg.d_model), jnp.float32)},
        "ln_s": {"scale": jnp.ones((groups, cfg.d_model), jnp.float32)},
        "final_norm": common.rmsnorm_init(cfg.d_model),
        "lm_head": common.dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype=dt),
    }


def _run_xlstm(
    cfg: ModelConfig,
    params: Params,
    x: jnp.ndarray,
    cache: dict | None,
    *,
    decode: bool,
    remat: bool,
):
    b, s, _ = x.shape
    every = cfg.slstm_every
    groups = cfg.n_layers // every

    def group_body(x, layer_in):
        p_m, p_s, ln_m, ln_s, c = layer_in

        def mlstm_body(x, inner):
            p, ln, st = inner
            h = rmsnorm({"scale": ln}, x, eps=cfg.norm_eps)
            state = ssm.XLSTMState(**st) if st is not None else None
            if decode:
                y, new_st = ssm.mlstm_step(cfg, p, h, state)
            else:
                y, new_st = ssm.mlstm_forward(cfg, p, h, state)
            return x + y, new_st._asdict()

        m_states = c["mlstm"] if c is not None else None
        if m_states is None:
            st0 = ssm.mlstm_init_state(cfg, b)
            m_states = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (every - 1, *a.shape)), st0._asdict()
            )
        x, new_m = _layer_scan(mlstm_body, x, (p_m, ln_m, m_states))

        h = rmsnorm({"scale": ln_s}, x, eps=cfg.norm_eps)
        s_state = ssm.XLSTMState(**c["slstm"]) if c is not None else None
        if decode:
            y, new_s = ssm.slstm_step(cfg, p_s, h, s_state)
        else:
            y, new_s = ssm.slstm_forward(cfg, p_s, h, s_state)
        x = x + y
        new_c = None
        if c is not None:
            new_c = {"mlstm": new_m, "slstm": new_s._asdict()}
        return x, new_c

    group_body = jax.checkpoint(group_body, policy=REMAT_POLICY) if remat else group_body
    cache_xs = None
    if cache is not None:
        cache_xs = {"mlstm": cache["mlstm"], "slstm": cache["slstm"]}
    x, new_cache = _layer_scan(
        group_body,
        x,
        (params["mlstm"], params["slstm"], params["ln_m"]["scale"],
         params["ln_s"]["scale"], cache_xs),
    )
    if cache is not None:
        new_cache["length"] = cache["length"] + s
    return x, (new_cache if cache is not None else None)


# ======================================================================
# Model facade
# ======================================================================


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    # perf knobs (threaded by launch/specs for §Perf hillclimbing)
    remat: bool = True                # activation checkpointing in train_loss
    vocab_chunk: int = 0              # >0: chunked CE, never materialises (B,S,V)

    # ------------------------------------------------------------------
    def init(self, rng) -> Params:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return _init_decoder_lm(rng, cfg)
        if cfg.family == "encdec":
            return _init_encdec(rng, cfg)
        if cfg.family == "hybrid":
            return _init_zamba(rng, cfg)
        if cfg.family == "ssm":
            return _init_xlstm(rng, cfg)
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------
    def train_loss(self, params: Params, batch: dict) -> tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        remat = self.remat
        if cfg.family in ("dense", "moe", "vlm"):
            x = _embed_tokens(cfg, params, batch)
            b, s = batch["tokens"].shape
            pos = _default_positions(cfg, b, s, batch)
            x, _, aux = _run_decoder_stack(
                cfg, params, x, positions=pos, cache=None,
                use_chunked=s > 4096, remat=remat,
            )
        elif cfg.family == "encdec":
            memory = _run_encoder(cfg, params, batch["frame_embeds"], remat=remat)
            x = params["embed"]["embedding"][batch["tokens"]].astype(memory.dtype)
            x, _ = _run_decoder_encdec(cfg, params, x, memory, None, remat=remat)
            aux = jnp.zeros((), jnp.float32)
        elif cfg.family == "hybrid":
            x = _embed_tokens(cfg, params, batch)
            x, _ = _run_zamba(cfg, params, x, None, decode=False, remat=remat)
            aux = jnp.zeros((), jnp.float32)
        elif cfg.family == "ssm":
            x = _embed_tokens(cfg, params, batch)
            x, _ = _run_xlstm(cfg, params, x, None, decode=False, remat=remat)
            aux = jnp.zeros((), jnp.float32)
        else:
            raise ValueError(cfg.family)
        if self.vocab_chunk:
            h = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
            nll = common.softmax_cross_entropy_chunked(
                h, params["lm_head"], batch["labels"], chunk=self.vocab_chunk
            )
        else:
            logits = _lm_logits(cfg, params, x)
            nll = common.softmax_cross_entropy(logits, batch["labels"])
        return nll + aux, {"nll": nll, "aux": aux}

    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = common.dtype_of(cfg.dtype)
        hd = cfg.resolved_head_dim
        length = jnp.zeros((), jnp.int32)
        if cfg.family in ("dense", "moe", "vlm"):
            n_dense0 = cfg.moe.first_dense_layers if cfg.moe else 0
            n_main = cfg.n_layers - n_dense0
            cache: dict = {"length": length}

            def kv(n_layers):
                if cfg.attn_kind == "mla":
                    m = cfg.mla
                    return {
                        "c_kv": jnp.zeros((n_layers, batch_size, max_len, m.kv_lora_rank), dt),
                        "k_rope": jnp.zeros((n_layers, batch_size, max_len, m.qk_rope_head_dim), dt),
                    }
                return {
                    "k": jnp.zeros((n_layers, batch_size, max_len, cfg.n_kv_heads, hd), dt),
                    "v": jnp.zeros((n_layers, batch_size, max_len, cfg.n_kv_heads, hd), dt),
                }

            cache.update({f"main/{k}": v for k, v in kv(n_main).items()})
            if n_dense0:
                cache.update({f"dense0/{k}": v for k, v in kv(n_dense0).items()})
            return cache
        if cfg.family == "encdec":
            L = cfg.n_layers
            return {
                "self_k": jnp.zeros((L, batch_size, max_len, cfg.n_kv_heads, hd), dt),
                "self_v": jnp.zeros((L, batch_size, max_len, cfg.n_kv_heads, hd), dt),
                "cross_k": jnp.zeros((L, batch_size, 1, cfg.n_kv_heads, hd), dt),  # resized at prefill
                "cross_v": jnp.zeros((L, batch_size, 1, cfg.n_kv_heads, hd), dt),
                "length": length,
            }
        if cfg.family == "hybrid":
            every = cfg.shared_attn_every
            groups = cfg.n_layers // every
            d_inner = cfg.ssm_expand * cfg.d_model
            n_heads_m = d_inner // cfg.mamba_headdim
            w = min(ZAMBA_WINDOW, max_len)
            return {
                "mamba": {
                    "h": jnp.zeros((groups, every, batch_size, n_heads_m,
                                    cfg.mamba_headdim, cfg.ssm_state), jnp.float32),
                    "conv": jnp.zeros((groups, every, batch_size, cfg.ssm_conv - 1,
                                       d_inner + 2 * cfg.ssm_state), dt),
                },
                "attn_k": jnp.zeros((groups, batch_size, w, cfg.n_kv_heads, hd), dt),
                "attn_v": jnp.zeros((groups, batch_size, w, cfg.n_kv_heads, hd), dt),
                "length": length,
            }
        if cfg.family == "ssm":
            every = cfg.slstm_every
            groups = cfg.n_layers // every
            m0 = ssm.mlstm_init_state(cfg, batch_size)._asdict()
            s0 = ssm.slstm_init_state(cfg, batch_size)._asdict()
            return {
                "mlstm": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (groups, every - 1, *a.shape)).copy(), m0
                ),
                "slstm": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (groups, *a.shape)).copy(), s0
                ),
                "length": length,
            }
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------
    def prefill(self, params: Params, batch: dict, cache: dict) -> tuple[jnp.ndarray, dict]:
        """Run the prompt through the model, filling the decode cache.

        Returns last-position logits (B, V) and the updated cache.
        """
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            x = _embed_tokens(cfg, params, batch)
            b, s = batch["tokens"].shape
            pos = _default_positions(cfg, b, s, batch)
            x, cache, _ = _run_decoder_stack(
                cfg, params, x, positions=pos, cache=cache,
                use_chunked=s > 4096, remat=False,
            )
        elif cfg.family == "encdec":
            memory = _run_encoder(cfg, params, batch["frame_embeds"], remat=False)
            # project cross-attention K/V once; they are fixed for decoding
            def proj(p):
                b, sk, _ = memory.shape
                k = linear(p["cross_attn"]["wk"], memory).reshape(b, sk, cfg.n_kv_heads, -1)
                v = linear(p["cross_attn"]["wv"], memory).reshape(b, sk, cfg.n_kv_heads, -1)
                return k, v

            ks, vs = jax.vmap(proj, in_axes=(0,))(params["dec_blocks"])
            cache = {**cache, "cross_k": ks, "cross_v": vs}
            x = params["embed"]["embedding"][batch["tokens"]].astype(memory.dtype)
            x, cache = _run_decoder_encdec(cfg, params, x, None, cache, remat=False)
        elif cfg.family == "hybrid":
            x = _embed_tokens(cfg, params, batch)
            x, cache = _run_zamba(cfg, params, x, cache, decode=False, remat=False)
        elif cfg.family == "ssm":
            x = _embed_tokens(cfg, params, batch)
            x, cache = _run_xlstm(cfg, params, x, cache, decode=False, remat=False)
        else:
            raise ValueError(cfg.family)
        logits = _lm_logits(cfg, params, x[:, -1:])[:, 0]
        return logits, cache

    # ------------------------------------------------------------------
    def decode_step(self, params: Params, tokens: jnp.ndarray, cache: dict,
                    extras: dict | None = None) -> tuple[jnp.ndarray, dict]:
        """One decode step.  tokens: (B, 1) int32.  Returns (logits, cache)."""
        cfg = self.cfg
        batch = {"tokens": tokens, **(extras or {})}
        x = params["embed"]["embedding"][tokens].astype(common.dtype_of(cfg.dtype))
        b = tokens.shape[0]
        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.rope_variant == "mrope":
                pos = batch.get(
                    "positions",
                    jnp.broadcast_to(cache["length"][None, None, None], (b, 1, 3)).astype(jnp.int32),
                )
            else:
                pos = jnp.broadcast_to(cache["length"][None, None], (b, 1)).astype(jnp.int32)
            x, cache, _ = _run_decoder_stack(
                cfg, params, x, positions=pos, cache=cache, use_chunked=False, remat=False
            )
        elif cfg.family == "encdec":
            x, cache = _run_decoder_encdec(cfg, params, x, None, cache, remat=False)
        elif cfg.family == "hybrid":
            x, cache = _run_zamba(cfg, params, x, cache, decode=True, remat=False)
        elif cfg.family == "ssm":
            x, cache = _run_xlstm(cfg, params, x, cache, decode=True, remat=False)
        else:
            raise ValueError(cfg.family)
        logits = _lm_logits(cfg, params, x)[:, 0]
        return logits, cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
