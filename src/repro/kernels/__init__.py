"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper
in ``ops.py``:

* ``flash_attention`` — online-softmax attention (causal/full/window, GQA)
* ``mamba_chunk_scan`` — Mamba2 SSD chunked selective scan
* ``mcop_phase``       — the paper's MinCutPhase inner loop (host phase loop)
* ``mcop_stoer_wagner_kernel`` — full batched MCOP: all phases + merges in
  one kernel invocation, grid over graphs (see ``core.mcop.mcop_batch``)

``default_interpret`` picks interpret-vs-compiled once per process from the
JAX backend; all kernel wrappers accept ``interpret=None`` to mean "auto".
"""

from repro.kernels.ops import (
    default_interpret,
    flash_attention,
    mamba_chunk_scan,
    mcop_min_cut,
    on_tpu,
)
from repro.kernels.mcop_phase import mcop_stoer_wagner_kernel
from repro.kernels import ref

__all__ = [
    "flash_attention",
    "mamba_chunk_scan",
    "mcop_min_cut",
    "mcop_stoer_wagner_kernel",
    "default_interpret",
    "on_tpu",
    "ref",
]
