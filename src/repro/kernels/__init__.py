"""Pallas TPU kernels for the framework's compute hot spots.

Three kernels, each with a pure-jnp oracle in ``ref.py`` and a jit'd
public wrapper in ``ops.py``:

* ``flash_attention`` — online-softmax attention (causal/full/window, GQA)
* ``mamba_chunk_scan`` — Mamba2 SSD chunked selective scan
* ``mcop_phase``       — the paper's MinCutPhase inner loop (MCOP on-device)
"""

from repro.kernels.ops import flash_attention, mamba_chunk_scan, mcop_min_cut, on_tpu
from repro.kernels import ref

__all__ = ["flash_attention", "mamba_chunk_scan", "mcop_min_cut", "on_tpu", "ref"]
