"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately naive — materialise-everything implementations whose
numerics define correctness.  tests/test_kernels.py sweeps shapes & dtypes
asserting the Pallas kernels (interpret=True) match these to tolerance.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "flash_reference",
    "mamba_chunk_scan_reference",
    "mcop_phase_reference",
]

NEG_INF = -2.0**30


def flash_reference(
    q: jnp.ndarray,   # (B, H, Sq, hd)
    k: jnp.ndarray,   # (B, Hkv, Sk, hd)
    v: jnp.ndarray,   # (B, Hkv, Sk, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Naive attention with the full (Sq, Sk) score matrix."""
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kr = jnp.repeat(k, rep, axis=1)
    vr = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba_chunk_scan_reference(
    x: jnp.ndarray,    # (B, H, NC, Q, P)
    dt: jnp.ndarray,   # (B, H, NC, Q)
    ld: jnp.ndarray,   # (B, H, NC, Q)
    bm: jnp.ndarray,   # (B, NC, Q, N)
    cm: jnp.ndarray,   # (B, NC, Q, N)
    h0: jnp.ndarray,   # (B, H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token SSM recurrence — the slowest, most obviously-correct
    form:  h_t = exp(ld_t)·h_{t−1} + dt_t·(x_t ⊗ B_t);  y_t = C_t·h_tᵀ."""
    b, h, nc, q, p = x.shape
    n = bm.shape[-1]

    xf = x.reshape(b, h, nc * q, p).astype(jnp.float32)
    dtf = dt.reshape(b, h, nc * q).astype(jnp.float32)
    ldf = ld.reshape(b, h, nc * q).astype(jnp.float32)
    bf = bm.reshape(b, nc * q, n).astype(jnp.float32)
    cf = cm.reshape(b, nc * q, n).astype(jnp.float32)

    def step(hst, inputs):
        xt, dtt, ldt, bt, ct = inputs
        # hst: (B, H, P, N)
        hst = hst * jnp.exp(ldt)[..., None, None] + (
            dtt[..., None, None] * xt[..., :, None] * bt[:, None, None, :]
        )
        yt = jnp.einsum("bn,bhpn->bhp", ct, hst)
        return hst, yt

    hT, ys = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (
            xf.transpose(2, 0, 1, 3),     # (T, B, H, P)
            dtf.transpose(2, 0, 1),
            ldf.transpose(2, 0, 1),
            bf.transpose(1, 0, 2),        # (T, B, N)
            cf.transpose(1, 0, 2),
        ),
    )
    y = ys.transpose(1, 2, 0, 3).reshape(b, h, nc, q, p)
    return y, hT


def mcop_phase_reference(
    adj: jnp.ndarray,     # (n, n)
    gains: jnp.ndarray,   # (n,)
    alive: jnp.ndarray,   # (n,) bool
    src: int,
    c_local_total: float,
) -> tuple[float, int, int]:
    """Numpy-free transcription of Algorithm 3 (used as kernel oracle)."""
    adj = jnp.asarray(adj, jnp.float32)
    gains = jnp.asarray(gains, jnp.float32)
    alive = jnp.asarray(alive, bool)
    n = adj.shape[0]
    n_alive = int(alive.sum())

    in_a = jnp.zeros(n, bool).at[src].set(True) & alive
    conn = adj[src]
    s_reg = t_reg = int(src)
    for i in range(n_alive - 1):
        cand = alive & ~in_a
        scores = jnp.where(cand, conn - gains, NEG_INF)
        v = int(jnp.argmax(scores))
        in_a = in_a.at[v].set(True)
        conn = conn + adj[v]
        s_reg, t_reg = t_reg, v
    comm = float((adj[t_reg] * alive).sum())
    cut = float(c_local_total) - float(gains[t_reg]) + comm
    return cut, s_reg, t_reg
