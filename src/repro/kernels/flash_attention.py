"""Pallas TPU flash attention (causal / full / sliding-window, GQA).

Target: TPU v5e.  The kernel follows the canonical TPU flash pattern:

* grid = (batch, q_heads, num_q_blocks, num_k_blocks) with the K dimension
  innermost and *sequential* ("arbitrary"), so the online-softmax
  accumulators can live in VMEM scratch across K iterations;
* BlockSpecs tile Q/K/V into (block_q × head_dim) / (block_k × head_dim)
  VMEM windows — the working set per grid step is
  block_q·hd + 2·block_k·hd + block_q·block_k floats, sized well under the
  ~16 MB/core VMEM budget for the default 512/512 blocks with hd ≤ 256;
* the MXU sees two matmuls per step (Q·Kᵀ and P·V) with dims that are
  multiples of 128 when hd ∈ {64, 128, 256} and block sizes are 128-aligned;
* GQA is expressed in the BlockSpec index map (KV head = Q head // group),
  so no repeated K/V materialisation in HBM.

Numerics are float32 in the accumulators regardless of input dtype,
matching ``ref.flash_reference`` (the pure-jnp oracle) to float32 rounding.

On this CPU-only container the kernel is validated with
``interpret=True``, which executes the same body in Python.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory-space handles; absent on some CPU-only builds
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = pl.MemorySpace.ANY  # type: ignore[attr-defined]

__all__ = ["flash_attention_kernel"]

NEG_INF = -2.0**30


def _flash_body(
    q_ref,      # (1, 1, block_q, hd)
    k_ref,      # (1, 1, block_k, hd)
    v_ref,      # (1, 1, block_k, hd)
    o_ref,      # (1, 1, block_q, hd)
    acc_ref,    # VMEM scratch (block_q, hd) f32
    m_ref,      # VMEM scratch (block_q, 1) f32
    l_ref,      # VMEM scratch (block_q, 1) f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: int | None,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                              # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k                                   # K padding
    mask &= q_pos < seq_q                                  # Q padding (harmless rows)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                    # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                 # (bq, bk)
    # fully-masked rows: exp(NEG_INF − NEG_INF) = 1 — zero them explicitly
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                         # (bq, 1)

    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l)[None, None].astype(o_ref.dtype)


def flash_attention_kernel(
    q: jnp.ndarray,   # (B, H, Sq, hd)
    k: jnp.ndarray,   # (B, Hkv, Sk, hd)
    v: jnp.ndarray,   # (B, Hkv, Sk, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """pallas_call wrapper.  Head-major layout; returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    body = functools.partial(
        _flash_body,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        seq_q=sq,
        seq_k=sk,
        causal=causal,
        window=window,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        body,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, qi, ki: (b_, h_ // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, qi, ki: (b_, h_ // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            _VMEM((block_q, hd), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
