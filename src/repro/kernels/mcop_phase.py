"""Pallas TPU kernels for MCOP (paper Algorithms 1–3) — phase and full solver.

Two kernels, one memory story:

* :func:`mcop_phase_kernel` — ONE MinCutPhase (Algorithm 3) per invocation.
  The host keeps the Algorithm-2 loop and the Algorithm-1 merges in numpy
  (see ``repro.kernels.ops.mcop_min_cut``), so the adjacency crosses
  HBM→VMEM once *per phase*: |V|−1 transfers per solve.

* :func:`mcop_stoer_wagner_kernel` — the FULL modified Stoer–Wagner in a
  single kernel invocation, batched over graphs.  All |V|−1 phases, the
  Algorithm-1 merges of (s, t), and the initial fold of unoffloadable
  vertices into the anchor run inside the kernel body, so the adjacency is
  loaded into VMEM exactly once per solve.  A grid dimension over the
  batch lets one ``pallas_call`` partition B independent graphs — the
  throughput shape for the paper's §3.1 *real-time online* requirement
  when millions of users (or an environment sweep) need placements per
  scheduler tick.

Dense adjacency is the TPU-native layout (the paper's graphs are small —
tens to a few thousand vertices — so a whole (n, n) matrix fits VMEM:
n = 1024 f32 is 4 MB against the ~16 MB/core budget; the wrappers enforce
the bound).  The phase hot loop is the Most-Tightly-Connected-Vertex scan:

    repeat |V|−1 times:
        Δ(v)  = conn(v) − [w_local(v) − w_cloud(v)]   over v ∉ A
        v*    = argmax Δ                               (VPU masked max)
        conn += adj[v*]                                (VPU row add)

The full kernel avoids dynamic row gathers and transposes entirely: rows
are extracted with one-hot masked reductions, and row↔column vector moves
use the identity-mask gadget ``Σ_j eye[i,j]·v[j]`` — both plain VPU work.

``interpret`` defaults to auto-detection (compiled on TPU, interpreter
elsewhere) via ``repro.kernels.ops.default_interpret``; pass an explicit
bool to override.

Padded/dead vertices are encoded ``alive = 0`` (phase kernel) or
``pinned = 1`` with zero weights (full kernel) and never selected (their
score is −∞); scalars travel as (1, 1) or (1, n) 2-D arrays to keep the
kernels TPU-lowering-friendly (2-D everywhere, no 0-D iota).

Backend selection cheat-sheet (see also ``repro.core.mcop``):

* one graph, need the per-phase trace        → ``mcop_reference`` (numpy)
* one graph inside a jitted loop             → ``mcop_jax``
* many graphs / env sweep, XLA               → ``core.mcop.mcop_batch``
* many graphs, adjacency resident in VMEM    → this file's full kernel
  (``mcop_batch(..., backend="pallas")``) — wins on TPU where the
  dominant cost is HBM row traffic, which single-load residency removes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = pl.MemorySpace.ANY  # type: ignore[attr-defined]

__all__ = [
    "mcop_phase_kernel",
    "mcop_stoer_wagner_kernel",
    "mcop_fused_solve_kernel",
    "default_block_graphs",
    "FUSED_MODEL_KINDS",
]

# f32-representable sentinels matching the solver backends in core.mcop —
# graphs priced in FLOPs/bytes can have cuts far above 2**30, so a small
# sentinel would silently swallow every phase cut.
NEG_INF = -1e30
POS_INF = 1e30

# VMEM bound: adjacency + vectors must fit on-core alongside double-buffers.
_VMEM_BYTES = 12 * 2**20


def _resolve_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    # Deferred import: ops.py imports this module at load time.
    from repro.kernels.ops import default_interpret

    return default_interpret()


# ======================================================================
# Single-phase kernel (Algorithm 3) — host drives the phase loop.
# ======================================================================


def _phase_body(
    adj_ref,      # (n, n) f32
    gains_ref,    # (1, n) f32   w_local − w_cloud
    alive_ref,    # (1, n) f32   1.0 = vertex alive in the current graph
    src_ref,      # (1, 1) i32   anchor vertex a
    ctot_ref,     # (1, 1) f32   C_local = Σ w_local (original graph)
    cut_ref,      # (1, 1) f32   out: cut-of-the-phase
    s_ref,        # (1, 1) i32   out
    t_ref,        # (1, 1) i32   out
    *,
    n: int,
):
    adj = adj_ref[...]
    gains = gains_ref[0, :]
    alive = alive_ref[0, :] > 0.5
    src = src_ref[0, 0]

    n_alive = jnp.sum(alive.astype(jnp.int32))
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]

    in_a0 = alive & (idx == src)
    conn0 = adj[src, :]

    def absorb(i, carry):
        in_a, conn, s_reg, t_reg = carry
        cand = alive & ~in_a
        scores = jnp.where(cand, conn - gains, NEG_INF)
        v = jnp.argmax(scores).astype(jnp.int32)
        do = (i + 1) < n_alive          # absorb exactly n_alive−1 vertices
        in_a = jnp.where(do, in_a | (idx == v), in_a)
        conn = jnp.where(do, conn + adj[v, :], conn)
        s_reg = jnp.where(do, t_reg, s_reg)
        t_reg = jnp.where(do, v, t_reg)
        return in_a, conn, s_reg, t_reg

    _, _, s_reg, t_reg = jax.lax.fori_loop(
        0, n - 1, absorb, (in_a0, conn0, src, src)
    )

    # Eq. 10: C_cut(A−t, t) = C_local − gains[t] + Σ_{v alive} w(e(t, v))
    comm = jnp.sum(adj[t_reg, :] * alive.astype(jnp.float32))
    cut_ref[0, 0] = ctot_ref[0, 0] - gains[t_reg] + comm
    s_ref[0, 0] = s_reg
    t_ref[0, 0] = t_reg


def mcop_phase_kernel(
    adj: jnp.ndarray,     # (n, n) f32 — current (possibly merged) graph
    gains: jnp.ndarray,   # (n,) f32
    alive: jnp.ndarray,   # (n,) bool/f32
    src: int | jnp.ndarray,
    c_local_total: float | jnp.ndarray,
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run one MinCutPhase.  Returns (cut_value, s, t).

    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere.
    """
    n = adj.shape[0]
    assert n * n * 4 <= _VMEM_BYTES, f"graph too large for single-core VMEM: n={n}"
    body = functools.partial(_phase_body, n=n)
    cut, s, t = pl.pallas_call(
        body,
        grid=(),
        in_specs=[
            pl.BlockSpec(adj.shape, lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=_resolve_interpret(interpret),
    )(
        adj.astype(jnp.float32),
        jnp.asarray(gains, jnp.float32)[None, :],
        jnp.asarray(alive, jnp.float32)[None, :],
        jnp.asarray(src, jnp.int32).reshape(1, 1),
        jnp.asarray(c_local_total, jnp.float32).reshape(1, 1),
    )
    return cut[0, 0], s[0, 0], t[0, 0]


# ======================================================================
# Full solver kernel — all phases + merges, one VMEM load, batch grid.
# ======================================================================


def _solve_graph(adj, wl, wc, pin, *, n: int):
    """One graph's full modified Stoer–Wagner, as pure kernel-body math.

    Args are VALUES already resident in VMEM (not refs): ``adj`` (n, n)
    f32, ``wl``/``wc`` (1, n) f32, ``pin`` (1, n) bool.  Returns
    ``(best_cut (1, 1) f32, local_mask (1, n) f32)``.  Factoring the
    solve out of the pallas body lets one program invocation solve a
    whole *block* of graphs (grid tuning) and lets the fused variant
    build the WCG weights in VMEM immediately before calling this.
    """
    f32 = jnp.float32

    row_i = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col_i = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    col1 = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    eye = (row_i == col_i).astype(f32)

    def as_col(v):
        # (1, n) → (n, 1) without transpose/reshape: diagonal-mask reduce.
        return jnp.sum(eye * v, axis=1, keepdims=True)

    def as_row(c):
        # (n, 1) → (1, n), same gadget along the other axis.
        return jnp.sum(eye * c, axis=0, keepdims=True)

    def row_of(mat, v_idx):
        return jnp.sum(
            mat * (row_i == v_idx).astype(f32), axis=0, keepdims=True
        )  # (1, n)

    ctot = jnp.sum(wl)  # C_local — invariant under merging

    # ---- fold all pinned vertices into the anchor (Algorithm 2 step 1) --
    any_p = jnp.any(pin)
    src0 = jnp.where(
        any_p, jnp.argmax(pin.astype(f32), axis=1)[0], 0
    ).astype(jnp.int32)
    others = pin & (col1 != src0)                               # (1, n)
    oth_f = others.astype(f32)
    # Σ of folded rows, as a column (symmetry: row-fold == col-fold).
    fold_col = jnp.sum(adj * oth_f, axis=1, keepdims=True)      # (n, 1)
    fold_row = as_row(fold_col)                                 # (1, n)
    keep_row = 1.0 - oth_f
    keep_col = as_col(keep_row)
    adj = adj * keep_row * keep_col
    s_rows = row_i == src0
    s_cols = col_i == src0
    adj = adj + s_rows.astype(f32) * (fold_row * keep_row)
    adj = adj + s_cols.astype(f32) * (fold_col * keep_col)
    adj = jnp.where(s_rows & s_cols, 0.0, adj)

    srcm = (col1 == src0).astype(f32)                           # (1, n)
    pin_f = pin.astype(f32)
    pin_src = jnp.sum(pin_f * srcm)
    wl_src = jnp.sum(wl * pin_f) + jnp.sum(wl * srcm) * (1.0 - pin_src)
    wc_src = jnp.sum(wc * pin_f) + jnp.sum(wc * srcm) * (1.0 - pin_src)
    wl = jnp.where(others, 0.0, wl)
    wl = jnp.where(srcm > 0.5, wl_src, wl)
    wc = jnp.where(others, 0.0, wc)
    wc = jnp.where(srcm > 0.5, wc_src, wc)
    alive = ~others                                             # (1, n)
    members = jnp.maximum(eye, s_rows.astype(f32) * pin_f)      # (n, n)

    # ---- Algorithm 2: |V|−1 phases, each followed by an Alg.-1 merge ----
    def phase(_, carry):
        adj, wl, wc, alive, members, src, best_cut, best_cloud = carry
        gains = wl - wc
        n_alive = jnp.sum(alive.astype(jnp.int32))
        valid = n_alive >= 2

        in_a0 = alive & (col1 == src)
        conn0 = row_of(adj, src)

        def absorb(i, inner):
            in_a, conn, s_reg, t_reg = inner
            cand = alive & ~in_a
            scores = jnp.where(cand, conn - gains, NEG_INF)
            v = jnp.argmax(scores, axis=1)[0].astype(jnp.int32)
            do = (i + 1) < n_alive
            in_a = jnp.where(do, in_a | (col1 == v), in_a)
            conn = jnp.where(do, conn + row_of(adj, v), conn)
            s_reg = jnp.where(do, t_reg, s_reg)
            t_reg = jnp.where(do, v, t_reg)
            return in_a, conn, s_reg, t_reg

        _, _, s_reg, t_reg = jax.lax.fori_loop(
            0, n - 1, absorb, (in_a0, conn0, src, src)
        )

        # Eq. 10 cut-of-the-phase.
        tm_f = (col1 == t_reg).astype(f32)
        t_row = row_of(adj, t_reg)                              # (1, n)
        comm = jnp.sum(t_row * alive.astype(f32))
        gains_t = jnp.sum(gains * tm_f)
        cut = jnp.where(valid, ctot - gains_t + comm, POS_INF)

        t_rows = row_i == t_reg
        cloud_t = jnp.sum(members * t_rows.astype(f32), axis=0, keepdims=True)
        improved = valid & (cut < best_cut)
        best_cut = jnp.where(improved, cut, best_cut)
        best_cloud = jnp.where(improved, cloud_t, best_cloud)

        # Algorithm 1: merge t into s (masked, symmetric).
        do_merge = valid & (s_reg != t_reg)
        s_rows_m = row_i == s_reg
        s_cols_m = col_i == s_reg
        t_cols = col_i == t_reg
        adj_m = adj + s_rows_m.astype(f32) * t_row
        adj_m = adj_m + s_cols_m.astype(f32) * as_col(t_row)
        adj_m = jnp.where(s_rows_m & s_cols_m, 0.0, adj_m)
        adj_m = jnp.where(t_rows | t_cols, 0.0, adj_m)
        sm_f = (col1 == s_reg).astype(f32)
        wl_m = jnp.where(tm_f > 0.5, 0.0, wl + sm_f * jnp.sum(wl * tm_f))
        wc_m = jnp.where(tm_f > 0.5, 0.0, wc + sm_f * jnp.sum(wc * tm_f))
        members_m = jnp.minimum(members + s_rows_m.astype(f32) * cloud_t, 1.0)
        members_m = jnp.where(t_rows, 0.0, members_m)
        alive_m = alive & ~(tm_f > 0.5)

        adj = jnp.where(do_merge, adj_m, adj)
        wl = jnp.where(do_merge, wl_m, wl)
        wc = jnp.where(do_merge, wc_m, wc)
        members = jnp.where(do_merge, members_m, members)
        alive = jnp.where(do_merge, alive_m, alive)
        src = jnp.where(do_merge & (t_reg == src), s_reg, src)
        return adj, wl, wc, alive, members, src, best_cut, best_cloud

    carry0 = (
        adj, wl, wc, alive, members, src0,
        jnp.asarray(POS_INF, f32), jnp.zeros((1, n), f32),
    )
    out = jax.lax.fori_loop(0, n - 1, phase, carry0)
    best_cut, best_cloud = out[6], out[7]
    return jnp.reshape(best_cut, (1, 1)), 1.0 - best_cloud


def _sw_block_body(
    adj_ref,   # (g, n, n) f32 — a block of g graphs
    wl_ref,    # (g, n) f32
    wc_ref,    # (g, n) f32
    pin_ref,   # (g, n) f32    1.0 = unoffloadable (pinned to local tier)
    cut_ref,   # (g, 1) f32    out: min over phases of Eq. 10
    mask_ref,  # (g, n) f32    out: 1.0 = execute locally
    *,
    n: int,
    g: int,
):
    """Solve the g graphs of this grid step back-to-back in VMEM.

    ``g == 1`` reproduces the historical one-graph-per-program grid
    bit-for-bit; ``g > 1`` amortizes per-invocation overhead (grid
    bookkeeping, output DMA turnaround) across g solves — the batch-grid
    tuning knob for small-bucket fleets where dispatch dominates.
    """
    adj_blk = adj_ref[...]
    wl_blk = wl_ref[...]
    wc_blk = wc_ref[...]
    pin_blk = pin_ref[...] > 0.5

    def solve_j(j, acc):
        cuts, masks = acc
        cut, mask = _solve_graph(
            jax.lax.dynamic_index_in_dim(adj_blk, j, 0, keepdims=False),
            jax.lax.dynamic_slice_in_dim(wl_blk, j, 1, 0),
            jax.lax.dynamic_slice_in_dim(wc_blk, j, 1, 0),
            jax.lax.dynamic_slice_in_dim(pin_blk, j, 1, 0),
            n=n,
        )
        cuts = jax.lax.dynamic_update_slice_in_dim(cuts, cut, j, 0)
        masks = jax.lax.dynamic_update_slice_in_dim(masks, mask, j, 0)
        return cuts, masks

    cuts0 = jnp.zeros((g, 1), jnp.float32)
    masks0 = jnp.zeros((g, n), jnp.float32)
    cuts, masks = jax.lax.fori_loop(0, g, solve_j, (cuts0, masks0))
    cut_ref[...] = cuts
    mask_ref[...] = masks


@functools.partial(jax.jit, static_argnames=("interpret", "block_graphs"))
def _sw_call(adj, wl, wc, pin, *, interpret: bool, block_graphs: int = 1):
    b, n, _ = adj.shape
    g = block_graphs
    assert b % g == 0, (b, g)
    body = functools.partial(_sw_block_body, n=n, g=g)
    cut, mask = pl.pallas_call(
        body,
        grid=(b // g,),
        in_specs=[
            pl.BlockSpec((g, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, n), lambda i: (i, 0)),
            pl.BlockSpec((g, n), lambda i: (i, 0)),
            pl.BlockSpec((g, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((g, 1), lambda i: (i, 0)),
            pl.BlockSpec((g, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(adj, wl, wc, pin)
    return cut[:, 0], mask > 0.5


def default_block_graphs(n: int, interpret: bool) -> int:
    """Graphs per program invocation for an n-vertex bucket.

    Compiled kernels amortize per-invocation overhead by solving several
    graphs per grid step: target ~2048 "vertex rows" of work per program,
    capped at 8 graphs and by the VMEM budget (the input block plus the
    ~5 n²-sized working arrays must fit).  The interpreter executes the
    grid serially with no per-step launch cost, so it keeps the
    historical 1-graph grid.  ``REPRO_MCOP_BLOCK_GRAPHS`` overrides both
    (the hillclimbing knob for real-TPU tuning).
    """
    import os

    override = os.environ.get("REPRO_MCOP_BLOCK_GRAPHS")
    if override is not None:
        g = int(override)
        if g < 1:
            raise ValueError(f"REPRO_MCOP_BLOCK_GRAPHS must be >= 1, got {g}")
        return g
    if interpret:
        return 1
    g = max(1, min(8, 2048 // max(n, 1)))
    while g > 1 and (g + 5) * n * n * 4 > _VMEM_BYTES:
        g //= 2
    return g


def _pad_batch(b: int, g: int) -> int:
    return (-b) % g


def mcop_stoer_wagner_kernel(
    adj: jnp.ndarray,       # (B, n, n) f32 — a batch of WCG adjacencies
    w_local: jnp.ndarray,   # (B, n)
    w_cloud: jnp.ndarray,   # (B, n)
    pinned: jnp.ndarray,    # (B, n) bool/f32 — True = unoffloadable
    *,
    interpret: bool | None = None,
    block_graphs: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Solve a batch of MCOP instances entirely on-device.

    ``block_graphs`` graphs per grid step (``None`` = auto, see
    :func:`default_block_graphs`); within a step each adjacency lives in
    VMEM for its whole |V|−1-phase run (single HBM load per solve).
    Batches that don't divide the block are zero-padded with pinned
    dummy graphs and cropped after.  Returns ``(min_cuts (B,),
    local_masks (B, n) bool)`` — semantics match
    :func:`repro.core.mcop.mcop_reference` (same heuristic, same
    tie-breaking, f32 arithmetic), independent of ``block_graphs``.
    Dead/padded vertices must be encoded as pinned with zero weights and
    zero incident edges.
    """
    adj = jnp.asarray(adj, jnp.float32)
    assert adj.ndim == 3, f"expected (B, n, n) batch, got {adj.shape}"
    b, n = adj.shape[0], adj.shape[-1]
    interp = _resolve_interpret(interpret)
    g = default_block_graphs(n, interp) if block_graphs is None else int(block_graphs)
    g = max(1, min(g, b if b else 1))
    # The body keeps the g-graph input block plus ~5 n²-sized working
    # arrays live (adj, eye, members, two iota matrices) — budget both.
    assert (g + 4) * n * n * 4 <= _VMEM_BYTES, (
        f"graph too large for single-core VMEM with kernel working set: "
        f"n={n}, block_graphs={g}"
    )
    wl = jnp.asarray(w_local, jnp.float32).reshape(b, n)
    wc = jnp.asarray(w_cloud, jnp.float32).reshape(b, n)
    pin = jnp.asarray(pinned, jnp.float32).reshape(b, n)
    pad = _pad_batch(b, g)
    if pad:
        adj = jnp.concatenate([adj, jnp.zeros((pad, n, n), jnp.float32)])
        wl = jnp.concatenate([wl, jnp.zeros((pad, n), jnp.float32)])
        wc = jnp.concatenate([wc, jnp.zeros((pad, n), jnp.float32)])
        pin = jnp.concatenate([pin, jnp.ones((pad, n), jnp.float32)])
    cuts, masks = _sw_call(adj, wl, wc, pin, interpret=interp, block_graphs=g)
    if pad:
        cuts, masks = cuts[:b], masks[:b]
    return cuts, masks


# ======================================================================
# Fused build+solve kernel — WCG weights constructed in VMEM, no HBM
# round-trip for the (B, n, n) adjacency batch.
# ======================================================================

# cost-model kinds the in-kernel builder implements (Eqs. 4 / 6 / 8);
# core.mcop maps CostModel instances onto these.
FUSED_MODEL_KINDS = ("time", "energy", "weighted")


def _kernel_weights(kind, omega, t_loc, d_in, d_out, d_in_t, d_out_t, env_row):
    """Eqs. 4/6/8 on VMEM-resident profile tensors, transpose-free.

    ``env_row`` is (1, 6): [bandwidth_up, bandwidth_down, speedup,
    p_compute, p_idle, p_transfer].  Mirrors
    ``repro.core.cost_models.CostModel.batch_weights`` in f32, except the
    symmetrisation uses pre-transposed copies of the data matrices
    (``d_in_t``/``d_out_t``) instead of ``swapaxes`` — plain VPU adds, no
    in-kernel transpose.  Returns ``(wl (1, n), wc (1, n), adj (n, n))``.
    """
    b_up = env_row[0, 0]
    b_down = env_row[0, 1]
    speedup = env_row[0, 2]
    p_c = env_row[0, 3]
    p_i = env_row[0, 4]
    p_tr = env_row[0, 5]

    # Eq. 1, symmetrised: per_dir + per_dirᵀ via the transposed copies.
    # Two-term association matches _edge_time_batch exactly (per-element
    # float sums are order-sensitive; transposing a division result is
    # bitwise the division of the transposed operand).
    per_dir = d_in / b_up + d_out / b_down
    per_dir_t = d_in_t / b_up + d_out_t / b_down
    adj_t = per_dir + per_dir_t
    wl_t = t_loc                      # (1, n)
    wc_t = t_loc / speedup
    if kind == "time":
        return wl_t, wc_t, adj_t
    wl_e = p_c * t_loc
    wc_e = p_i * wc_t
    adj_e = p_tr * adj_t
    if kind == "energy":
        return wl_e, wc_e, adj_e
    # Eq. 8: ω·T/T_local + (1−ω)·E/E_local, normalised per graph.
    t_norm = jnp.maximum(jnp.sum(wl_t), 1e-30)
    e_norm = jnp.maximum(jnp.sum(wl_e), 1e-30)
    w = jnp.float32(omega)
    return (
        w * wl_t / t_norm + (1 - w) * wl_e / e_norm,
        w * wc_t / t_norm + (1 - w) * wc_e / e_norm,
        w * adj_t / t_norm + (1 - w) * adj_e / e_norm,
    )


def _fused_block_body(
    tl_ref,     # (1, n) f32 — profile t_local, replicated across the grid
    din_ref,    # (n, n) f32 — profile data_in
    dout_ref,   # (n, n) f32 — profile data_out
    dint_ref,   # (n, n) f32 — data_inᵀ (host-pre-transposed)
    doutt_ref,  # (n, n) f32 — data_outᵀ
    pin_ref,    # (1, n) f32 — profile pinned mask (anchor included)
    env_ref,    # (g, 6) f32 — this block's environments
    cut_ref,    # (g, 1) f32 out
    mask_ref,   # (g, n) f32 out
    *,
    n: int,
    g: int,
    kind: str,
    omega: float,
):
    """Build each environment's WCG weights in VMEM, then solve it.

    The profile tensors are loaded once per program invocation and reused
    for all g graphs; only the (g, 6) environment rows vary — the
    adjacency batch never exists in HBM at all.
    """
    t_loc = tl_ref[...]
    d_in = din_ref[...]
    d_out = dout_ref[...]
    d_in_t = dint_ref[...]
    d_out_t = doutt_ref[...]
    pin = pin_ref[...] > 0.5
    env = env_ref[...]

    def solve_j(j, acc):
        cuts, masks = acc
        wl, wc, adj = _kernel_weights(
            kind,
            omega,
            t_loc,
            d_in,
            d_out,
            d_in_t,
            d_out_t,
            jax.lax.dynamic_slice_in_dim(env, j, 1, 0),
        )
        cut, mask = _solve_graph(adj, wl, wc, pin, n=n)
        cuts = jax.lax.dynamic_update_slice_in_dim(cuts, cut, j, 0)
        masks = jax.lax.dynamic_update_slice_in_dim(masks, mask, j, 0)
        return cuts, masks

    cuts0 = jnp.zeros((g, 1), jnp.float32)
    masks0 = jnp.zeros((g, n), jnp.float32)
    cuts, masks = jax.lax.fori_loop(0, g, solve_j, (cuts0, masks0))
    cut_ref[...] = cuts
    mask_ref[...] = masks


@functools.partial(
    jax.jit, static_argnames=("kind", "omega", "interpret", "block_graphs")
)
def _fused_call(
    t_local, data_in, data_out, pinned, env, *, kind, omega, interpret, block_graphs
):
    k = env.shape[0]
    n = t_local.shape[-1]
    g = block_graphs
    assert k % g == 0, (k, g)
    body = functools.partial(
        _fused_block_body, n=n, g=g, kind=kind, omega=omega
    )
    rep2 = pl.BlockSpec((n, n), lambda i: (0, 0))
    cut, mask = pl.pallas_call(
        body,
        grid=(k // g,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            rep2,
            rep2,
            rep2,
            rep2,
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((g, 6), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((g, 1), lambda i: (i, 0)),
            pl.BlockSpec((g, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        ],
        interpret=interpret,
    )(
        t_local.reshape(1, n),
        data_in,
        data_out,
        data_in.T,
        data_out.T,
        pinned.reshape(1, n).astype(jnp.float32),
        env,
    )
    return cut[:, 0], mask > 0.5


def mcop_fused_solve_kernel(
    t_local: jnp.ndarray,   # (n,) f32 — profile local execution times
    data_in: jnp.ndarray,   # (n, n) f32 — profile transfer-in bytes
    data_out: jnp.ndarray,  # (n, n) f32 — profile transfer-out bytes
    pinned: jnp.ndarray,    # (n,) bool/f32 — profile unoffloadable mask
    env: jnp.ndarray,       # (K, 6) f32 — per-graph environment columns
    *,
    kind: str,
    omega: float = 0.5,
    interpret: bool | None = None,
    block_graphs: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """VMEM-resident fused pipeline: env rows → WCG weights → min cut.

    The XLA-fused ``solve_envs`` path materializes the (K, n, n)
    adjacency batch in HBM between the build and the solve; this kernel
    builds each graph's weights in VMEM immediately before its phases
    run, so the only HBM traffic per graph is 6 environment scalars in
    and (1 + n) result floats out.  ``kind`` is one of
    ``FUSED_MODEL_KINDS`` (Eq. 4 / Eq. 6 / Eq. 8-with-``omega``).
    Returns ``(min_cuts (K,), local_masks (K, n) bool)``.
    """
    if kind not in FUSED_MODEL_KINDS:
        raise ValueError(
            f"unknown fused cost-model kind {kind!r}; expected one of "
            f"{FUSED_MODEL_KINDS}"
        )
    env = jnp.asarray(env, jnp.float32)
    assert env.ndim == 2 and env.shape[1] == 6, f"env must be (K, 6), got {env.shape}"
    k = env.shape[0]
    n = int(t_local.shape[-1])
    interp = _resolve_interpret(interpret)
    g = default_block_graphs(n, interp) if block_graphs is None else int(block_graphs)
    g = max(1, min(g, k if k else 1))
    # working set: 5 replicated n² profile blocks + ~5 n²-sized solver arrays
    assert 10 * n * n * 4 <= _VMEM_BYTES, (
        f"graph too large for single-core VMEM with fused working set: n={n}"
    )
    pad = _pad_batch(k, g)
    if pad:
        env = jnp.concatenate([env, jnp.ones((pad, 6), jnp.float32)])
    cuts, masks = _fused_call(
        jnp.asarray(t_local, jnp.float32),
        jnp.asarray(data_in, jnp.float32),
        jnp.asarray(data_out, jnp.float32),
        jnp.asarray(pinned, jnp.float32),
        env,
        kind=kind,
        omega=float(omega),
        interpret=interp,
        block_graphs=g,
    )
    if pad:
        cuts, masks = cuts[:k], masks[:k]
    return cuts, masks
