"""Pallas TPU kernel for one MCOP *MinCutPhase* (paper Algorithm 3).

The phase's hot loop is the Most-Tightly-Connected-Vertex scan:

    repeat |V|−1 times:
        Δ(v)  = conn(v) − [w_local(v) − w_cloud(v)]   over v ∉ A
        v*    = argmax Δ                               (VPU masked max)
        conn += adj[v*]                                (VPU row add)

Dense adjacency is the TPU-native layout (the paper's graphs are small —
tens to a few thousand vertices — so the whole (n, n) matrix fits VMEM:
n = 1024 f32 is 4 MB against the ~16 MB/core budget; ops.py enforces the
bound).  The entire phase runs as ONE kernel invocation — a
``lax.fori_loop`` over absorptions inside the kernel body — so there is a
single HBM→VMEM transfer of the adjacency per phase instead of one per
absorption: the loop is bandwidth-bound on `conn += adj[v*]` row reads,
which is exactly the term VMEM residency removes.

Outputs: the phase's cut value (Eq. 10), s and t (the last two vertices),
matching ``repro.core.mcop._min_cut_phase`` bit-for-bit on the paper's
worked example (property-tested in tests/test_kernels.py).

Padded vertices are encoded ``alive = 0`` and never selected (their score
is −∞); scalars travel as (1, 1) f32/i32 arrays to keep the kernel
TPU-lowering-friendly (2-D everywhere, no 0-D iota).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = pl.MemorySpace.ANY  # type: ignore[attr-defined]

__all__ = ["mcop_phase_kernel"]

NEG_INF = -2.0**30


def _phase_body(
    adj_ref,      # (n, n) f32
    gains_ref,    # (1, n) f32   w_local − w_cloud
    alive_ref,    # (1, n) f32   1.0 = vertex alive in the current graph
    src_ref,      # (1, 1) i32   anchor vertex a
    ctot_ref,     # (1, 1) f32   C_local = Σ w_local (original graph)
    cut_ref,      # (1, 1) f32   out: cut-of-the-phase
    s_ref,        # (1, 1) i32   out
    t_ref,        # (1, 1) i32   out
    *,
    n: int,
):
    adj = adj_ref[...]
    gains = gains_ref[0, :]
    alive = alive_ref[0, :] > 0.5
    src = src_ref[0, 0]

    n_alive = jnp.sum(alive.astype(jnp.int32))
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]

    in_a0 = alive & (idx == src)
    conn0 = adj[src, :]

    def absorb(i, carry):
        in_a, conn, s_reg, t_reg = carry
        cand = alive & ~in_a
        scores = jnp.where(cand, conn - gains, NEG_INF)
        v = jnp.argmax(scores).astype(jnp.int32)
        do = (i + 1) < n_alive          # absorb exactly n_alive−1 vertices
        in_a = jnp.where(do, in_a | (idx == v), in_a)
        conn = jnp.where(do, conn + adj[v, :], conn)
        s_reg = jnp.where(do, t_reg, s_reg)
        t_reg = jnp.where(do, v, t_reg)
        return in_a, conn, s_reg, t_reg

    _, _, s_reg, t_reg = jax.lax.fori_loop(
        0, n - 1, absorb, (in_a0, conn0, src, src)
    )

    # Eq. 10: C_cut(A−t, t) = C_local − gains[t] + Σ_{v alive} w(e(t, v))
    comm = jnp.sum(adj[t_reg, :] * alive.astype(jnp.float32))
    cut_ref[0, 0] = ctot_ref[0, 0] - gains[t_reg] + comm
    s_ref[0, 0] = s_reg
    t_ref[0, 0] = t_reg


def mcop_phase_kernel(
    adj: jnp.ndarray,     # (n, n) f32 — current (possibly merged) graph
    gains: jnp.ndarray,   # (n,) f32
    alive: jnp.ndarray,   # (n,) bool/f32
    src: int | jnp.ndarray,
    c_local_total: float | jnp.ndarray,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run one MinCutPhase.  Returns (cut_value, s, t)."""
    n = adj.shape[0]
    # VMEM bound: adjacency + vectors must fit on-core.
    assert n * n * 4 <= 12 * 2**20, f"graph too large for single-core VMEM: n={n}"
    body = functools.partial(_phase_body, n=n)
    cut, s, t = pl.pallas_call(
        body,
        grid=(),
        in_specs=[
            pl.BlockSpec(adj.shape, lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        adj.astype(jnp.float32),
        jnp.asarray(gains, jnp.float32)[None, :],
        jnp.asarray(alive, jnp.float32)[None, :],
        jnp.asarray(src, jnp.int32).reshape(1, 1),
        jnp.asarray(c_local_total, jnp.float32).reshape(1, 1),
    )
    return cut[0, 0], s[0, 0], t[0, 0]
