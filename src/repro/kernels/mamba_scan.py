"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

The SSD ("state-space dual") formulation splits the selective-scan into an
*intra-chunk* quadratic term (an MXU-friendly (Q×Q)·(Q×P) matmul pair) and
an *inter-chunk* linear recurrence over per-chunk states.  That is exactly
the decomposition ``repro.models.ssm.mamba2_forward`` uses in pure jnp;
this kernel fuses one (batch, head) stream of it with the chunk loop kept
*sequential on the grid* so the running state h ∈ R^{P×N} lives in VMEM
scratch between chunks and never round-trips to HBM.

Grid: (batch, heads, num_chunks) — num_chunks is the innermost, sequential
("arbitrary") dimension.  Per step the VMEM working set is

    x (Q×P) + B,C (Q×N each) + decay tables (Q×Q) + h (P×N)

≈ 0.75 MB for the production Q=256, P=64, N=64 — far under VMEM budget,
leaving room for the compiler to double-buffer the HBM→VMEM streams of the
next chunk while the MXU works on this one.

Inputs are pre-projected (the surrounding jnp layer does conv/gating —
those are elementwise and XLA-fused); the kernel consumes:

    x   (B, H, NC, Q, P)   — per-head inputs
    dt  (B, H, NC, Q)      — softplus'd step sizes
    ld  (B, H, NC, Q)      — log-decay dt·a  (a < 0)
    Bm  (B, NC, Q, N)      — input projection (shared across heads)
    Cm  (B, NC, Q, N)      — output projection (shared across heads)
    h0  (B, H, P, N)       — initial state

and returns y (B, H, NC, Q, P) plus the final state (B, H, P, N).
The pure-jnp oracle is ``ref.mamba_chunk_scan_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = pl.MemorySpace.ANY  # type: ignore[attr-defined]

__all__ = ["mamba_chunk_scan_kernel"]


def _ssd_body(
    x_ref,     # (1, 1, 1, Q, P)
    dt_ref,    # (1, 1, 1, Q)
    ld_ref,    # (1, 1, 1, Q)
    b_ref,     # (1, 1, Q, N)
    c_ref,     # (1, 1, Q, N)
    h0_ref,    # (1, 1, P, N)
    y_ref,     # (1, 1, 1, Q, P)
    hout_ref,  # (1, 1, P, N)
    h_ref,     # VMEM scratch (P, N) f32 — carried across chunks
    *,
    num_chunks: int,
    q_len: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    ld = ld_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    bm = b_ref[0, 0].astype(jnp.float32)            # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)            # (Q, N)
    h = h_ref[...]                                  # (P, N)

    cum = jnp.cumsum(ld)                            # (Q,)

    # ---- intra-chunk quadratic term --------------------------------
    # w[t, s] = exp(cum_t − cum_s) · (C_t·B_s) · dt_s   for s ≤ t
    row = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    causal = col <= row
    decay = cum[:, None] - cum[None, :]             # (Q, Q)
    gate = jnp.where(causal, jnp.exp(decay), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, Q)
    w = scores * gate * dt[None, :]
    y_intra = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, P)

    # ---- inter-chunk: read state entering the chunk ------------------
    # y_inter[t] = exp(cum_t) · C_t · hᵀ
    ch = jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, P)
    y = y_intra + jnp.exp(cum)[:, None] * ch
    y_ref[...] = y[None, None, None].astype(y_ref.dtype)

    # ---- state update -------------------------------------------------
    # h ← h·exp(cum_end) + Σ_s exp(cum_end − cum_s)·dt_s · x_s ⊗ B_s
    tail = jnp.exp(cum[-1] - cum) * dt              # (Q,)
    s_n = jax.lax.dot_general(
        x, bm * tail[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (P, N)
    h_new = h * jnp.exp(cum[-1]) + s_n
    h_ref[...] = h_new

    @pl.when(ci == num_chunks - 1)
    def _final():
        hout_ref[...] = h_new[None, None].astype(hout_ref.dtype)


def mamba_chunk_scan_kernel(
    x: jnp.ndarray,    # (B, H, NC, Q, P) float32
    dt: jnp.ndarray,   # (B, H, NC, Q)
    ld: jnp.ndarray,   # (B, H, NC, Q)
    bm: jnp.ndarray,   # (B, NC, Q, N)
    cm: jnp.ndarray,   # (B, NC, Q, N)
    h0: jnp.ndarray,   # (B, H, P, N)
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, h, nc, q, p = x.shape
    n = bm.shape[-1]
    body = functools.partial(_ssd_body, num_chunks=nc, q_len=q)
    y, h_final = pl.pallas_call(
        body,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b_, h_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b_, h_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, ld, bm, cm, h0)
    return y, h_final
