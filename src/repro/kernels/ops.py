"""Jit'd public wrappers around the Pallas kernels.

The model code keeps its (B, S, H, hd) layout; these wrappers handle the
head-major transposes, GQA plumbing, chunk reshapes and interpret-mode
selection (interpret=True on CPU — this container — and compiled on TPU).

``flash_attention``     — drop-in for models.attention.chunked_attention.
``mamba_chunk_scan``    — drop-in for the scan core of ssm.mamba2_forward.
``mcop_min_cut``        — full MCOP built on the mcop_phase kernel: the
                          phase loop (merging, Eq. 10 bookkeeping) runs in
                          numpy on host, each phase's O(V²) hot scan runs
                          in the kernel.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.mamba_scan import mamba_chunk_scan_kernel
from repro.kernels.mcop_phase import mcop_phase_kernel

__all__ = [
    "flash_attention",
    "mamba_chunk_scan",
    "mcop_min_cut",
    "on_tpu",
    "default_interpret",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """Pallas interpret-mode default, detected once from the JAX backend.

    Compiled kernels on TPU; the (slow but portable) interpreter everywhere
    else — CPU CI containers, GPU hosts.  Kernel wrappers take
    ``interpret=None`` to mean "use this".

    The ``REPRO_PALLAS_INTERPRET`` environment variable overrides the
    detection without code edits (the TPU-validation knob): ``1/true/
    yes/on`` forces interpret mode, ``0/false/no/off`` forces compiled
    kernels.  The value is read once per process (lru_cache); call
    ``default_interpret.cache_clear()`` after changing it.
    """
    override = os.environ.get("REPRO_PALLAS_INTERPRET")
    if override is not None:
        norm = override.strip().lower()
        if norm in ("1", "true", "yes", "on"):
            return True
        if norm in ("0", "false", "no", "off"):
            return False
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET={override!r} is not a boolean "
            "(use 1/true/yes/on or 0/false/no/off)"
        )
    return not on_tpu()


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, S, H, hd) — model layout
    k: jnp.ndarray,   # (B, S, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(
        qh, kh, vh,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_chunk_scan(
    x: jnp.ndarray,    # (B, S, H, P)
    dt: jnp.ndarray,   # (B, S, H)
    ld: jnp.ndarray,   # (B, S, H) — log decay dt·a
    bm: jnp.ndarray,   # (B, S, N)
    cm: jnp.ndarray,   # (B, S, N)
    h0: jnp.ndarray,   # (B, H, P, N)
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xk = x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)     # (B,H,NC,Q,P)
    dtk = dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2)         # (B,H,NC,Q)
    ldk = ld.reshape(b, nc, q, h).transpose(0, 3, 1, 2)
    bmk = bm.reshape(b, nc, q, n)
    cmk = cm.reshape(b, nc, q, n)
    y, hT = mamba_chunk_scan_kernel(
        xk.astype(jnp.float32),
        dtk.astype(jnp.float32),
        ldk.astype(jnp.float32),
        bmk.astype(jnp.float32),
        cmk.astype(jnp.float32),
        h0.astype(jnp.float32),
        interpret=interpret,
    )
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)
    return y, hT


def mcop_min_cut(
    adj: np.ndarray,
    w_local: np.ndarray,
    w_cloud: np.ndarray,
    offloadable: np.ndarray,
    *,
    interpret: bool | None = None,
) -> tuple[float, np.ndarray]:
    """MCOP with the per-phase hot loop on the accelerator.

    Host keeps the graph-surgery (Algorithm 1 merges, Algorithm 2 loop) in
    numpy — that part is O(V²) total and latency-bound — while each
    MinCutPhase's O(V²) scan runs in the Pallas kernel.  Returns
    (min_cut, local_mask over original vertices).
    """
    adj = np.array(adj, np.float32)
    w_local = np.array(w_local, np.float32)
    w_cloud = np.array(w_cloud, np.float32)
    n = adj.shape[0]
    alive = np.ones(n, bool)
    members = [{i} for i in range(n)]
    c_total = float(w_local.sum())

    # merge unoffloadables into the anchor
    pinned = np.nonzero(~np.asarray(offloadable, bool))[0]
    src = int(pinned[0]) if pinned.size else 0

    def merge(s: int, t: int) -> None:
        adj[s, :] += adj[t, :]
        adj[:, s] += adj[:, t]
        adj[s, s] = 0.0
        adj[t, :] = 0.0
        adj[:, t] = 0.0
        w_local[s] += w_local[t]
        w_cloud[s] += w_cloud[t]
        members[s] |= members[t]
        members[t] = set()
        alive[t] = False

    for other in pinned[1:]:
        merge(src, int(other))

    best_cut, best_cloud = np.inf, frozenset()
    while alive.sum() > 1:
        cut, s, t = mcop_phase_kernel(
            jnp.asarray(adj),
            jnp.asarray(w_local - w_cloud),
            jnp.asarray(alive.astype(np.float32)),
            src,
            c_total,
            interpret=interpret,
        )
        cut, s, t = float(cut), int(s), int(t)
        if cut < best_cut:
            best_cut = cut
            best_cloud = frozenset(members[t])
        if s != t:
            merge(s, t)
            if t == src:
                src = s
        else:  # degenerate single-alive-vertex phase
            break

    local_mask = np.ones(n, bool)
    for i in best_cloud:
        local_mask[i] = False
    return best_cut, local_mask
