"""Program profiler: architecture config + shape → stage-level WCG inputs.

The paper's program profiler walks a call graph measuring per-method time
and per-invocation transfer bytes (§6.1).  Here the "program" is a model
config and the "methods" are pipeline-able stages; costs are *analytic*
(FLOPs, HBM bytes, activation bytes) — exactly the quantities a dynamic
profiler would measure on hardware, derived instead from the architecture
algebra.  The output plugs into ``core.placement.build_stage_wcg``
unchanged, so swapping analytic → measured numbers on a real fleet does
not touch the partitioning stack.

Stage granularity: embed | one vertex per transformer layer (or layer
group) | head.  Embed is pinned to the local tier (the paper's
camera/GPS-style unoffloadable source); for decode shapes the head/sampler
is pinned local too (tokens must return to the serving front-end).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cost_models import AppProfile
from repro.core.placement import StageSpec

__all__ = ["layer_flops", "layer_param_bytes", "stage_specs", "app_profile_from_config"]

_DTYPE_BYTES = {"bfloat16": 2, "float32": 4, "float16": 2}


def _attn_kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV-cache bytes appended per token per layer."""
    b = _DTYPE_BYTES[cfg.dtype]
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return (m.kv_lora_rank + m.qk_rope_head_dim) * b
    return 2 * cfg.n_kv_heads * cfg.resolved_head_dim * b


def layer_param_count(cfg: ModelConfig) -> int:
    """Average parameters per layer (experts included once — they are
    weights that must live somewhere, which is what placement cares about)."""
    n_layers = max(cfg.n_layers, 1)
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return max((cfg.param_count() - embed) // n_layers, 1)


def active_layer_param_count(cfg: ModelConfig) -> int:
    """Average *active* parameters per layer (MoE: routed-to experts only)."""
    n_layers = max(cfg.n_layers, 1)
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return max((cfg.active_param_count() - embed) // n_layers, 1)


def layer_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """FLOPs per step for ONE layer under the given shape.

    matmul term: 2·P_active·tokens (×3 for train fwd+bwd).
    attention term: 4·B·S²·d_attn·causal_factor (quadratic mixers only);
    decode reads the cache instead: 4·B·S_cache·d_attn.
    """
    p_act = active_layer_param_count(cfg)
    tokens = shape.tokens
    mm = 2.0 * p_act * tokens
    d_attn = cfg.n_heads * cfg.resolved_head_dim
    if cfg.attn_kind == "none" or cfg.family == "ssm":
        attn = 0.0
        # SSD/recurrent mixing: linear in S — fold into an effective matmul
        attn = 2.0 * tokens * cfg.d_model * max(cfg.ssm_state, 16)
    elif shape.kind == "decode":
        attn = 4.0 * shape.global_batch * shape.seq_len * d_attn
    else:
        attn = 2.0 * shape.global_batch * (shape.seq_len**2) * d_attn  # causal ½·4
    total = mm + attn
    if shape.kind == "train":
        total *= 3.0  # backward ≈ 2× forward
    return total


def layer_param_bytes(cfg: ModelConfig) -> float:
    return layer_param_count(cfg) * _DTYPE_BYTES[cfg.dtype]


def layer_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """HBM traffic per layer per step: weights + activations (+ KV reads)."""
    b = _DTYPE_BYTES[cfg.dtype]
    act = shape.tokens * cfg.d_model * b * 4  # read+write, residual+branch
    kv = 0.0
    if shape.kind == "decode" and cfg.attn_kind != "none" and cfg.family != "ssm":
        kv = shape.global_batch * shape.seq_len * _attn_kv_bytes_per_token(cfg)
    w = layer_param_bytes(cfg)
    if shape.kind == "train":
        act *= 3  # grads/recompute traffic
        w *= 3    # read weights fwd+bwd, write grads
    return w + act + kv


def boundary_act_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Bytes crossing a layer→layer cut per step (the WCG edge numerator)."""
    b = _DTYPE_BYTES[cfg.dtype]
    per = shape.tokens * cfg.d_model * b
    if shape.kind == "train":
        per *= 2  # activations forward + activation-grads backward
    return per


def stage_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    group: int = 1,
    pin_head_local: bool | None = None,
) -> list[StageSpec]:
    """One StageSpec per layer group, plus pinned embed/head stages."""
    if pin_head_local is None:
        pin_head_local = shape.kind == "decode"  # sampler feeds the front-end
    b = _DTYPE_BYTES[cfg.dtype]
    n_groups = max(cfg.n_layers // group, 1)
    lf = layer_flops(cfg, shape) * group
    lb = layer_hbm_bytes(cfg, shape) * group
    edge = boundary_act_bytes(cfg, shape)

    embed_flops = 2.0 * shape.tokens * cfg.d_model
    head_flops = 2.0 * shape.tokens * cfg.d_model * cfg.vocab_size
    if shape.kind == "decode":
        head_flops = 2.0 * shape.global_batch * cfg.d_model * cfg.vocab_size
    if shape.kind == "train":
        head_flops *= 3.0

    stages = [
        StageSpec(
            name="embed",
            flops=embed_flops,
            bytes_hbm=shape.tokens * cfg.d_model * b,
            act_bytes_out=edge,
            params_bytes=cfg.vocab_size * cfg.d_model * b,
            pinned_tier=0,
        )
    ]
    for g in range(n_groups):
        stages.append(
            StageSpec(
                name=f"layers[{g * group}:{(g + 1) * group}]",
                flops=lf,
                bytes_hbm=lb,
                act_bytes_out=edge,
                params_bytes=layer_param_bytes(cfg) * group,
            )
        )
    stages.append(
        StageSpec(
            name="head",
            flops=head_flops,
            bytes_hbm=cfg.vocab_size * cfg.d_model * b,
            act_bytes_out=shape.tokens * 4.0,  # token ids / logits summary back
            params_bytes=cfg.vocab_size * cfg.d_model * b,
            pinned_tier=0 if pin_head_local else None,
        )
    )
    return stages


def app_profile_from_config(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    group: int = 1,
    local_flops_per_s: float = 197e12 * 256,
) -> AppProfile:
    """Paper-style AppProfile (t_local per task, transfer bytes per edge).

    ``t_local`` is the stage time on the *local* tier; cost models scale
    the cloud side by F and the edges by the measured bandwidth — this is
    the object the adaptive controller re-prices as the environment drifts.
    """
    import numpy as np

    stages = stage_specs(cfg, shape, group=group)
    n = len(stages)
    t_local = np.array([s.flops / local_flops_per_s for s in stages])
    data_in = np.zeros((n, n))
    data_out = np.zeros((n, n))
    for i, st in enumerate(stages):
        succ = st.successors if st.successors else ((i + 1,) if i + 1 < n else ())
        for j in succ:
            data_in[i, j] = st.act_bytes_out
    offloadable = np.array([s.pinned_tier is None for s in stages])
    return AppProfile(
        t_local=t_local,
        data_in=data_in,
        data_out=data_out,
        offloadable=offloadable,
        names=[s.name for s in stages],
    )
