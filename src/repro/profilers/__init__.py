"""Profilers (paper §6): program, network and energy information collection."""

from repro.profilers.network import NetworkProfiler, SimulatedChannel
from repro.profilers.energy import EnergyProfiler, EnergyReport
from repro.profilers.program import (
    app_profile_from_config,
    boundary_act_bytes,
    layer_flops,
    layer_param_bytes,
    stage_specs,
)

__all__ = [
    "NetworkProfiler",
    "SimulatedChannel",
    "EnergyProfiler",
    "EnergyReport",
    "app_profile_from_config",
    "boundary_act_bytes",
    "layer_flops",
    "layer_param_bytes",
    "stage_specs",
]
