"""Network profiler (paper §6.2).

The paper's network profiler measures throughput "by measuring the time
duration when sending a certain amount of data" and continuously monitors
environmental changes.  Here the links being profiled are inter-pod DCN /
intra-pod ICI / host PCIe rather than WiFi/3G, but the estimator is the
same: timed transfers folded into an exponentially-weighted moving average,
with variance tracking so the adaptive controller can distinguish drift
from noise.

On this CPU-only container real link hardware does not exist, so
:class:`SimulatedChannel` plays the role of the physical link: it models a
configurable true bandwidth with multiplicative jitter and regime shifts
(the paper's "user moves to another location"), and *actually moves bytes*
(numpy copies) so the profiler's timing path is exercised end to end.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

__all__ = ["SimulatedChannel", "NetworkProfiler", "BandwidthSample"]


@dataclasses.dataclass
class BandwidthSample:
    bytes_moved: int
    seconds: float

    @property
    def bandwidth(self) -> float:
        return self.bytes_moved / max(self.seconds, 1e-12)


class SimulatedChannel:
    """A fake link with a true (hidden) bandwidth and measurement noise.

    ``transfer(nbytes)`` returns the simulated wall time for the transfer
    and performs a real memory copy of the payload so that profiling code
    paths run against actual buffers.
    """

    def __init__(
        self,
        bandwidth: float,
        *,
        jitter: float = 0.05,
        latency: float = 1e-4,
        seed: int = 0,
    ):
        self.true_bandwidth = float(bandwidth)
        self.jitter = jitter
        self.latency = latency
        self._rng = np.random.default_rng(seed)

    def set_bandwidth(self, bandwidth: float) -> None:
        """Regime shift — the paper's environment change."""
        self.true_bandwidth = float(bandwidth)

    def transfer(self, nbytes: int) -> BandwidthSample:
        payload = np.empty(max(1, nbytes // 8), dtype=np.float64)
        _ = payload.copy()  # real data movement, keeps timing honest
        noise = 1.0 + self.jitter * self._rng.standard_normal()
        noise = max(noise, 0.2)
        seconds = self.latency + nbytes / (self.true_bandwidth * noise)
        return BandwidthSample(bytes_moved=nbytes, seconds=seconds)


class NetworkProfiler:
    """EWMA bandwidth estimator with drift detection (paper Fig. 1 input).

    ``alpha`` is the EWMA smoothing factor; ``probe_bytes`` the size of an
    active probe.  Passive samples (real transfers the runtime performed
    anyway) are folded in for free via :meth:`record`.
    """

    def __init__(
        self,
        channel: SimulatedChannel | None = None,
        *,
        alpha: float = 0.3,
        probe_bytes: int = 1 << 20,
    ):
        self.channel = channel
        self.alpha = alpha
        self.probe_bytes = probe_bytes
        self._estimate: float | None = None
        self._var: float = 0.0
        self.samples: list[BandwidthSample] = []

    # ------------------------------------------------------------------
    def record(self, sample: BandwidthSample) -> float:
        bw = sample.bandwidth
        if self._estimate is None:
            self._estimate = bw
        else:
            delta = bw - self._estimate
            self._estimate += self.alpha * delta
            self._var = (1 - self.alpha) * (self._var + self.alpha * delta * delta)
        self.samples.append(sample)
        return self._estimate

    def probe(self) -> float:
        """Active measurement against the attached channel."""
        if self.channel is None:
            raise RuntimeError("no channel attached for active probing")
        t0 = time.perf_counter()
        sample = self.channel.transfer(self.probe_bytes)
        _ = time.perf_counter() - t0  # host-side overhead, unused in sim
        return self.record(sample)

    # ------------------------------------------------------------------
    @property
    def bandwidth(self) -> float:
        if self._estimate is None:
            raise RuntimeError("no samples yet")
        return self._estimate

    @property
    def std(self) -> float:
        return float(np.sqrt(self._var))

    def relative_uncertainty(self) -> float:
        if self._estimate in (None, 0.0):
            return float("inf")
        return self.std / self._estimate
