"""Energy profiler (paper §6.3).

The paper estimates device energy with a power model (PowerTutor-style
software monitor): per-component powers integrated over activity time.
We keep exactly that structure.  For the paper-reproduction figures the
powers are the HP iPAQ constants (P_m=0.9 W, P_i=0.3 W, P_tr=1.3 W); for
the TPU-tier instantiation they become per-chip compute/idle/link watts
from :class:`~repro.core.placement.TierSpec`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_models import Environment
from repro.core.graph import WCG

__all__ = ["EnergyReport", "EnergyProfiler"]


@dataclasses.dataclass
class EnergyReport:
    compute_j: float
    idle_j: float
    transfer_j: float

    @property
    def total_j(self) -> float:
        return self.compute_j + self.idle_j + self.transfer_j


class EnergyProfiler:
    """Integrates the power model over a placement's activity timeline.

    Mirrors Eq. 6 exactly: local vertices draw P_m for their local runtime,
    offloaded vertices leave the device idling at P_i for the remote
    runtime, and every cut edge draws P_tr for its transfer time.
    """

    def __init__(self, env: Environment):
        self.env = env

    def measure(self, time_wcg: WCG, local_mask: np.ndarray) -> EnergyReport:
        """``time_wcg`` must be the *response-time* WCG (node=time, edge=time)."""
        local_mask = np.asarray(local_mask, dtype=bool)
        compute = float(time_wcg.w_local[local_mask].sum()) * self.env.p_compute
        idle = float(time_wcg.w_cloud[~local_mask].sum()) * self.env.p_idle
        cut = local_mask[:, None] != local_mask[None, :]
        transfer_t = float((time_wcg.adj * cut).sum() / 2.0)
        transfer = transfer_t * self.env.p_transfer
        return EnergyReport(compute_j=compute, idle_j=idle, transfer_j=transfer)
