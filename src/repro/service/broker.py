"""OffloadBroker — async multi-tenant partition service (serving tier).

The paper's adaptive loop (Fig. 1) is per-user: profile once, monitor
the environment, re-partition on drift.  At serving scale millions of
users run the *same* profiled applications through a handful of
recurring environment regimes, so solving each repartition point
one-at-a-time wastes both dispatches and solutions.  The broker is the
subsystem that turns the PR-2 throughput primitives
(:func:`repro.core.mcop.mcop_batch`,
:class:`repro.core.placement_cache.PlacementCache`) into a long-lived
service:

* **Tenants** — one registered (profile, cost model) pair per served
  application, each with its own shared
  :class:`~repro.core.placement_cache.PlacementCache` guarded by a
  :func:`~repro.core.placement_cache.profile_fingerprint`.
* **Async submit** — per-user controllers
  (:class:`repro.service.session.BrokerSession` wrapping
  :class:`~repro.core.adaptive.AdaptiveController`) and elastic events
  (:meth:`repro.runtime.elastic.ElasticMeshManager.submit_resize`)
  enqueue solve requests and get a :class:`PlacementFuture` back.
* **Coalescing tick** — :meth:`OffloadBroker.tick` drains the queue,
  serves cache hits immediately, coalesces remaining requests by
  (tenant, quantized-environment-bin) down to one representative solve
  per bin, and flushes all representatives through **one**
  ``mcop_batch`` call per static shape bucket.  Followers and hits are
  repriced under their *exact* request graph (same honesty contract as
  the controller), so a tick costs O(distinct bins), not O(requests).
* **Array-native flush** — :meth:`submit` no longer builds a WCG per
  request: construction is deferred to the tick, where each tenant's
  pending environments are built in ONE vectorized
  ``cost_model.build_batch`` call (rows bit-identical to the scalar
  builder), and each bucket's representatives are packed into a
  :class:`~repro.core.graph.WCGBatch` that ``mcop_batch`` dispatches
  directly — no per-request Python graph objects on the hot path.
* **Fused tick pricing** — every reply a tick produces (cache hits,
  representative clamps, coalesced followers) is priced in one
  vectorized :meth:`~repro.core.graph.WCGBatch.price_batch` evaluation
  per graph size instead of a scalar ``reprice_clamped`` per future;
  replies are bit-identical to the serial per-future path (unpadded
  pricing batches, see ``repro.core.pricing``).
* **Weighted-fair scheduling** — the flush order is a
  :class:`~repro.service.scheduler.WeightedFairScheduler`: elastic
  resize events
  (:meth:`~repro.runtime.elastic.ElasticMeshManager.submit_resize`,
  ``lane="elastic"``) remain a strict priority lane (a shrinking fleet
  must re-place before any user refresh is served a placement solved
  for capacity that no longer exists), and user-lane requests drain by
  deficit round robin over per-tenant weights (``register(...,
  weight=)``), so a chatty tenant cannot starve a light one when
  :meth:`tick` runs with a ``budget``.  Backpressure: past
  ``max_queued_bins`` distinct queued (tenant, bin) pairs, a submission
  opening a new bin is rejected — its future resolves immediately with
  a :attr:`BrokerReply.rejected` reply.  Lane occupancy, per-tenant
  shares and rejections are telemetered per tick
  (:attr:`TickReport.elastic` / :attr:`TickReport.shares` /
  :attr:`TickReport.rejected`).
* **Batched session groups** — :meth:`OffloadBroker.register_batch`
  attaches a :class:`~repro.service.session.BatchSessionGroup`: K
  sessions of a tenant held as ONE
  :class:`~repro.core.session_batch.SessionBatch` pytree, observed as
  arrays and resolved per tick by one vectorized
  :func:`~repro.core.session_batch.tick_sessions` call against the
  tenant's shared cache — the 10⁵–10⁶-concurrent-user path, with events
  bit-identical to the per-object sessions above.  Group service
  latency feeds the scheduler's optional load-adaptive weights
  (``register(..., adaptive_weight=True)``).
* **Fault tolerance** (opt-in) — constructed with a
  :class:`~repro.service.resilience.ResiliencePolicy` (and optionally a
  seeded :class:`~repro.service.faults.FaultInjector` for chaos
  testing), the tick becomes a failure domain per (bin, bucket): solver
  dispatches retry with exponential backoff under a per-backend circuit
  breaker (pallas → jax → reference), a flush that exhausts its retries
  quarantines ONLY its own bucket's requests — served a *fallback
  placement* (stale cached bin if available, else the paper's §4.3
  no-offload plan) marked :attr:`BrokerReply.degraded`, or re-queued —
  while healthy buckets commit normally; per-request deadlines resolve
  overdue queued futures as :attr:`BrokerReply.timed_out`; and
  :meth:`OffloadBroker.drain` resolves abandoned futures at shutdown
  instead of stranding them.  With ``resilience=None`` (default) the
  legacy contract is preserved bit-identically: failures re-queue
  unresolved requests and re-raise.
* **Persistence** — tenant caches snapshot/load as JSON
  (:meth:`OffloadBroker.snapshot` / ``warm_start=`` on
  :meth:`OffloadBroker.register`), so a serving restart replays a known
  workload with *zero* solver dispatches.
* **Telemetry** — per-tick latency, queue depth, coalesce ratio and
  cache hit rate (:class:`BrokerTelemetry`), the numbers a deployment
  would alert on.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import baselines
from repro.core.cost_models import AppProfile, CostModel, Environment
from repro.core.graph import WCG, WCGBatch
from repro.core.mcop import DEFAULT_BUCKETS, MCOPResult, _bucket_size, mcop_batch
from repro.core.placement_cache import (
    EnvQuantizer,
    PlacementCache,
    profile_fingerprint,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer
from repro.service.faults import FaultInjector, InjectedFault, poison_batch
from repro.service.resilience import ResiliencePolicy
from repro.service.scheduler import QueueEntry, WeightedFairScheduler

__all__ = [
    "PlacementFuture",
    "BrokerReply",
    "TickReport",
    "BrokerTelemetry",
    "OffloadBroker",
]


@dataclasses.dataclass(frozen=True)
class BrokerReply:
    """What a resolved :class:`PlacementFuture` carries.

    ``result`` is clamped (paper §4.3) and priced under the requester's
    exact WCG — identical to what a serial
    :meth:`~repro.core.adaptive.AdaptiveController.observe` would have
    produced.  ``cache_hit`` mirrors the controller's event flag
    (coalesced followers count as hits: the serial loop would have hit
    the representative's just-stored mask).  ``coalesced`` additionally
    distinguishes same-tick followers from genuine cache hits.

    ``rejected`` marks a backpressure rejection (the scheduler's queued
    -bin cap was reached); a rejected reply carries ``result=None`` and
    resolves at submit time, so callers can retry a later tick without
    waiting.  A broker shutdown (:meth:`OffloadBroker.drain`) also
    resolves abandoned futures as rejected.

    ``degraded`` marks a graceful-degradation reply (resilient brokers
    only): the solve exhausted its retries, so ``result`` is a *fallback
    placement* — the stale cached bin if one existed, else the paper's
    §4.3 no-offload plan — always valid, possibly not optimal.

    ``timed_out`` marks a deadline expiry: the request was still queued
    past its deadline tick and carries ``result=None``.
    """

    result: MCOPResult | None
    cache_hit: bool
    coalesced: bool
    tick: int
    rejected: bool = False
    degraded: bool = False
    timed_out: bool = False


class PlacementFuture:
    """Minimal single-assignment future resolved by :meth:`OffloadBroker.tick`.

    Deliberately not ``asyncio`` — the broker is deterministic and
    tick-driven, so waiters poll :attr:`done` after a tick rather than
    suspend on an event loop.
    """

    __slots__ = ("_reply",)

    def __init__(self) -> None:
        self._reply: BrokerReply | None = None

    @property
    def done(self) -> bool:
        return self._reply is not None

    def set(self, reply: BrokerReply) -> None:
        if self._reply is not None:
            raise RuntimeError("future already resolved")
        self._reply = reply

    @property
    def result(self) -> BrokerReply:
        if self._reply is None:
            raise RuntimeError("future not resolved yet; run broker.tick()")
        return self._reply


@dataclasses.dataclass(frozen=True)
class TickReport:
    """One tick's telemetry snapshot."""

    tick: int
    queue_depth: int        # requests waiting when the tick started
    requests: int           # requests drained this tick (== queue_depth
                            # unless the tick ran with a budget)
    cache_hits: int         # served from a tenant cache, no solve
    coalesced: int          # same-bin followers folded into another solve
    solved: int             # representative solves actually dispatched
    dispatches: int         # mcop_batch calls (≤ one per shape bucket)
    buckets: tuple[int, ...]  # bucket sizes dispatched this tick
    latency_s: float        # wall time of the tick under the broker clock
    elastic: int = 0        # priority-lane occupancy: elastic events drained
    rejected: int = 0       # backpressure rejections since the last tick
    shares: tuple[tuple[str, int], ...] = ()  # per-tenant requests drained
                            # this tick (name-sorted) — the WFQ split
    batch_groups: int = 0   # session batch groups ticked
    batch_sessions: int = 0  # active batched sessions observed this tick
    batch_hits: int = 0     # batched due-sessions served from cache
    batch_solved: int = 0   # representative solves for batched sessions
    # fault-tolerance counters (resilient brokers; all zero otherwise)
    faults: int = 0         # injected/observed fault events this tick
    retries: int = 0        # dispatch retries performed this tick
    breaker_trips: int = 0  # circuit-breaker open transitions this tick
    degraded: int = 0       # fallback-placement replies this tick
    timed_out: int = 0      # futures resolved as timed-out this tick


# TickReport field → BrokerTelemetry aggregate attribute (they differ in
# a few names); used to seed registry views from pre-bind history
_TEL_FIELD = {
    "requests": "requests",
    "cache_hits": "cache_hits",
    "coalesced": "coalesced",
    "solved": "solved",
    "dispatches": "dispatches",
    "elastic": "elastic_requests",
    "rejected": "rejected_requests",
    "batch_sessions": "batch_sessions",
    "batch_solved": "batch_solved",
    "faults": "faults",
    "retries": "retries",
    "breaker_trips": "breaker_trips",
    "degraded": "degraded_replies",
    "timed_out": "timed_out_requests",
}


@dataclasses.dataclass
class BrokerTelemetry:
    """Aggregated across ticks; ``reports`` keeps a bounded recent window."""

    ticks: int = 0
    requests: int = 0
    cache_hits: int = 0
    coalesced: int = 0
    solved: int = 0
    dispatches: int = 0
    elastic_requests: int = 0
    rejected_requests: int = 0
    batch_sessions: int = 0
    batch_solved: int = 0
    faults: int = 0
    retries: int = 0
    breaker_trips: int = 0
    degraded_replies: int = 0
    timed_out_requests: int = 0
    max_queue_depth: int = 0
    total_latency_s: float = 0.0
    reports: list[TickReport] = dataclasses.field(default_factory=list)
    keep_reports: int = 256
    # export plane (None = legacy standalone counters).  Once bound, every
    # legacy field is a view over a registry counter: record() increments
    # both from the same TickReport, so `telemetry.requests` and
    # `registry.value("broker_requests")` can never disagree (asserted by
    # tests/test_observability.py), and the registry additionally carries
    # the tick-latency histogram the plain fields never had.
    metrics: "MetricsRegistry | None" = None

    # TickReport field → registry counter, the mirrored-view schema
    _COUNTER_VIEWS = (
        ("requests", "broker_requests"),
        ("cache_hits", "broker_cache_hits"),
        ("coalesced", "broker_coalesced"),
        ("solved", "broker_solved"),
        ("dispatches", "broker_dispatches"),
        ("elastic", "broker_elastic_requests"),
        ("rejected", "broker_rejected_requests"),
        ("batch_sessions", "broker_batch_sessions"),
        ("batch_solved", "broker_batch_solved"),
        ("faults", "broker_faults"),
        ("retries", "broker_retries"),
        ("breaker_trips", "broker_breaker_trips"),
        ("degraded", "broker_degraded_replies"),
        ("timed_out", "broker_timed_out_requests"),
    )

    def bind_metrics(self, registry: "MetricsRegistry") -> None:
        """Attach the export plane; counters/histograms mirror every
        subsequent :meth:`record` (pre-bind history is seeded so views
        stay equal to the legacy fields)."""
        self.metrics = registry
        registry.counter("broker_ticks").inc(self.ticks)
        for field, counter in self._COUNTER_VIEWS:
            total = getattr(self, _TEL_FIELD[field])
            if total:
                registry.counter(counter).inc(total)

    def tick_latency_quantiles(self) -> tuple[float, float, float]:
        """(p50, p90, p99) tick latency from the bound registry histogram
        (zeros while unbound or before the first tick)."""
        if self.metrics is None:
            return (0.0, 0.0, 0.0)
        h = self.metrics.get_histogram("broker_tick_latency_s")
        if h is None:
            return (0.0, 0.0, 0.0)
        return (h.p50, h.p90, h.p99)

    def _bound_instruments(self):
        """Resolve (and cache) the mirrored instruments: the per-tick
        hot path must not pay a registry lookup per counter."""
        b = self.__dict__.get("_instr")
        if b is None or b[0] is not self.metrics:
            reg = self.metrics
            b = (
                reg,
                reg.counter("broker_ticks"),
                tuple(
                    (field, reg.counter(c)) for field, c in self._COUNTER_VIEWS
                ),
                reg.histogram("broker_tick_latency_s"),
            )
            self.__dict__["_instr"] = b
        return b

    def record(self, report: TickReport) -> None:
        if self.metrics is not None:
            _, ticks_c, views, latency_h = self._bound_instruments()
            ticks_c.inc()
            for field, counter in views:
                v = getattr(report, field)
                if v:
                    counter.inc(v)
            latency_h.observe(report.latency_s)
        self.ticks += 1
        self.requests += report.requests
        self.cache_hits += report.cache_hits
        self.coalesced += report.coalesced
        self.solved += report.solved
        self.dispatches += report.dispatches
        self.elastic_requests += report.elastic
        self.rejected_requests += report.rejected
        self.batch_sessions += report.batch_sessions
        self.batch_solved += report.batch_solved
        self.faults += report.faults
        self.retries += report.retries
        self.breaker_trips += report.breaker_trips
        self.degraded_replies += report.degraded
        self.timed_out_requests += report.timed_out
        self.max_queue_depth = max(self.max_queue_depth, report.queue_depth)
        self.total_latency_s += report.latency_s
        self.reports.append(report)
        del self.reports[: -self.keep_reports]

    @property
    def coalesce_ratio(self) -> float:
        """Fraction of requests that did NOT need their own solve."""
        return 1.0 - self.solved / self.requests if self.requests else 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.requests if self.requests else 0.0

    @property
    def mean_tick_latency_s(self) -> float:
        return self.total_latency_s / self.ticks if self.ticks else 0.0

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "requests": self.requests,
            "cache_hits": self.cache_hits,
            "coalesced": self.coalesced,
            "solved": self.solved,
            "dispatches": self.dispatches,
            "elastic_requests": self.elastic_requests,
            "rejected_requests": self.rejected_requests,
            "batch_sessions": self.batch_sessions,
            "batch_solved": self.batch_solved,
            "faults": self.faults,
            "retries": self.retries,
            "breaker_trips": self.breaker_trips,
            "degraded_replies": self.degraded_replies,
            "timed_out_requests": self.timed_out_requests,
            "max_queue_depth": self.max_queue_depth,
            "coalesce_ratio": round(self.coalesce_ratio, 4),
            "hit_rate": round(self.hit_rate, 4),
            "mean_tick_latency_s": self.mean_tick_latency_s,
        }


@dataclasses.dataclass
class _Tenant:
    name: str
    profile: AppProfile | None
    cost_model: CostModel | None
    cache: PlacementCache
    fingerprint: str | None
    weight: float = 1.0


@dataclasses.dataclass
class _Request:
    tenant: _Tenant
    g: WCG | None               # None = deferred: built at tick time from env
    key: tuple[int, ...]
    future: PlacementFuture
    env: Environment | None = None
    lane: str = "user"
    expires: int | None = None  # absolute tick deadline (None = no deadline)

    @property
    def n(self) -> int:
        """Graph size of this request (profile size while deferred)."""
        return self.g.n if self.g is not None else self.tenant.profile.n


@dataclasses.dataclass
class _TickCtx:
    """One tick's fault/resilience scratchpad (resilient brokers only)."""

    injector: FaultInjector | None
    policy: ResiliencePolicy | None
    sleep: Callable[[float], None]
    entry_of: dict[int, QueueEntry] = dataclasses.field(default_factory=dict)
    solve_seq: int = 0          # per-tick dispatch-attempt counter ("solve" site)
    price_seq: int = 0          # per-tick pricing-attempt counter ("pricing" site)
    faults: int = 0
    retries: int = 0
    breaker_trips: int = 0
    degraded: int = 0

    @property
    def attempts(self) -> int:
        return self.policy.retry.attempts if self.policy is not None else 1


class OffloadBroker:
    """Coalescing tick-driven front end over the batched MCOP engine.

    Parameters:
      backend:  MCOP batch backend for the solves ("jax", "pallas",
                "reference" — the latter loops the numpy oracle, used by
                parity tests).
      buckets:  static shape buckets; each tick issues at most one
                ``mcop_batch`` call per bucket, shared across tenants.
      clock:    injectable monotonic clock for tick-latency telemetry
                (tests pass a fake clock so reports are deterministic).
      max_queued_bins: backpressure cap on distinct queued user-lane
                (tenant, bin) pairs; a submission opening a new bin past
                the cap gets an immediately-resolved rejection future
                (``None`` disables rejection — the default, matching the
                historical unbounded queue).
      resilience: optional
                :class:`~repro.service.resilience.ResiliencePolicy` —
                retry/backoff on failing dispatches, per-backend circuit
                breaker, per-request deadlines, and graceful degradation
                of quarantined (bin, bucket) flushes.  ``None`` keeps
                the legacy contract: failures re-queue unresolved
                requests and re-raise.
      fault_injector: optional seeded
                :class:`~repro.service.faults.FaultInjector` consulted
                at the solve / pricing / cache-load / cache-store sites
                (chaos testing and the faults benchmark).  With
                ``rate=0`` or ``enabled=False`` every broker event is
                bit-identical to a broker without an injector.
      tracer:   optional :class:`~repro.obs.trace.Tracer` — the tick
                emits per-stage spans (materialize, cache probe, per-
                bucket solve flush, pricing, commit, batch groups) and
                tags fault/retry/breaker/degraded/timed-out events onto
                the active span, so a degraded reply in an exported
                trace is attributable to the exact injected fault.
      metrics:  optional :class:`~repro.obs.metrics.MetricsRegistry` —
                telemetry counters mirror into it
                (:meth:`BrokerTelemetry.bind_metrics`), tick latency
                feeds a quantile histogram, tenant caches bind
                hit/miss/eviction counters, solver dispatches record
                per-(backend, bucket) timing, and scheduler queue
                depth / queued bins / per-tenant deficits publish as
                gauges each tick.

    ``tracer``/``metrics`` are pure observers: with both detached
    (default) every instrumented path is bit-identical to the
    pre-observability broker (asserted by
    ``tests/test_observability.py``), and neither ever reads the
    broker's ``clock`` (the tracer keeps its own).
    """

    def __init__(
        self,
        *,
        backend: str = "jax",
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        clock: Callable[[], float] = time.perf_counter,
        max_queued_bins: int | None = None,
        resilience: ResiliencePolicy | None = None,
        fault_injector: FaultInjector | None = None,
        tracer: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
        mesh=None,
    ):
        if backend not in ("reference", "jax", "pallas"):
            raise ValueError(f"unknown MCOP batch backend: {backend!r}")
        self.backend = backend
        self.buckets = tuple(buckets)
        # Solver-fleet routing (repro.core.mcop_shard): resolved ONCE at
        # construction so every flush this broker dispatches — bucket
        # solves and batch-group ticks alike — sees the same fleet.
        # None = auto (shard when >1 device), False = force single-device,
        # Mesh = shard over exactly that fleet.
        from repro.core.mcop_shard import resolve_mesh, solver_shards

        self.mesh = resolve_mesh(mesh)
        self._devices = 1 if self.mesh is None else solver_shards(self.mesh)
        self.clock = clock
        self.resilience = resilience
        self.fault_injector = fault_injector
        self.tracer = tracer
        self.metrics = metrics
        self._obs_gauges = None  # cached gauge instruments (see tick)
        self.telemetry = BrokerTelemetry()
        if metrics is not None:
            self.telemetry.bind_metrics(metrics)
        self._tenants: dict[str, _Tenant] = {}
        self._scheduler = WeightedFairScheduler(max_queued_bins=max_queued_bins)
        self._batch_groups: list = []  # BatchSessionGroup, registration order
        self._rejected_since_tick = 0
        self._deadlines_armed = False
        self._tick = 0

    # -- tenants ---------------------------------------------------------
    def register(
        self,
        name: str,
        profile: AppProfile | None = None,
        cost_model: CostModel | None = None,
        *,
        cache: PlacementCache | None = None,
        quantizer: EnvQuantizer | None = None,
        cache_capacity: int = 4096,
        warm_start=None,
        weight: float = 1.0,
        adaptive_weight: bool = False,
    ) -> _Tenant:
        """Register a served application (or a raw-graph producer).

        With a ``profile`` + ``cost_model`` the tenant accepts
        :meth:`submit`; raw-graph tenants (e.g. the elastic manager,
        whose WCG is built from stage/tier specs) use
        :meth:`submit_graph` and may register with ``profile=None``.
        ``warm_start`` is a snapshot dict or JSON path loaded into the
        tenant cache under the profile's fingerprint guard — a
        mismatched or corrupt snapshot cold-starts silently.
        ``weight`` is the tenant's weighted-fair share of a budgeted
        tick (deficit round robin; see
        :class:`~repro.service.scheduler.WeightedFairScheduler`).
        ``adaptive_weight=True`` additionally opts the tenant into the
        scheduler's load-adaptive weighting: the broker feeds each
        tick's per-tenant service latency into an EWMA, and the
        effective weight scales by inverse recent latency (clamped
        around ``weight``; see
        :meth:`~repro.service.scheduler.WeightedFairScheduler.set_adaptive`).
        """
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if (profile is None) != (cost_model is None):
            raise ValueError("profile and cost_model must be given together")
        # the snapshot guard covers the whole (profile, objective) pair: a
        # cache warmed under one cost model must not serve another
        fingerprint = (
            f"{profile_fingerprint(profile)}:{cost_model.fingerprint}"
            if profile is not None
            else None
        )
        if cache is None:
            cache = PlacementCache(quantizer, capacity=cache_capacity)
        tenant = _Tenant(name, profile, cost_model, cache, fingerprint, weight)
        if warm_start is not None:
            cache.load(warm_start, fingerprint=fingerprint)
        if self.metrics is not None:
            cache.bind_metrics(self.metrics, tenant=name)
        self._tenants[name] = tenant
        self._scheduler.ensure_tenant(name, weight=weight)
        if adaptive_weight:
            self._scheduler.set_adaptive(name)
        return tenant

    def register_batch(
        self,
        name: str,
        capacity: int,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
        device_telemetry: bool = False,
    ):
        """Attach a :class:`~repro.service.session.BatchSessionGroup`.

        ``capacity`` session slots of tenant ``name`` held as one
        :class:`~repro.core.session_batch.SessionBatch` pytree: the
        group stages a whole tick of observations as arrays and
        :meth:`tick` resolves it with ONE vectorized
        ``tick_sessions`` call against the tenant's shared cache — the
        10⁵–10⁶-user path.  Groups tick after the request queue drains,
        ordered by scheduler weight (descending; registration order
        breaks ties), and each group's service latency feeds the
        scheduler's load-adaptive weighting when the tenant opted in.
        """
        # deferred import: session.py imports the broker module
        from repro.service.session import BatchSessionGroup

        t = self._tenants[name]
        if t.profile is None:
            raise ValueError(
                f"tenant {name!r} has no profile; batch groups need one"
            )
        group = BatchSessionGroup(
            self,
            name,
            capacity=capacity,
            threshold=threshold,
            min_interval=min_interval,
            device_telemetry=device_telemetry,
        )
        self._batch_groups.append(group)
        return group

    def set_weight(self, name: str, weight: float) -> None:
        """Adjust a tenant's weighted-fair share for future ticks."""
        self._tenants[name].weight = float(weight)
        self._scheduler.set_weight(name, weight)

    def tenant(self, name: str) -> _Tenant:
        return self._tenants[name]

    def snapshot(self, name: str) -> dict:
        """Fingerprint-stamped snapshot of one tenant's cache."""
        t = self._tenants[name]
        return t.cache.snapshot(fingerprint=t.fingerprint)

    def save_snapshot(self, name: str, path, *, meta: dict | None = None) -> None:
        t = self._tenants[name]
        t.cache.save(path, fingerprint=t.fingerprint, meta=meta)

    def restore_tick(self, tick: int) -> None:
        """Fast-forward the tick counter to ``tick`` (warm restart).

        Replies stamp the tick they resolved on, so a serving plane
        replaying a journal tail after a crash must first realign the
        counter with the persisted history — otherwise the replayed
        replies would renumber from zero and break bit-identity with
        the uninterrupted run.  Only ever move forward on an idle
        broker: rewinding (or skipping while requests are queued) would
        corrupt armed deadlines and the telemetry timeline.
        """
        tick = int(tick)
        if tick < self._tick:
            raise ValueError(
                f"cannot rewind tick counter {self._tick} -> {tick}"
            )
        if self._scheduler.pending and tick != self._tick:
            raise RuntimeError("restore_tick requires an empty queue")
        self._tick = tick

    # -- submission ------------------------------------------------------
    def _enqueue(self, r: _Request) -> PlacementFuture:
        """Offer a request to the scheduler, resolving rejections inline.

        The backpressure bin is (tenant, graph size, quantized env) —
        exactly the coalescing unit, so joining an already-queued bin is
        always admitted (it costs no extra solver work) and only a
        submission that would open a new bin past the cap is rejected.
        """
        admitted = self._scheduler.submit(
            QueueEntry(r.tenant.name, r, (r.n, r.key), lane=r.lane)
        )
        if not admitted:
            self._rejected_since_tick += 1
            self._event(
                "rejected",
                tenant=r.tenant.name,
                tick=self._tick,
                reason="backpressure",
            )
            r.future.set(
                BrokerReply(
                    None,
                    cache_hit=False,
                    coalesced=False,
                    tick=self._tick,
                    rejected=True,
                )
            )
        return r.future

    def _deadline_tick(self, deadline: int | None) -> int | None:
        """Absolute expiry tick for a submission (arms the deadline sweep)."""
        if deadline is None and self.resilience is not None:
            deadline = self.resilience.deadline_ticks
        if deadline is None:
            return None
        if deadline <= 0:
            raise ValueError("deadline must be positive (ticks)")
        self._deadlines_armed = True
        return self._tick + int(deadline)

    def submit(
        self,
        name: str,
        env: Environment,
        *,
        lane: str = "user",
        deadline: int | None = None,
    ) -> PlacementFuture:
        """Enqueue a solve for ``env`` under the tenant's cost model.

        Args:
          name: registered tenant (must have a profile + cost model).
          env:  the environment to price/partition for; also determines
                the coalescing bin via the tenant cache's quantizer.
          lane: ``"user"`` (weighted-fair) or ``"elastic"`` (strict
                priority, e.g. fleet resizes).
          deadline: optional per-request deadline in ticks — a request
                still queued after that many ticks resolves as
                ``timed_out`` (default: the resilience policy's
                ``deadline_ticks``, or no deadline).
        Returns:
          :class:`PlacementFuture`, resolved by a later :meth:`tick` —
          or immediately with a ``rejected`` reply when the scheduler's
          queued-bin cap is reached.

        Construction is deferred: the WCG is built at the next tick, where
        all of this tenant's pending environments go through ONE vectorized
        ``cost_model.build_batch`` call instead of a Python build per
        request.
        """
        t = self._tenants[name]
        if t.profile is None:
            raise ValueError(
                f"tenant {name!r} has no profile; use submit_graph()"
            )
        return self._enqueue(
            _Request(
                t,
                None,
                t.cache.key(env),
                PlacementFuture(),
                env=env,
                lane=lane,
                expires=self._deadline_tick(deadline),
            )
        )

    def submit_graph(
        self,
        name: str,
        g: WCG,
        env: Environment,
        *,
        lane: str = "user",
        deadline: int | None = None,
    ) -> PlacementFuture:
        """Enqueue a caller-built WCG; ``env`` only determines the bin key.

        Same future/rejection/deadline semantics as :meth:`submit`; used
        by raw-graph tenants (elastic manager, broker sessions carrying
        an already-built controller graph).
        """
        t = self._tenants[name]
        return self._enqueue(
            _Request(
                t,
                g,
                t.cache.key(env),
                PlacementFuture(),
                env=env,
                lane=lane,
                expires=self._deadline_tick(deadline),
            )
        )

    @property
    def pending(self) -> int:
        return self._scheduler.pending

    @property
    def queued_bins(self) -> int:
        """Distinct queued (tenant, bin) pairs — the backpressure gauge."""
        return self._scheduler.queued_bins

    # -- the tick --------------------------------------------------------
    def tick(self, *, budget: int | None = None) -> TickReport:
        """Drain the scheduler: lanes → hits → followers → bucket dispatches.

        Args:
          budget: optional cap on requests drained this tick.  The
            weighted-fair scheduler then splits the budget across
            tenants proportionally to their weights (elastic-lane events
            always drain first); undrained requests stay queued for the
            next tick.  ``None`` (default) drains everything.
        Returns:
          :class:`TickReport` — per-tick telemetry, including the
          per-tenant WFQ ``shares`` and backpressure ``rejected`` count.

        Elastic-lane requests are flushed ahead of user-lane requests;
        within a tenant, FIFO order is preserved, so cache counters and
        placements are bit-identical to N serial controllers sharing one
        cache and observing in submission order (asserted by the
        broker↔serial parity tests).  Deferred (env-only) submissions are
        materialized here, one vectorized cost-model build per tenant,
        and every reply is priced in one vectorized evaluation per graph
        size (see :meth:`_price_replies`).

        Failure containment: if a solve dispatch raises (transient
        device/XLA error), every request whose future is still unresolved
        is put back at the front of the queue before the exception
        propagates, so the next :meth:`tick` retries instead of stranding
        waiters forever.
        """
        t0 = self.clock()
        self._tick += 1
        with self._span("broker.tick", tick=self._tick) as root:
            # deadline sweep BEFORE draining: an overdue request must
            # resolve as timed_out, not be served late (the sweep only ever
            # runs once a deadline has actually been armed, so deadline-free
            # brokers pay nothing and stay bit-identical to the historical
            # tick)
            timed_out = 0
            if self._deadlines_armed:
                for e in self._scheduler.expire(
                    lambda e: e.item.expires is not None
                    and e.item.expires < self._tick
                ):
                    if not e.item.future.done:
                        e.item.future.set(
                            BrokerReply(
                                None,
                                cache_hit=False,
                                coalesced=False,
                                tick=self._tick,
                                timed_out=True,
                            )
                        )
                        timed_out += 1
                        self._event(
                            "timed_out",
                            tenant=e.item.tenant.name,
                            tick=self._tick,
                        )
            depth = self._scheduler.pending
            entries = self._scheduler.drain(budget)
            requests = [e.item for e in entries]
            ctx = (
                _TickCtx(
                    self.fault_injector,
                    self.resilience,
                    self._backoff_sleep,
                    entry_of={id(e.item): e for e in entries},
                )
                if self.resilience is not None
                or self.fault_injector is not None
                else None
            )
            try:
                # materialization is inside the containment: a failing
                # deferred build (bad environment) must re-queue innocents,
                # not drop them
                self._materialize(requests, ctx)
                report = self._run_tick(requests, depth, ctx)
            except BaseException as err:
                self._scheduler.requeue(
                    e for e in entries if not e.item.future.done
                )
                if self.resilience is None or not isinstance(err, Exception):
                    raise
                # resilient backstop: an error that escaped the per-bucket
                # quarantine is still contained — unresolved requests are
                # already back at the front of the queue for the next tick
                if ctx is not None:
                    ctx.faults += 1
                self._event(
                    "tick_contained", tick=self._tick, error=type(err).__name__
                )
                report = TickReport(
                    tick=self._tick,
                    queue_depth=depth,
                    requests=len(requests),
                    cache_hits=0,
                    coalesced=0,
                    solved=0,
                    dispatches=0,
                    buckets=(),
                    latency_s=0.0,
                    elastic=sum(r.lane == "elastic" for r in requests),
                    rejected=self._rejected_since_tick,
                    shares=(),
                )
            # batched session groups tick after the request queue: each is
            # one vectorized tick_sessions call, atomic on its own (a
            # failing group keeps its staged observation for retry and does
            # not disturb the already-resolved request futures above)
            report = self._tick_batches(report, ctx)
            if ctx is not None:
                report = dataclasses.replace(
                    report,
                    faults=ctx.faults,
                    retries=ctx.retries,
                    breaker_trips=ctx.breaker_trips,
                    degraded=ctx.degraded,
                )
            if timed_out:
                report = dataclasses.replace(report, timed_out=timed_out)
            report = dataclasses.replace(report, latency_s=self.clock() - t0)
            self._rejected_since_tick = 0
            self.telemetry.record(report)
            root.set(
                queue_depth=report.queue_depth,
                requests=report.requests,
                cache_hits=report.cache_hits,
                coalesced=report.coalesced,
                solved=report.solved,
                dispatches=report.dispatches,
                degraded=report.degraded,
                timed_out=report.timed_out,
                faults=report.faults,
            )
        if self.metrics is not None:
            self._publish_gauges()
        return report

    def _publish_gauges(self) -> None:
        """Post-tick scheduler gauges (cached instruments: no registry
        lookups on the per-tick path)."""
        g = self._obs_gauges
        if g is None:
            g = self._obs_gauges = (
                self.metrics.gauge("broker_queue_depth"),
                self.metrics.gauge("broker_queued_bins"),
                {},  # tenant -> (deficit gauge, weight gauge)
            )
        g[0].set(self._scheduler.pending)
        g[1].set(self._scheduler.queued_bins)
        per_tenant = g[2]
        for name, deficit in self._scheduler.deficits().items():
            pair = per_tenant.get(name)
            if pair is None:
                pair = per_tenant[name] = (
                    self.metrics.gauge("scheduler_deficit", tenant=name),
                    self.metrics.gauge("scheduler_weight", tenant=name),
                )
            pair[0].set(deficit)
            pair[1].set(self._scheduler.weight(name))

    def drain(self) -> int:
        """Resolve every still-queued future as ``rejected`` (shutdown).

        A broker being torn down must not strand waiters: all queued
        requests — whatever their lane or deadline — resolve immediately
        with a ``rejected`` reply, and staged (un-ticked) batch-group
        observations are discarded so the groups can be re-observed
        against another broker.  Returns the number of futures resolved.
        """
        n = 0
        for e in self._scheduler.drain(None):
            if not e.item.future.done:
                e.item.future.set(
                    BrokerReply(
                        None,
                        cache_hit=False,
                        coalesced=False,
                        tick=self._tick,
                        rejected=True,
                    )
                )
                n += 1
        self.telemetry.rejected_requests += n
        for group in self._batch_groups:
            group.discard_staged()
        return n

    def _backoff_sleep(self, seconds: float) -> None:
        """Charge backoff/latency time to the broker clock.

        Injected clocks (anything with ``advance``) are advanced —
        deterministic tests and benchmarks never actually sleep; real
        clocks sleep for real.
        """
        if seconds <= 0:
            return
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(seconds)
        else:
            time.sleep(seconds)

    # -- observability guards (None tracer/registry compile away to no-ops
    # -- without ever touching a clock: the broker's injected clock must be
    # -- read exactly twice per tick with or without instrumentation) --
    def _span(self, name: str, **attrs):
        return (
            self.tracer.span(name, **attrs)
            if self.tracer is not None
            else NULL_SPAN
        )

    def _event(self, name: str, **attrs) -> None:
        if self.tracer is not None:
            self.tracer.event(name, **attrs)

    def _timer(self, name: str, **labels):
        return (
            self.metrics.timer(name, **labels)
            if self.metrics is not None
            else NULL_SPAN
        )

    def _tick_batches(
        self, report: TickReport, ctx: _TickCtx | None = None
    ) -> TickReport:
        """Run every staged batch group; fold counts into the report.

        Groups run ordered by current scheduler weight (descending,
        registration order breaking ties — the WFQ notion of precedence
        applied at group granularity), and each group's wall time is
        reported to the scheduler as that tenant's service latency,
        which drives the load-adaptive weights of opted-in tenants.
        """
        staged = [g for g in self._batch_groups if g.pending]
        if not staged:
            return report
        staged.sort(key=lambda g: -self._scheduler.weight(g.tenant))
        groups = sessions = hits = solved = 0
        for group in staged:
            g0 = self.clock()
            with self._span("stage.batch_group", tenant=group.tenant):
                try:
                    group_report = group._tick()
                except Exception as err:
                    # resilient brokers contain a failing group to its own
                    # failure domain: the staged observation is kept (the
                    # group retries next tick) and healthy groups still run
                    if self.resilience is None:
                        raise
                    if ctx is not None:
                        ctx.faults += 1
                    self._event(
                        "group_contained",
                        tenant=group.tenant,
                        tick=self._tick,
                        error=type(err).__name__,
                    )
                    self._scheduler.observe_latency(
                        group.tenant, self.clock() - g0
                    )
                    continue
            self._scheduler.observe_latency(group.tenant, self.clock() - g0)
            if group_report is None:
                continue
            groups += 1
            sessions += int(np.count_nonzero(group_report.active))
            hits += group_report.hits + group_report.coalesced
            solved += group_report.solved
            if ctx is not None:
                ctx.faults += group_report.faults
                ctx.retries += group_report.retries
                ctx.breaker_trips += group_report.breaker_trips
                if group_report.degraded is not None:
                    ctx.degraded += int(
                        np.count_nonzero(group_report.degraded)
                    )
        return dataclasses.replace(
            report,
            batch_groups=groups,
            batch_sessions=sessions,
            batch_hits=hits,
            batch_solved=solved,
        )

    def _materialize(
        self, requests: list[_Request], ctx: _TickCtx | None = None
    ) -> None:
        """Build deferred WCGs: one ``build_batch`` per tenant per tick.

        Rows of the vectorized build are bit-identical to the scalar
        ``cost_model.build`` (same code path, batch of K), so deferral
        never changes a placement or a reported cost.

        Resilient brokers additionally quarantine requests whose
        *environment* carries a non-finite scalar before the vectorized
        build: one poisoned observation must not abort the whole
        tenant's build (the legacy path lets ``build_batch`` raise —
        ``NonFiniteWeightError`` — and the tick containment re-queue).
        A quarantined request resolves immediately as ``rejected``: its
        input is invalid, so no placement — stale or fallback — can
        honestly answer it.
        """
        deferred: dict[str, list[_Request]] = {}
        for r in requests:
            if r.g is None:
                deferred.setdefault(r.tenant.name, []).append(r)
        if not deferred:
            return
        with self._span(
            "stage.materialize",
            tenants=len(deferred),
            requests=sum(len(rs) for rs in deferred.values()),
        ):
            self._materialize_deferred(deferred, ctx)

    def _materialize_deferred(
        self, deferred: dict[str, list[_Request]], ctx: _TickCtx | None
    ) -> None:
        for name, rs in deferred.items():
            if ctx is not None and ctx.policy is not None:
                kept = []
                for r in rs:
                    if all(
                        math.isfinite(float(v))
                        for v in dataclasses.astuple(r.env)
                    ):
                        kept.append(r)
                        continue
                    self._rejected_since_tick += 1
                    self._event(
                        "rejected",
                        tenant=name,
                        tick=self._tick,
                        reason="non_finite_env",
                    )
                    r.future.set(
                        BrokerReply(
                            None,
                            cache_hit=False,
                            coalesced=False,
                            tick=self._tick,
                            rejected=True,
                        )
                    )
                rs = kept
                if not rs:
                    continue
            t = self._tenants[name]
            batch = t.cost_model.build_batch(t.profile, [r.env for r in rs])
            for i, r in enumerate(rs):
                r.g = batch.wcg(i)

    @staticmethod
    def _price_rows(
        graphs: list[WCG], masks: list[np.ndarray]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized Eq.-2 + all-local pricing of (graph, mask) rows.

        One :meth:`~repro.core.graph.WCGBatch.total_cost` evaluation per
        distinct graph size (unpadded, so every number is bit-identical
        to the scalar per-row path — see ``repro.core.pricing``).
        Returns ``(partial, no_offload)`` float arrays aligned with the
        rows.
        """
        partial = np.zeros(len(graphs))
        no_off = np.zeros(len(graphs))
        by_n: dict[int, list[int]] = {}
        for i, g in enumerate(graphs):
            by_n.setdefault(g.n, []).append(i)
        for n, idxs in by_n.items():
            batch = WCGBatch.from_wcgs([graphs[i] for i in idxs], m=n)
            stacked = np.stack([masks[i] for i in idxs])
            partial[idxs] = batch.total_cost(stacked)
            no_off[idxs] = np.asarray(batch.w_local).sum(axis=-1)
        return partial, no_off

    def _reply(self, result: MCOPResult, *, cache_hit: bool, coalesced: bool):
        return BrokerReply(
            result, cache_hit=cache_hit, coalesced=coalesced, tick=self._tick
        )

    # -- fault-site wrappers (ctx=None compiles away to the legacy path) --
    def _cache_lookup(
        self, r: _Request, index: int, ctx: _TickCtx | None
    ) -> np.ndarray | None:
        """Cache probe under the ``cache_load`` fault site.

        A firing error/corrupt decision discards the loaded value — the
        request is treated as a miss and re-solved (the cache is an
        optimization, never ground truth, so a lost load is always safe).
        Latency faults charge the clock and return the real value.
        """
        if ctx is not None and ctx.injector is not None:
            d = ctx.injector.decide("cache_load", self._tick, index)
            if d.fires:
                ctx.faults += 1
                self._event(
                    "fault",
                    site="cache_load",
                    kind=d.kind,
                    tick=self._tick,
                    index=index,
                )
                if d.kind == "latency":
                    ctx.sleep(d.delay_s)
                else:
                    return None
        return r.tenant.cache.lookup(r.key, expected_n=r.g.n)

    def _cache_store(
        self, r: _Request, slot: int, mask: np.ndarray, ctx: _TickCtx | None
    ) -> None:
        """Representative store under the ``cache_store`` fault site.

        A dropped store is silently absorbed: the bin simply misses again
        on a later tick and re-solves — no stale or partial entry is ever
        written.
        """
        if ctx is not None and ctx.injector is not None:
            d = ctx.injector.decide("cache_store", self._tick, slot)
            if d.fires:
                ctx.faults += 1
                self._event(
                    "fault",
                    site="cache_store",
                    kind=d.kind,
                    tick=self._tick,
                    index=slot,
                )
                if d.kind == "latency":
                    ctx.sleep(d.delay_s)
                else:
                    return
        r.tenant.cache.store(r.key, mask)

    def _priced_rows(
        self,
        graphs: list[WCG],
        masks: list[np.ndarray],
        ctx: _TickCtx | None,
    ) -> tuple[np.ndarray, np.ndarray] | None:
        """:meth:`_price_rows` under the ``pricing`` fault site, retried.

        Returns ``None`` when every attempt failed (resilient brokers
        only) — the caller degrades those rows to fallback replies.
        """
        if ctx is None:
            with self._timer("broker_price_duration_s"):
                return self._price_rows(graphs, masks)
        base = ctx.price_seq
        ctx.price_seq += ctx.attempts
        for attempt in range(ctx.attempts):
            if attempt:
                ctx.retries += 1
                self._event(
                    "retry", site="pricing", attempt=attempt, tick=self._tick
                )
                if ctx.policy is not None:
                    ctx.sleep(ctx.policy.retry.backoff(attempt - 1))
            try:
                if ctx.injector is not None:
                    d = ctx.injector.decide(
                        "pricing", self._tick, base + attempt
                    )
                    if d.fires:
                        ctx.faults += 1
                        self._event(
                            "fault",
                            site="pricing",
                            kind=d.kind,
                            tick=self._tick,
                            index=base + attempt,
                        )
                        if d.kind == "latency":
                            ctx.sleep(d.delay_s)
                        else:
                            raise InjectedFault(
                                "pricing", self._tick, base + attempt, d.kind
                            )
                with self._timer("broker_price_duration_s"):
                    return self._price_rows(graphs, masks)
            except Exception:
                if ctx.policy is None:
                    raise
        return None

    def _dispatch(
        self, wb: WCGBatch, m: int, ctx: _TickCtx | None
    ) -> list[MCOPResult] | None:
        """One bucket's ``mcop_batch`` under retry/breaker/fault policy.

        Resilient path, per attempt: pick the effective backend (the
        circuit breaker walks pallas → jax → reference past open
        circuits), consult the injector (corruption poisons a COPY of
        the batch — caught by ``validate_finite`` before it can be
        silently solved), dispatch, and reject non-finite cut values.
        Returns ``None`` when all attempts failed — the caller
        quarantines exactly this bucket's requests, nothing else.
        """
        if ctx is None:
            with self._timer(
                "mcop_dispatch_duration_s",
                backend=self.backend, bucket=m, devices=self._devices,
            ):
                return mcop_batch(
                    wb, backend=self.backend, buckets=(m,),
                    mesh=self.mesh if self.mesh is not None else False,
                    tracer=self.tracer,
                )
        policy = ctx.policy
        breaker = policy.breaker if policy is not None else None
        for attempt in range(ctx.attempts):
            if attempt:
                ctx.retries += 1
                self._event(
                    "retry",
                    site="solve",
                    attempt=attempt,
                    bucket=m,
                    tick=self._tick,
                )
                if policy is not None:
                    ctx.sleep(policy.retry.backoff(attempt - 1))
            backend = (
                breaker.backend(self.backend, self._tick)
                if breaker is not None
                else self.backend
            )
            index = ctx.solve_seq
            ctx.solve_seq += 1
            use = wb
            try:
                if ctx.injector is not None:
                    d = ctx.injector.decide("solve", self._tick, index)
                    if d.fires:
                        ctx.faults += 1
                        self._event(
                            "fault",
                            site="solve",
                            kind=d.kind,
                            tick=self._tick,
                            index=index,
                            bucket=m,
                        )
                        if d.kind == "latency":
                            ctx.sleep(d.delay_s)
                        elif d.kind == "error":
                            raise InjectedFault("solve", self._tick, index)
                        else:
                            use = poison_batch(wb)
                use.validate_finite()
                with self._timer(
                    "mcop_dispatch_duration_s",
                    backend=backend, bucket=m, devices=self._devices,
                ):
                    out = mcop_batch(
                        use, backend=backend, buckets=(m,),
                        mesh=self.mesh if self.mesh is not None else False,
                        tracer=self.tracer,
                    )
                if not all(math.isfinite(res.min_cut) for res in out):
                    raise RuntimeError(
                        "non-finite min_cut from solver dispatch"
                    )
                if breaker is not None:
                    breaker.record_success(backend)
                return out
            except Exception:
                if breaker is not None and breaker.record_failure(
                    backend, self._tick
                ):
                    ctx.breaker_trips += 1
                    self._event(
                        "breaker_trip",
                        backend=backend,
                        bucket=m,
                        tick=self._tick,
                    )
                if policy is None:
                    raise
        return None

    def _fallback_reply(
        self,
        r: _Request,
        ctx: _TickCtx,
        *,
        count: bool = True,
        cache_hit: bool = False,
        coalesced: bool = False,
    ) -> None:
        """Serve the safe placement: stale cached bin, else §4.3 no-offload.

        The stale probe is uncounted — the request's single cache-stat
        event is the miss recorded here when ``count`` (hits that
        degraded at pricing were already counted at classification).
        Fallbacks never store: the bin stays cold and re-solves once the
        fault clears.
        """
        mask = r.tenant.cache.lookup(r.key, expected_n=r.g.n)
        no_off = float(np.asarray(r.g.w_local).sum())
        if mask is None:
            res = MCOPResult(
                min_cut=no_off,
                local_mask=np.ones(r.g.n, dtype=bool),
                phases=[],
            )
        else:
            res = baselines.reprice_clamped_priced(
                float(r.g.total_cost(mask)), no_off, mask
            )
        if count:
            r.tenant.cache.record(False)
        ctx.degraded += 1
        self._event(
            "degraded",
            tenant=r.tenant.name,
            tick=self._tick,
            stale=mask is not None,
        )
        r.future.set(
            BrokerReply(
                res,
                cache_hit=cache_hit,
                coalesced=coalesced,
                tick=self._tick,
                degraded=True,
            )
        )

    def _quarantine(
        self, rep: _Request, fols: list[_Request], ctx: _TickCtx
    ) -> None:
        """Contain one (bin, bucket) flush failure to its own requests."""
        if ctx.policy is not None and ctx.policy.degrade == "requeue":
            self._scheduler.requeue(
                ctx.entry_of[id(r)]
                for r in (rep, *fols)
                if id(r) in ctx.entry_of
            )
            return
        self._fallback_reply(rep, ctx)
        for f in fols:
            self._fallback_reply(f, ctx, coalesced=True)

    def _run_tick(
        self,
        requests: list[_Request],
        depth: int,
        ctx: _TickCtx | None = None,
    ) -> TickReport:
        # requests quarantined at materialization (invalid environment)
        # are already resolved and never got a graph
        requests = [r for r in requests if r.g is not None]
        hits = coalesced = 0
        solves: list[_Request] = []
        hit_rows: list[tuple[_Request, np.ndarray]] = []
        # coalescing key includes the vertex count: a raw-graph tenant may
        # legally mix graph sizes in one env bin, and a follower must never
        # be handed a wrong-length mask (mirrors the cache's expected_n)
        rep_slot: dict[tuple[str, int, tuple[int, ...]], int] = {}
        followers: dict[int, list[_Request]] = {}
        with self._span("stage.cache_probe", requests=len(requests)) as probe:
            for i, r in enumerate(requests):
                mask = self._cache_lookup(r, i, ctx)
                if mask is not None:
                    r.tenant.cache.record(True)
                    hits += 1
                    hit_rows.append((r, mask))
                    continue
                slot_key = (r.tenant.name, r.g.n, r.key)
                if slot_key in rep_slot:
                    coalesced += 1
                    followers.setdefault(rep_slot[slot_key], []).append(r)
                    continue
                rep_slot[slot_key] = len(solves)
                solves.append(r)
            probe.set(hits=hits, coalesced=coalesced, misses=len(solves))

        # cache hits are priced in ONE vectorized evaluation per graph
        # size and resolved BEFORE any solver dispatch — a failing
        # dispatch must not strand futures the cache already answered
        if hit_rows:
            with self._span(
                "stage.pricing", phase="hits", rows=len(hit_rows)
            ):
                priced = self._priced_rows(
                    [r.g for r, _ in hit_rows], [m for _, m in hit_rows], ctx
                )
            if priced is None:
                # pricing exhausted its retries: the hits were already
                # counted at classification, serve each the fallback
                for r, _ in hit_rows:
                    self._fallback_reply(r, ctx, count=False, cache_hit=True)
            else:
                h_partial, h_no_off = priced
                for i, (r, mask) in enumerate(hit_rows):
                    r.future.set(
                        self._reply(
                            baselines.reprice_clamped_priced(
                                float(h_partial[i]), float(h_no_off[i]), mask
                            ),
                            cache_hit=True,
                            coalesced=False,
                        )
                    )

        # one mcop_batch call per static shape bucket, shared across
        # tenants; each bucket is packed into a WCGBatch once, so the
        # dispatch skips the per-graph packing pass.  A bucket whose
        # dispatch exhausts its retries is quarantined — its slots stay
        # None and are degraded/re-queued after the healthy buckets
        # commit below.
        by_bucket: dict[int, list[int]] = {}
        for i, r in enumerate(solves):
            by_bucket.setdefault(_bucket_size(r.g.n, self.buckets), []).append(i)
        solved: list[MCOPResult | None] = [None] * len(solves)
        dispatches = 0
        dispatched_buckets: list[int] = []
        quarantined: list[int] = []
        for m, idxs in sorted(by_bucket.items()):
            with self._span(
                "stage.solve_flush",
                bucket=m,
                batch=len(idxs),
                backend=self.backend,
                devices=self._devices,
            ):
                batch = self._dispatch(
                    WCGBatch.from_wcgs([solves[i].g for i in idxs], m=m),
                    m,
                    ctx,
                )
            if batch is None:
                self._event(
                    "quarantine", bucket=m, requests=len(idxs), tick=self._tick
                )
                quarantined.extend(idxs)
                continue
            dispatches += 1
            dispatched_buckets.append(m)
            for i, res in zip(idxs, batch):
                solved[i] = res

        # followers are priced in one more vectorized evaluation per graph
        # size: a follower's row carries its representative's RAW solved
        # mask, and the reply select below resolves it exactly like
        # reprice_clamped would.  Representatives only need the all-local
        # baseline for the §4.3 clamp — a single w_local sum each
        # (bit-identical to no_offloading(g).cost).
        row_graphs: list[WCG] = []
        row_masks: list[np.ndarray] = []

        def add_row(g: WCG, mask) -> int:
            row_graphs.append(g)
            row_masks.append(np.asarray(mask, dtype=bool))
            return len(row_graphs) - 1

        rep_no_off = [float(r.g.w_local.sum()) for r in solves]
        fol_rows = {
            s: [add_row(f.g, solved[s].local_mask) for f in fs]
            for s, fs in followers.items()
            if solved[s] is not None
        }
        if row_graphs:
            with self._span(
                "stage.pricing", phase="followers", rows=len(row_graphs)
            ):
                priced = self._priced_rows(row_graphs, row_masks, ctx)
        else:
            priced = (np.zeros(0), np.zeros(0))
        # follower repricing degraded: reps still commit below, and each
        # follower falls back (its stale probe then finds the mask its
        # representative just stored — still the freshest safe answer)
        partial, no_off = priced if priced is not None else (None, None)

        # counter recording for misses/followers happens here, after the
        # dispatches succeeded: a failed tick re-queues these requests, and
        # the retry must not double-count them (a serial shared-cache loop
        # would count each request exactly once).  Followers count as hits:
        # serially they would have hit the representative's put().
        with self._span("stage.commit", representatives=len(solves)):
            for slot, r in enumerate(solves):
                if solved[slot] is None:
                    continue  # quarantined bucket, handled below
                # §4.3 clamp against the baseline; the reply keeps the
                # solver's own cut value (shared helper with the serial path)
                rep_clamped = rep_no_off[slot] < solved[slot].min_cut
                candidate = baselines.clamp_no_offloading_priced(
                    solved[slot], rep_no_off[slot]
                )
                r.tenant.cache.record(False)
                self._cache_store(r, slot, candidate.local_mask, ctx)
                r.future.set(
                    self._reply(candidate, cache_hit=False, coalesced=False)
                )
                for f, fi in zip(
                    followers.get(slot, ()), fol_rows.get(slot, ())
                ):
                    if partial is None:
                        self._fallback_reply(f, ctx, coalesced=True)
                        continue
                    # a clamped representative hands followers the all-local
                    # mask, whose price is exactly the no-offload baseline
                    if rep_clamped:
                        res = MCOPResult(
                            min_cut=float(no_off[fi]),
                            local_mask=np.ones(f.g.n, dtype=bool),
                            phases=[],
                        )
                    else:
                        res = baselines.reprice_clamped_priced(
                            float(partial[fi]),
                            float(no_off[fi]),
                            row_masks[fi],
                        )
                    f.tenant.cache.record(True)
                    f.future.set(
                        self._reply(res, cache_hit=True, coalesced=True)
                    )

        for slot in quarantined:
            self._quarantine(
                solves[slot], list(followers.get(slot, ())), ctx
            )

        shares: dict[str, int] = {}
        for r in requests:
            shares[r.tenant.name] = shares.get(r.tenant.name, 0) + 1
        report = TickReport(
            tick=self._tick,
            queue_depth=depth,
            requests=len(requests),
            cache_hits=hits,
            coalesced=coalesced,
            solved=sum(res is not None for res in solved),
            dispatches=dispatches,
            buckets=tuple(dispatched_buckets),
            # latency is stamped by tick() once batch groups have run, so
            # the injected clock is read exactly twice per tick
            latency_s=0.0,
            elastic=sum(r.lane == "elastic" for r in requests),
            rejected=self._rejected_since_tick,
            shares=tuple(sorted(shares.items())),
        )
        return report
